//! Checkpointing: save/restore parameters + run metadata.
//!
//! Own binary format (no serde offline): magic, version, a small JSON
//! metadata blob (reuses `config::json`), then the raw f32 parameters.
//! Used by long e2e runs (`lm_pretrain --save/--resume`) and by operators
//! who want to warm-start a hybrid run from a BSP checkpoint or vice versa.

use std::io::{Read, Write};
use std::path::Path;

use crate::config::{json, Value};
use crate::{Error, Result};

const MAGIC: &[u8; 8] = b"HYBRCKP1";

/// A parameter checkpoint with free-form metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub theta: Vec<f32>,
    /// Iteration the checkpoint was taken at.
    pub iter: u64,
    /// Free-form metadata (mode, loss, config name, ...).
    pub meta: Value,
}

impl Checkpoint {
    pub fn new(theta: Vec<f32>, iter: u64) -> Checkpoint {
        Checkpoint {
            theta,
            iter,
            meta: Value::empty_table(),
        }
    }

    pub fn with_meta(mut self, key: &str, v: Value) -> Checkpoint {
        self.meta.set(key, v).expect("meta is a table");
        self
    }

    /// Serialize to a writer.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&self.iter.to_le_bytes())?;
        let meta = json::to_string(&self.meta);
        w.write_all(&(meta.len() as u64).to_le_bytes())?;
        w.write_all(meta.as_bytes())?;
        w.write_all(&(self.theta.len() as u64).to_le_bytes())?;
        // f32 slab, little-endian.
        let mut buf = Vec::with_capacity(self.theta.len() * 4);
        for v in &self.theta {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
        Ok(())
    }

    /// Deserialize from a reader.
    pub fn read_from(r: &mut impl Read) -> Result<Checkpoint> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(Error::other("not a hybriditer checkpoint (bad magic)"));
        }
        let mut u64buf = [0u8; 8];
        r.read_exact(&mut u64buf)?;
        let iter = u64::from_le_bytes(u64buf);
        r.read_exact(&mut u64buf)?;
        let meta_len = u64::from_le_bytes(u64buf) as usize;
        if meta_len > 64 << 20 {
            return Err(Error::other("checkpoint metadata unreasonably large"));
        }
        let mut meta_bytes = vec![0u8; meta_len];
        r.read_exact(&mut meta_bytes)?;
        let meta = json::parse(
            std::str::from_utf8(&meta_bytes)
                .map_err(|_| Error::other("checkpoint metadata is not UTF-8"))?,
        )?;
        r.read_exact(&mut u64buf)?;
        let n = u64::from_le_bytes(u64buf) as usize;
        if n > (8usize << 30) / 4 {
            return Err(Error::other("checkpoint parameter count unreasonably large"));
        }
        let mut slab = vec![0u8; n * 4];
        r.read_exact(&mut slab)?;
        let theta = slab
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Checkpoint { theta, iter, meta })
    }

    /// Save to a file (creating parent dirs).
    ///
    /// The write is atomic: the bytes go to a temp file in the same
    /// directory, which is renamed into place only after a successful
    /// flush — a crash mid-save leaves any previous checkpoint intact
    /// instead of a truncated, unloadable one.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file_name = path
            .file_name()
            .ok_or_else(|| Error::other("checkpoint path has no file name"))?
            .to_os_string();
        file_name.push(".tmp");
        let tmp = path.with_file_name(file_name);
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        let write = self.write_to(&mut f).and_then(|()| f.flush().map_err(Error::from));
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp);
            return Err(e);
        }
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        Checkpoint::read_from(&mut f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir()
            .join("hybriditer_ckpt_test")
            .join(name)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let mut rng = Pcg64::seeded(1);
        let mut theta = vec![0.0f32; 1000];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        let ckpt = Checkpoint::new(theta.clone(), 42)
            .with_meta("mode", Value::Str("hybrid".into()))
            .with_meta("loss", Value::Float(0.125));
        let path = tmp("a.ckpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, ckpt);
        assert_eq!(back.iter, 42);
        assert_eq!(back.meta.req_str("mode").unwrap(), "hybrid");
        assert_eq!(back.theta, theta);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn exact_bit_preservation_of_specials() {
        let theta = vec![0.0f32, -0.0, f32::MIN_POSITIVE, 1e-45, 3.4e38, -1.5];
        let ckpt = Checkpoint::new(theta.clone(), 0);
        let path = tmp("b.ckpt");
        ckpt.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        for (a, b) in back.theta.iter().zip(&theta) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("c.ckpt");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn rejects_truncated() {
        let ckpt = Checkpoint::new(vec![1.0; 100], 7);
        let mut buf = Vec::new();
        ckpt.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() - 10);
        let mut cur = std::io::Cursor::new(buf);
        assert!(Checkpoint::read_from(&mut cur).is_err());
    }

    #[test]
    fn save_survives_truncation_of_a_previous_save() {
        // A crash mid-save must not corrupt the checkpoint on disk.
        // Simulate the old non-atomic failure mode by truncating the
        // *temp* artifact a crashed writer would leave behind, then
        // verify the real path still loads the earlier save intact.
        let path = tmp("d.ckpt");
        let first = Checkpoint::new(vec![1.0; 50], 1);
        first.save(&path).unwrap();
        // A later save that dies mid-write leaves only a stray temp
        // file; the target is untouched until the atomic rename.
        let second = Checkpoint::new(vec![2.0; 80], 2);
        let mut buf = Vec::new();
        second.write_to(&mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        let tmp_path = path.with_file_name("d.ckpt.tmp");
        std::fs::write(&tmp_path, &buf).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back, first);
        // The interrupted temp file itself is rejected, not silently
        // mistaken for a checkpoint.
        assert!(Checkpoint::load(&tmp_path).is_err());
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&tmp_path).unwrap();
    }

    #[test]
    fn save_leaves_no_temp_file_behind() {
        let path = tmp("e.ckpt");
        Checkpoint::new(vec![3.0; 10], 3).save(&path).unwrap();
        assert!(path.exists());
        assert!(!path.with_file_name("e.ckpt.tmp").exists());
        // Overwriting an existing checkpoint goes through the same
        // rename and replaces it completely.
        Checkpoint::new(vec![4.0; 20], 4).save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.iter, 4);
        assert_eq!(back.theta.len(), 20);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_theta_ok() {
        let ckpt = Checkpoint::new(vec![], 0);
        let mut buf = Vec::new();
        ckpt.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(&mut std::io::Cursor::new(buf)).unwrap();
        assert!(back.theta.is_empty());
    }
}
