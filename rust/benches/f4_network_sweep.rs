//! F4 — iteration efficiency vs network unreliability (drop rate × γ).
//!
//! The paper's hybrid barrier tolerates *compute-side* stragglers; this
//! sweep asks how it behaves when the network itself loses messages
//! (arXiv:1810.07766's regime).  For each (drop probability, γ) cell we
//! train to a fixed convergence target — 90% of the initial→optimal loss
//! gap closed — and report iterations- and virtual-time-to-target.
//!
//! The 15 (drop × γ) cells run concurrently on the sweep engine
//! (`--threads N` overrides the pool size); every cell shares the cached
//! problem, so generation's Cholesky solve happens once.
//!
//! Expected reading: drops act like extra abandonment, so
//! iterations-to-target inflate with the drop rate, and a mid-sized γ
//! (which already plans for missing replies) degrades more gracefully
//! than γ = M (where every lost reply shrinks the barrier below full
//! membership).  The γ=12 drop-sweep headline lands in
//! `results/BENCH_f4_network.json` as a trajectory point.

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, RunReport, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::net::NetSpec;
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;

const M: usize = 16;
const ITERS: u64 = 600;
const SEEDS: u64 = 2;
const GAP_FRACTION: f64 = 0.1; // target: 90% of the loss gap closed

fn run_once(problem: &KrrProblem, gamma: usize, drop: f64, seed: u64) -> RunReport {
    let cluster = ClusterSpec {
        workers: M,
        base_compute: 0.01,
        delay: DelayModel::LogNormal { mu: -4.0, sigma: 0.5 },
        seed: 70 + seed,
        ..ClusterSpec::default()
    }
    .with_net(if drop > 0.0 { NetSpec::lossy(drop) } else { NetSpec::ideal() });
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma },
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(problem.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(ITERS);
    let mut pool = problem.native_pool();
    sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
}

struct Cell {
    drop: f64,
    gamma: usize,
    /// Mean iterations to target (unreached seeds count as `ITERS`).
    iters: f64,
    time: f64,
    reached: u64,
    final_loss: f64,
    dropped: u64,
    duplicated: u64,
    abandon_pct: f64,
}

fn main() {
    let engine = SweepEngine::from_env();
    println!(
        "F4: drop rate × gamma network sweep — M={M}, {ITERS} iters cap, {SEEDS} seeds, \
         target = {:.0}% of loss gap closed",
        (1.0 - GAP_FRACTION) * 100.0
    );
    println!("sweep pool: {} threads\n", engine.threads());
    let spec = KrrProblemSpec::small().with_machines(M);
    let problem = engine.cache().get(&spec);

    // The clean γ=M reference defines the absolute loss target.
    let reference = run_once(&problem, M, 0.0, 0);
    let start_loss = reference
        .recorder
        .rows()
        .first()
        .map(|r| r.loss)
        .expect("reference run recorded no rows");
    let target = problem.loss_star + (start_loss - problem.loss_star) * GAP_FRACTION;
    println!(
        "loss: start {start_loss:.6}, optimum {:.6}, target {target:.6}\n",
        problem.loss_star
    );

    let mut table = Table::new(
        "F4 iterations-to-target vs drop rate",
        &[
            "drop_prob",
            "gamma",
            "iters_to_target",
            "time_to_target_s",
            "reached",
            "final_loss",
            "net_dropped",
            "net_dup",
            "abandon_pct",
        ],
    );
    let mut points: Vec<(f64, usize)> = Vec::new();
    for &drop in &[0.0, 0.05, 0.1, 0.2, 0.3] {
        for &gamma in &[M / 2, M * 3 / 4, M] {
            points.push((drop, gamma));
        }
    }
    let cells: Vec<Cell> = engine.run(&points, |cache, &(drop, gamma)| {
        let problem = cache.get(&spec);
        let mut iters_sum = 0.0;
        let mut time_sum = 0.0;
        let mut reached = 0u64;
        let mut final_loss = 0.0;
        let mut dropped = 0u64;
        let mut duplicated = 0u64;
        let mut abandon = 0.0;
        for seed in 0..SEEDS {
            let rep = run_once(&problem, gamma, drop, seed);
            match rep.recorder.iters_to_loss(target) {
                Some(it) => {
                    iters_sum += it as f64;
                    time_sum += rep.recorder.time_to_loss(target).unwrap_or(0.0);
                    reached += 1;
                }
                None => {
                    iters_sum += ITERS as f64;
                    time_sum += rep.total_time();
                }
            }
            final_loss += rep.final_loss();
            dropped += rep.net.dropped;
            duplicated += rep.net.duplicated;
            abandon += rep.abandon_rate();
        }
        let n = SEEDS as f64;
        Cell {
            drop,
            gamma,
            iters: iters_sum / n,
            time: time_sum / n,
            reached,
            final_loss: final_loss / n,
            dropped,
            duplicated,
            abandon_pct: abandon / n * 100.0,
        }
    });
    for cell in &cells {
        table.row(vec![
            f(cell.drop, 2),
            cell.gamma.to_string(),
            f(cell.iters, 1),
            f(cell.time, 3),
            format!("{}/{}", cell.reached, SEEDS),
            format!("{:.6}", cell.final_loss),
            cell.dropped.to_string(),
            cell.duplicated.to_string(),
            f(cell.abandon_pct, 1),
        ]);
    }
    table.print();
    table.save_csv("f4_network_sweep").unwrap();

    // Headline trajectory point: how much a 10% drop rate inflates
    // iterations-to-target at γ = 3M/4.
    let g_ref = M * 3 / 4;
    let clean = cells
        .iter()
        .find(|c| c.drop == 0.0 && c.gamma == g_ref)
        .expect("clean cell");
    let lossy = cells
        .iter()
        .find(|c| c.drop == 0.1 && c.gamma == g_ref)
        .expect("lossy cell");
    let inflation = if clean.iters > 0.0 { lossy.iters / clean.iters } else { f64::NAN };
    let points_json: Vec<String> = cells
        .iter()
        .map(|c| {
            format!(
                "    {{\"drop_prob\": {}, \"gamma\": {}, \"iters_to_target\": {:.1}, \
                 \"time_to_target_s\": {:.4}, \"reached\": {}, \"final_loss\": {:.6}}}",
                c.drop, c.gamma, c.iters, c.time, c.reached, c.final_loss
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"f4_network\",\n  \"machines\": {M},\n  \"iters_cap\": {ITERS},\n  \
         \"seeds\": {SEEDS},\n  \"target_loss\": {target:.6},\n  \"headline\": {{\n    \
         \"gamma\": {g_ref},\n    \"clean_iters_to_target\": {:.1},\n    \
         \"drop10_iters_to_target\": {:.1},\n    \"iteration_inflation\": {inflation:.3}\n  }},\n  \
         \"points\": [\n{}\n  ]\n}}\n",
        clean.iters,
        lossy.iters,
        points_json.join(",\n")
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/BENCH_f4_network.json", json).unwrap();
    println!(
        "\nheadline: gamma={g_ref} iters-to-target {:.1} -> {:.1} at 10% drop (x{inflation:.2})",
        clean.iters, lossy.iters
    );
    println!("trajectory point -> results/BENCH_f4_network.json");

    println!(
        "\nReading: message loss inflates iterations-to-target roughly like\n\
         extra abandonment — γ below M absorbs moderate loss (the barrier\n\
         already plans for missing replies), while γ = M feels every drop.\n\
         Duplicates are absorbed by the barrier's admission dedup at no\n\
         accuracy cost."
    );
}
