//! Virtual-timing driver: discrete-event simulation of the whole cluster.
//!
//! Latencies are *bookkept*, never slept, so a 10,000-iteration straggler
//! sweep runs in seconds and is bit-for-bit reproducible.  Semantics are
//! shared with the threaded runtime ([`crate::worker`]): the same
//! [`PartialBarrier`] closes iterations, the same aggregator/optimizer
//! update θ, and which results get abandoned depends only on the sampled
//! latency order — exactly what a physical cluster's barrier sees.
//!
//! BSP failure recovery follows the Hadoop model the paper argues against
//! ("they have to calculate it again when failure occurs"): a missing shard
//! is detected after a timeout and re-executed on a healthy node, with
//! permanent reassignment when the owner crashed for good — so BSP keeps
//! *correctness* but pays latency, while the hybrid barrier simply keeps
//! going (the paper's fault-tolerance claim, F2).
//!
//! **Elastic membership**: a [`ClusterSpec::elastic`] schedule applies
//! deterministic leave/join events at iteration boundaries, and with
//! [`ClusterSpec::rebalance_every`] `> 0` the coordinator re-plans shard
//! ownership over the live set ([`crate::data::plan_rebalance`]) whenever
//! the membership epoch changed (and on the fixed cadence).  A worker that
//! owns k shards computes them serially (latency ×k) and contributes one
//! gradient per shard, aggregated in ascending shard order — exactly the
//! order the threaded runtime uses, so the two drivers stay decision- and
//! trajectory-equivalent (see `tests/parity_drivers.rs`).
//!
//! **Unreliable network**: every coordinator↔worker roundtrip routes
//! through [`crate::net::VirtualTransport`] — the `Work` broadcast down,
//! the `Grad` reply back up.  A [`crate::net::NetSpec`] realizes each
//! message's fate (drop, delay, duplicate; scripted partitions silence
//! whole windows) as a pure function of `(seed, worker, iteration)`, so
//! the threaded runtime realizes the *same* fates (see
//! [`crate::net::NetShim`]).  The [`PartialBarrier`] thereby finally sees
//! a realistic source of duplicate and late arrivals.  `NetSpec::ideal()`
//! — the default — bypasses all sampling and reproduces the pre-transport
//! admission sequence bit for bit.

use crate::cluster::{ClusterSpec, ElasticKind, ElasticRuntime, Membership};
use crate::coordinator::aggregator::{aggregate_iter, Contribution};
use crate::coordinator::barrier::PartialBarrier;
use crate::coordinator::convergence::{ConvergenceTracker, RunStatus};
use crate::coordinator::estimator::AdaptiveEstimator;
use crate::coordinator::estimator::EstimatorParams;
use crate::coordinator::{BspRecovery, RunConfig, RunReport, SyncMode};
use crate::data::ComputePool;
use crate::math::vec_ops;
use crate::metrics::{IterRow, Recorder};
use crate::net::{NetStats, Transport, VirtualTransport};
use crate::straggler::{FailureEvent, FailureState};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Problem-specific evaluation callbacks (exact holdout loss, ‖θ−θ*‖).
pub trait EvalHooks {
    fn hook_eval_loss(&self, theta: &[f32]) -> Option<f64> {
        let _ = theta;
        None
    }
    fn hook_theta_err(&self, theta: &[f32]) -> Option<f64> {
        let _ = theta;
        None
    }
}

/// No evaluation.
pub struct NoEval;
impl EvalHooks for NoEval {}

impl EvalHooks for crate::data::KrrProblem {
    fn hook_eval_loss(&self, theta: &[f32]) -> Option<f64> {
        Some(crate::data::KrrProblem::eval_loss(self, theta))
    }
    fn hook_theta_err(&self, theta: &[f32]) -> Option<f64> {
        Some(crate::data::KrrProblem::theta_err(self, theta))
    }
}

/// Run a full experiment in virtual time.
pub fn run_virtual(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
) -> Result<RunReport> {
    let driver_start = std::time::Instant::now();
    let m = pool.n_workers();
    if m != cluster.workers {
        return Err(Error::Cluster(format!(
            "pool has {m} workers, cluster spec says {}",
            cluster.workers
        )));
    }
    crate::coordinator::validate_elastic(cluster, &cfg.mode)?;
    if cfg.mode.is_async() {
        return run_async(pool, cluster, cfg, hooks, driver_start);
    }
    run_sync(pool, cluster, cfg, hooks, driver_start)
}

// ---------------------------------------------------------------------
// Synchronous modes (BSP / hybrid family)
// ---------------------------------------------------------------------

/// Slab of reusable [`crate::data::GradResult`] slots: `clear()` resets the
/// cursor without dropping the gradient buffers, `next()` hands out the
/// next slot (the slab grows only until its high-water mark is reached, so
/// steady-state iterations recycle the same allocations).
struct GradArena {
    slots: Vec<crate::data::GradResult>,
    len: usize,
}

impl GradArena {
    fn new() -> GradArena {
        GradArena { slots: Vec::new(), len: 0 }
    }

    fn clear(&mut self) {
        self.len = 0;
    }

    fn next(&mut self) -> &mut crate::data::GradResult {
        if self.len == self.slots.len() {
            self.slots.push(crate::data::GradResult::empty());
        }
        self.len += 1;
        &mut self.slots[self.len - 1]
    }

    fn results(&self) -> &[crate::data::GradResult] {
        &self.slots[..self.len]
    }
}

/// Per-iteration scratch the sync driver reuses across iterations.  Every
/// buffer the loop needs lives here and is cleared (capacity kept) rather
/// than reallocated, so a steady-state virtual iteration performs **zero**
/// heap allocations after warmup — asserted by `tests/alloc_regression.rs`.
/// Pure buffer reuse: the computed values are bit-identical to the
/// allocate-per-iteration seed driver (see `tests/parity_drivers.rs`).
struct IterScratch {
    /// Per-worker failure events this iteration.
    events: Vec<FailureEvent>,
    /// Per-worker response latency (∞ = no response).
    latency: Vec<f64>,
    /// Workers that respond this iteration.
    responders: Vec<usize>,
    /// Per-worker owned-shard lists (ownership snapshot).
    assignment: Vec<Vec<usize>>,
    /// Shards admitted by the barrier, ascending.
    included_shards: Vec<usize>,
    /// Workers admitted by the barrier.
    included_workers: Vec<usize>,
    /// Workers whose primary reply was delivered.
    arrived_workers: Vec<usize>,
    /// BSP: per-worker delivery mask.
    delivered: Vec<bool>,
    /// BSP: shards with no delivered owner.
    missing: Vec<usize>,
    /// Reuse ablation: arrived-but-abandoned workers, ascending.
    late: Vec<usize>,
    /// The partial barrier, `reset()` per iteration.
    barrier: PartialBarrier,
    /// This iteration's included gradients.
    grads: GradArena,
    /// Staleness-1 gradients carried into the next iteration.
    carryover: GradArena,
}

impl IterScratch {
    fn new(m: usize) -> IterScratch {
        IterScratch {
            events: vec![FailureEvent::Healthy; m],
            latency: vec![f64::INFINITY; m],
            responders: Vec::with_capacity(m),
            assignment: Vec::new(),
            included_shards: Vec::with_capacity(m),
            included_workers: Vec::with_capacity(m),
            arrived_workers: Vec::with_capacity(m),
            delivered: vec![false; m],
            missing: Vec::with_capacity(m),
            late: Vec::with_capacity(m),
            barrier: PartialBarrier::new(0, m, 1),
            grads: GradArena::new(),
            carryover: GradArena::new(),
        }
    }
}

fn run_sync(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
    driver_start: std::time::Instant,
) -> Result<RunReport> {
    let m = pool.n_workers();
    let dim = pool.dim();
    let profiles = cluster.profiles();
    let n_total: usize = (0..m).map(|w| pool.shard_examples(w)).sum();
    let zeta = pool.shard_examples(0);

    let mut theta = cfg
        .init_theta
        .clone()
        .unwrap_or_else(|| vec![0.0f32; dim]);
    if theta.len() != dim {
        return Err(Error::Shape(format!(
            "init_theta has {} elements, problem dim is {dim}",
            theta.len()
        )));
    }

    let mut gamma = cfg.mode.initial_gamma(n_total, zeta, m)?;
    let mut adaptive = match cfg.mode {
        SyncMode::HybridAdaptive { alpha, xi, window } => Some((
            AdaptiveEstimator::new(n_total, zeta, m, EstimatorParams { alpha, xi }),
            window,
        )),
        _ => None,
    };

    let mut seed_rng = Pcg64::new(cluster.seed, 0x51D);
    let mut delay_rngs: Vec<Pcg64> = (0..m).map(|w| seed_rng.split(w as u64)).collect();
    let mut fail_rngs: Vec<Pcg64> =
        (0..m).map(|w| seed_rng.split(1000 + w as u64)).collect();
    let mut fstates: Vec<FailureState> = profiles
        .iter()
        .map(|p| FailureState::new(p.failure.clone()))
        .collect();
    let mut membership = Membership::new(m);

    // Shard ownership + rebalance state, shared logic with the threaded
    // driver.  BSP-retry's permanent reassignment mutates the map directly.
    let mut elastic = ElasticRuntime::new(&membership);
    // Workers evicted by a scheduled Leave.  Tracked separately from
    // FailureState so a FailureModel with `rejoin_after` cannot auto-revive
    // a scheduled leaver before its scheduled Join (the threaded driver's
    // master-side eviction has the same semantics).
    let mut evicted = vec![false; m];

    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut agg = vec![0.0f32; dim];
    let mut now = 0.0f64;
    let mut status = RunStatus::Completed;
    // All coordinator↔worker traffic goes through the transport; with an
    // ideal NetSpec it is a zero-perturbation passthrough.
    let mut net = VirtualTransport::new(cluster.net.clone(), cluster.seed);
    // Hybrid-reuse ablation: abandoned results computed at θ_t arrive during
    // iteration t+1 and are folded in with staleness 1 (aggregator-weighted).
    let reuse_late = matches!(
        cfg.aggregator,
        crate::coordinator::AggregatorKind::StalenessDamped { .. }
    );
    // Every per-iteration buffer lives in this arena and is reused across
    // iterations: zero steady-state allocations (tests/alloc_regression.rs).
    let mut scratch = IterScratch::new(m);

    'iters: for iter in 0..cfg.stop.max_iters {
        // Split the scratch into disjoint &mut locals so the loop body
        // reads like the original allocate-per-iteration code.
        let IterScratch {
            events,
            latency,
            responders,
            assignment,
            included_shards,
            included_workers,
            arrived_workers,
            delivered,
            missing,
            late,
            barrier,
            grads,
            carryover,
        } = &mut scratch;
        // --- 0. elastic membership events & shard rebalancing ----------
        // Scheduled leave/join events land exactly at this boundary, in
        // schedule order (a leave@k followed by join@k nets out alive).
        let rebalanced = elastic.at_boundary(
            iter,
            &cluster.elastic,
            cluster.rebalance_every,
            &mut membership,
            |ev| {
                match ev.kind {
                    ElasticKind::Leave => {
                        evicted[ev.worker] = true;
                        fstates[ev.worker].force_crash(iter);
                    }
                    ElasticKind::Join => {
                        evicted[ev.worker] = false;
                        fstates[ev.worker].force_rejoin();
                    }
                }
                true
            },
        )?;
        if rebalanced {
            log::debug!("iter {iter}: shard ownership rebalanced");
        }

        // Snapshot the assignment once per iteration (O(shards)); it only
        // changes at boundaries, except for BSP-retry's mid-iteration
        // reassignment, which reads the live map directly below.
        elastic.ownership.grouped_into(assignment);

        // --- 1. failure events & responder latencies -------------------
        for w in 0..m {
            latency[w] = f64::INFINITY;
            if evicted[w] {
                // Scheduled eviction: no failure-state step (so
                // `rejoin_after` cannot revive it early), no response.
                events[w] = FailureEvent::Down;
                continue;
            }
            let ev = fstates[w].step(iter, &mut fail_rngs[w]);
            membership.observe(w, ev);
            events[w] = ev;
            if matches!(ev, FailureEvent::Healthy | FailureEvent::Rejoined) {
                // Serial execution of owned shards; a worker that briefly
                // owns no shards still reports (one base heartbeat),
                // matching the threaded slave's `shards.len().max(1)`.
                latency[w] = profiles[w].sample_latency(&mut delay_rngs[w])
                    * assignment[w].len().max(1) as f64;
            }
        }
        responders.clear();
        responders.extend((0..m).filter(|&w| latency[w].is_finite()));
        if membership.alive() == 0 {
            status = RunStatus::ClusterDead { iter };
            break;
        }
        if responders.is_empty() {
            // Everyone transiently dropped: burn a detection window.
            now += cluster.base_compute.max(1e-6);
            continue;
        }

        // --- 2. transport + barrier: which shards contribute, latency ---
        // Every responder's roundtrip goes through the transport: the Work
        // broadcast down, `latency[w]` of compute, the Grad reply up.  The
        // NetSpec realizes drops / delays / duplicates per message.
        let stats_iter_start = net.stats();
        for &w in responders.iter() {
            net.send_roundtrip(w, iter, latency[w]);
        }
        included_shards.clear();
        included_workers.clear();
        // Workers whose primary reply reached the coordinator (delivered,
        // whether or not the barrier admitted it).
        arrived_workers.clear();
        let mut iter_abandoned = 0usize;
        let mut iter_stale = 0usize;
        let iter_latency: f64;
        match (&cfg.mode, gamma) {
            (SyncMode::Bsp, _) => {
                delivered.fill(false);
                let mut last_arrival = 0.0f64;
                while let Some(d) = net.poll() {
                    if !d.duplicate {
                        delivered[d.worker] = true;
                        arrived_workers.push(d.worker);
                    }
                    last_arrival = last_arrival.max(d.at);
                }
                // A shard is missing if its owner is down *or* its reply
                // was lost in the network — BSP cannot tell the two apart.
                missing.clear();
                for s in 0..m {
                    let o = elastic.ownership.owner(s);
                    if !(matches!(events[o], FailureEvent::Healthy | FailureEvent::Rejoined)
                        && delivered[o])
                    {
                        missing.push(s);
                    }
                }
                if !missing.is_empty() {
                    match cfg.bsp_recovery {
                        BspRecovery::Stall => {
                            status = RunStatus::Stalled { iter };
                            break 'iters;
                        }
                        BspRecovery::Retry { detect_timeout } => {
                            // Reassign permanently-dead owners' shards.
                            for &s in missing.iter() {
                                let o = elastic.ownership.owner(s);
                                if fstates[o].is_down() {
                                    // least-loaded alive worker takes over
                                    let new_o = (0..m)
                                        .filter(|&w| !fstates[w].is_down())
                                        .min_by_key(|&w| elastic.ownership.load(w))
                                        .ok_or_else(|| {
                                            Error::Cluster(
                                                "no alive worker for reassignment".into(),
                                            )
                                        })?;
                                    elastic.ownership.reassign(s, new_o);
                                }
                            }
                            // Every shard contributes; stragglers pay
                            // detect+retry (the retry itself is assumed to
                            // traverse a clean path — one retransmission
                            // suffices in this model).
                            let mut retry_max = 0.0f64;
                            for &s in missing.iter() {
                                let o = elastic.ownership.owner(s);
                                let retry_lat = if latency[o].is_finite() {
                                    latency[o]
                                } else {
                                    profiles[o].base_compute * elastic.ownership.load(o) as f64
                                };
                                retry_max = retry_max.max(detect_timeout + retry_lat);
                            }
                            included_shards.extend(0..m);
                            iter_latency = last_arrival.max(retry_max);
                        }
                    }
                } else {
                    included_shards.extend(0..m);
                    iter_latency = last_arrival;
                }
            }
            (_, Some(g)) => {
                // Hybrid family: the first γ_eff *delivered* replies close
                // the barrier; everything later — and every duplicate — is
                // abandoned, exactly what a physical barrier would see.
                let deliverable = net.deliverable();
                if deliverable == 0 {
                    // Every reply dropped or partitioned away: burn a
                    // detection window, like the all-transient-drop case.
                    now += cluster.base_compute.max(1e-6);
                    continue;
                }
                let g_eff = g.min(deliverable);
                barrier.reset(iter, g_eff);
                let mut close_time = 0.0f64;
                while let Some(d) = net.poll() {
                    if !d.duplicate {
                        arrived_workers.push(d.worker);
                    }
                    match barrier.offer(d.worker, d.iter) {
                        crate::coordinator::barrier::Admission::Included
                        | crate::coordinator::barrier::Admission::IncludedAndClosed => {
                            close_time = d.at;
                            included_workers.push(d.worker);
                            included_shards.extend(assignment[d.worker].iter().copied());
                            membership.record_contribution(d.worker);
                        }
                        crate::coordinator::barrier::Admission::Abandoned => {
                            membership.record_abandoned(d.worker);
                            iter_abandoned += 1;
                        }
                        crate::coordinator::barrier::Admission::Stale => {
                            membership.record_abandoned(d.worker);
                            iter_stale += 1;
                        }
                    }
                }
                iter_latency = close_time;
                // Aggregate in shard-index order: f32 summation order is
                // then independent of arrival order (γ=M reproduces BSP
                // bit-for-bit; see prop_gamma_m_equals_bsp) and matches
                // the threaded runtime's order.
                included_shards.sort_unstable();
            }
            (mode, None) => {
                return Err(Error::Config(format!(
                    "mode {} has no gamma in sync driver",
                    mode.name()
                )))
            }
        }
        if matches!(cfg.mode, SyncMode::Bsp) {
            included_workers.clear();
            included_workers.extend_from_slice(responders);
            for &w in responders.iter() {
                membership.record_contribution(w);
            }
        }

        if included_shards.is_empty() {
            // Only possible transiently under elastic churn: the γ slots
            // were all taken by zero-shard workers.  Mirror the threaded
            // driver (worker/mod.rs): no update, no convergence
            // observation — just advance the clock.
            carryover.clear();
            now += iter_latency + cluster.master_overhead;
            continue;
        }

        // --- 3. compute included gradients ------------------------------
        // Gradients land in reusable arena slots (`grad_into`): the fused
        // kernel writes into last iteration's buffers, so the steady state
        // allocates nothing.
        grads.clear();
        for &s in included_shards.iter() {
            pool.grad_into(s, &theta, iter, grads.next())?;
        }
        aggregate_iter(
            cfg.aggregator,
            grads
                .results()
                .iter()
                .map(|g| Contribution { grad: &g.grad, examples: g.examples, staleness: 0 })
                .chain(carryover.results().iter().map(|g| Contribution {
                    grad: &g.grad,
                    examples: g.examples,
                    staleness: 1,
                })),
            &mut agg,
        );
        let grad_norm = vec_ops::norm2(&agg);

        // Adaptive γ: observe scatter, re-estimate per window.
        if let Some((est, window)) = adaptive.as_mut() {
            est.observe_results(grads.results());
            if *window > 0 && (iter + 1) % *window == 0 {
                let g_new = est.gamma()?;
                if Some(g_new) != gamma {
                    log::debug!("adaptive gamma: {:?} -> {}", gamma, g_new);
                    gamma = Some(g_new);
                }
                est.reset_window();
            }
        }

        // Training-loss estimate at θ_t from the included shards.
        let loss_sum: f64 = grads.results().iter().filter_map(|g| g.loss_sum).sum();
        let loss_examples: usize = grads
            .results()
            .iter()
            .filter(|g| g.loss_sum.is_some())
            .map(|g| g.examples)
            .sum();
        let loss = cfg.loss_form.assemble(loss_sum, loss_examples, &theta);

        // --- 4. update & clock -----------------------------------------
        // Reuse ablation: abandoned responders' θ_t gradients become next
        // iteration's staleness-1 carryover.  Only replies that actually
        // *arrived* qualify — a network-dropped result never reached the
        // coordinator, so there is nothing to reuse.
        carryover.clear();
        if reuse_late {
            // Ascending worker order (not arrival order) keeps the f32
            // fold order identical to the pre-transport driver.
            late.clear();
            late.extend(
                arrived_workers
                    .iter()
                    .copied()
                    .filter(|w| !included_workers.contains(w)),
            );
            late.sort_unstable();
            for &w in late.iter() {
                for &s in &assignment[w] {
                    pool.grad_into(s, &theta, iter, carryover.next())?;
                }
            }
        }
        opt.step(&mut theta, &agg, iter);
        now += iter_latency + cluster.master_overhead;

        // --- 5. record / evaluate / stop --------------------------------
        let do_eval = cfg.eval_every > 0 && iter % cfg.eval_every == 0;
        let stop = tracker.observe(iter, loss, grad_norm);
        let record = cfg.record_every > 0 && iter % cfg.record_every == 0;
        if record || do_eval || stop.is_some() {
            let (eval_loss, theta_err) = if do_eval || stop.is_some() {
                (hooks.hook_eval_loss(&theta), hooks.hook_theta_err(&theta))
            } else {
                (None, None)
            };
            let dnet = net.stats().since(&stats_iter_start);
            rec.push(IterRow {
                iter,
                time: now,
                loss,
                eval_loss,
                theta_err,
                included: included_shards.len(),
                abandoned: iter_abandoned,
                stale: iter_stale,
                dropped: dnet.dropped as usize,
                duplicated: dnet.duplicated as usize,
                alive: membership.alive(),
                gamma,
                grad_norm,
            });
        }
        if let Some(s) = stop {
            status = s;
            break;
        }
    }

    Ok(RunReport {
        recorder: rec,
        theta,
        status,
        gamma,
        mode_name: cfg.mode.name(),
        total_contributions: membership.total_contributed(),
        total_abandoned: membership.total_abandoned(),
        crashes: membership.crashes(),
        rejoins: membership.rejoins(),
        rebalances: elastic.rebalances(),
        net: net.stats(),
        mean_staleness: None,
        driver_secs: driver_start.elapsed().as_secs_f64(),
    })
}

// ---------------------------------------------------------------------
// Fully asynchronous baseline
// ---------------------------------------------------------------------

/// f64 ordered wrapper for the event heap.
#[derive(PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Schedule worker `w`'s next async arrival: `base + compute + net + tail`
/// on the event heap, with the roundtrip's network fate riding in the
/// entry.  A dropped roundtrip still pops (the master "detects" the loss a
/// full traversal later) but carries `delivers = false`, so the update is
/// discarded and the worker retries.  With an ideal spec no network
/// sampling happens and the arrival time degenerates to the pre-transport
/// expression bit for bit.
#[allow(clippy::too_many_arguments)]
fn schedule_async_arrival(
    heap: &mut std::collections::BinaryHeap<std::cmp::Reverse<(OrdF64, usize, bool)>>,
    base: f64,
    tail: f64,
    w: usize,
    profiles: &[crate::straggler::StragglerProfile],
    delay_rng: &mut Pcg64,
    net: &crate::net::NetSpec,
    net_ideal: bool,
    seed: u64,
    attempts: &mut [u64],
    stats: &mut NetStats,
) {
    let compute = profiles[w].sample_latency(delay_rng);
    let (delivers, net_delay) = if net_ideal {
        stats.sent += 2;
        stats.delivered += 2;
        (true, 0.0)
    } else {
        // Async applies each arrival at most once, so the duplicated copy
        // is not modelled here (`count_dup = false`); the attempt counter
        // keys the per-message realization the way `iter` does for sync.
        let r = net.realize(seed, w, attempts[w]);
        let ok = stats.count_roundtrip(&r, false);
        (ok, r.roundtrip_delay())
    };
    attempts[w] += 1;
    heap.push(std::cmp::Reverse((
        OrdF64(base + compute + net_delay + tail),
        w,
        delivers,
    )));
}

fn run_async(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
    driver_start: std::time::Instant,
) -> Result<RunReport> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let damping = match cfg.mode {
        SyncMode::Async { damping } => damping,
        _ => unreachable!("run_async requires Async mode"),
    };
    let m = pool.n_workers();
    let dim = pool.dim();
    let profiles = cluster.profiles();

    let mut theta = cfg.init_theta.clone().unwrap_or_else(|| vec![0.0f32; dim]);
    let mut seed_rng = Pcg64::new(cluster.seed, 0xA51C);
    let mut delay_rngs: Vec<Pcg64> = (0..m).map(|w| seed_rng.split(w as u64)).collect();
    let mut fail_rngs: Vec<Pcg64> = (0..m).map(|w| seed_rng.split(2000 + w as u64)).collect();
    let mut fstates: Vec<FailureState> = profiles
        .iter()
        .map(|p| FailureState::new(p.failure.clone()))
        .collect();
    let mut membership = Membership::new(m);

    // Each worker computes against the θ snapshot it was last handed.
    let mut theta_given: Vec<Vec<f32>> = (0..m).map(|_| theta.clone()).collect();
    let mut version_given = vec![0u64; m];
    let mut version = 0u64;

    let net_ideal = cluster.net.is_ideal();
    let mut net_stats = NetStats::default();
    let mut stats_at_row = NetStats::default();
    let mut attempts = vec![0u64; m];
    let mut heap: BinaryHeap<Reverse<(OrdF64, usize, bool)>> = BinaryHeap::new();
    for w in 0..m {
        schedule_async_arrival(
            &mut heap,
            0.0,
            0.0,
            w,
            &profiles,
            &mut delay_rngs[w],
            &cluster.net,
            net_ideal,
            cluster.seed,
            &mut attempts,
            &mut net_stats,
        );
    }

    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut now = 0.0;
    let mut status = RunStatus::Completed;
    let mut staleness_sum = 0.0f64;
    let mut updates = 0u64;
    let mut scaled = vec![0.0f32; dim];
    let mut loss_ema: Option<f64> = None;
    // Reusable gradient slot: the event loop's steady state allocates
    // nothing per applied update.
    let mut grad_slot = crate::data::GradResult::empty();

    while let Some(Reverse((OrdF64(t), w, delivers))) = heap.pop() {
        now = t;
        if !delivers {
            // The network lost this roundtrip: the update never reaches
            // the master; the worker retries from the same θ.
            schedule_async_arrival(
                &mut heap,
                now,
                0.0,
                w,
                &profiles,
                &mut delay_rngs[w],
                &cluster.net,
                net_ideal,
                cluster.seed,
                &mut attempts,
                &mut net_stats,
            );
            continue;
        }
        // Failure check at delivery time.
        let ev = fstates[w].step(updates, &mut fail_rngs[w]);
        membership.observe(w, ev);
        match ev {
            FailureEvent::Crashed | FailureEvent::Down => {
                if membership.alive() == 0 {
                    status = RunStatus::ClusterDead { iter: updates };
                    break;
                }
                continue; // worker drops out of the loop (no reschedule)
            }
            FailureEvent::TransientDrop => {
                // Result lost; worker retries from the same θ.
                schedule_async_arrival(
                    &mut heap,
                    now,
                    0.0,
                    w,
                    &profiles,
                    &mut delay_rngs[w],
                    &cluster.net,
                    net_ideal,
                    cluster.seed,
                    &mut attempts,
                    &mut net_stats,
                );
                membership.record_abandoned(w);
                continue;
            }
            FailureEvent::Healthy | FailureEvent::Rejoined => {}
        }

        pool.grad_into(w, &theta_given[w], updates, &mut grad_slot)?;
        let res = &grad_slot;
        let staleness = version - version_given[w];
        staleness_sum += staleness as f64;
        membership.record_contribution(w);

        // Staleness-damped application.
        let weight = if damping > 0.0 {
            (1.0 / (1.0 + staleness as f64)).powf(damping)
        } else {
            1.0
        };
        scaled.copy_from_slice(&res.grad);
        if weight != 1.0 {
            vec_ops::scale(&mut scaled, weight as f32);
        }
        opt.step(&mut theta, &scaled, updates);
        version += 1;
        updates += 1;

        // Hand the worker fresh parameters; schedule its next arrival.
        theta_given[w].copy_from_slice(&theta);
        version_given[w] = version;
        schedule_async_arrival(
            &mut heap,
            now,
            cluster.master_overhead,
            w,
            &profiles,
            &mut delay_rngs[w],
            &cluster.net,
            net_ideal,
            cluster.seed,
            &mut attempts,
            &mut net_stats,
        );

        // Loss estimate: EMA over single-shard losses (noisy but cheap).
        if let Some(ls) = res.loss_sum {
            let shard_loss = cfg.loss_form.assemble(ls, res.examples, &theta);
            loss_ema = Some(match loss_ema {
                None => shard_loss,
                Some(prev) => 0.9 * prev + 0.1 * shard_loss,
            });
        }

        // Record every `record_every × m` updates ≈ one sync-iteration.
        let iter_equiv = updates / m.max(1) as u64;
        let grad_norm = vec_ops::norm2(&scaled);
        let loss = loss_ema.unwrap_or(f64::NAN);
        let stop = tracker.observe(updates.saturating_sub(1), loss, grad_norm);
        if updates % (cfg.record_every.max(1) * m as u64) == 0 || stop.is_some() {
            let do_eval = cfg.eval_every > 0 && iter_equiv % cfg.eval_every == 0;
            let (eval_loss, theta_err) = if do_eval || stop.is_some() {
                (hooks.hook_eval_loss(&theta), hooks.hook_theta_err(&theta))
            } else {
                (None, None)
            };
            let dnet = net_stats.since(&stats_at_row);
            stats_at_row = net_stats;
            rec.push(IterRow {
                iter: updates,
                time: now,
                loss,
                eval_loss,
                theta_err,
                included: 1,
                abandoned: 0,
                stale: 0,
                dropped: dnet.dropped as usize,
                duplicated: dnet.duplicated as usize,
                alive: membership.alive(),
                gamma: None,
                grad_norm,
            });
        }
        if let Some(s) = stop {
            status = s;
            break;
        }
    }
    if heap.is_empty() && membership.alive() == 0 && status == RunStatus::Completed {
        status = RunStatus::ClusterDead { iter: updates };
    }

    let _ = now;
    Ok(RunReport {
        recorder: rec,
        theta,
        status,
        gamma: None,
        mode_name: "async",
        total_contributions: membership.total_contributed(),
        total_abandoned: membership.total_abandoned(),
        crashes: membership.crashes(),
        rejoins: membership.rejoins(),
        rebalances: 0,
        net: net_stats,
        mean_staleness: if updates > 0 {
            Some(staleness_sum / updates as f64)
        } else {
            None
        },
        driver_secs: driver_start.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{KrrProblem, KrrProblemSpec};
    use crate::optim::OptimizerKind;
    use crate::straggler::DelayModel;

    fn tiny_problem(machines: usize) -> KrrProblem {
        let spec = KrrProblemSpec {
            config: "test".into(),
            d: 4,
            l: 16,
            zeta: 64,
            machines,
            noise: 0.05,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 128,
            seed: 11,
        };
        KrrProblem::generate(&spec).unwrap()
    }

    fn base_cfg(problem: &KrrProblem) -> RunConfig {
        RunConfig {
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: crate::coordinator::LossForm::krr(problem.spec.lambda),
            eval_every: 25,
            ..RunConfig::default()
        }
    }

    #[test]
    fn bsp_converges_to_theta_star() {
        let p = tiny_problem(4);
        let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
        let cfg = base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(800);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy());
        let err = p.theta_err(&rep.theta);
        assert!(err < 1e-2, "theta_err={err}");
    }

    #[test]
    fn hybrid_converges_with_abandonment() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 5 })
            .with_iters(400);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy());
        assert!(rep.total_abandoned > 0, "no abandonment happened");
        let err = p.theta_err(&rep.theta);
        assert!(err < 5e-2, "theta_err={err}");
    }

    #[test]
    fn hybrid_is_faster_than_bsp_under_stragglers() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.5 },
            ..ClusterSpec::default()
        }
        .with_slow_tail(1, 10.0);
        let iters = 150;
        let mut pool = p.native_pool();
        let bsp = run_virtual(
            &mut pool,
            &cluster,
            &base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(iters),
            &NoEval,
        )
        .unwrap();
        let mut pool2 = p.native_pool();
        let hyb = run_virtual(
            &mut pool2,
            &cluster,
            &base_cfg(&p)
                .with_mode(SyncMode::Hybrid { gamma: 6 })
                .with_iters(iters),
            &NoEval,
        )
        .unwrap();
        assert!(
            hyb.total_time() < bsp.total_time() * 0.7,
            "hybrid {:.3}s vs bsp {:.3}s",
            hyb.total_time(),
            bsp.total_time()
        );
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let p = tiny_problem(6);
        let cluster = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(100);
        let mut pool1 = p.native_pool();
        let r1 = run_virtual(&mut pool1, &cluster, &cfg, &NoEval).unwrap();
        let mut pool2 = p.native_pool();
        let r2 = run_virtual(&mut pool2, &cluster, &cfg, &NoEval).unwrap();
        assert_eq!(r1.theta, r2.theta);
        assert_eq!(r1.total_time(), r2.total_time());
        assert_eq!(r1.total_abandoned, r2.total_abandoned);
    }

    #[test]
    fn bsp_stalls_on_crash_without_recovery() {
        let p = tiny_problem(4);
        let cluster = ClusterSpec {
            workers: 4,
            failure: crate::straggler::FailureModel {
                crash_prob: 0.05,
                transient_prob: 0.0,
                rejoin_after: None,
            },
            seed: 7,
            ..ClusterSpec::default()
        };
        let mut cfg = base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(500);
        cfg.bsp_recovery = BspRecovery::Stall;
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(matches!(rep.status, RunStatus::Stalled { .. }), "{:?}", rep.status);
    }

    #[test]
    fn hybrid_survives_crashes() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            failure: crate::straggler::FailureModel {
                crash_prob: 0.001,
                transient_prob: 0.01,
                rejoin_after: None,
            },
            seed: 13,
            ..ClusterSpec::default()
        };
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 3 })
            .with_iters(600);
        // Decay η to squeeze out the partial-gradient noise floor.
        cfg.optimizer = OptimizerKind::Sgd {
            eta: crate::optim::EtaSchedule { eta0: 1.0, decay: 0.01 },
        };
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert!(rep.crashes > 0, "no crash got injected");
        // Dead shards bias the reachable optimum away from the full-data θ*;
        // the claim under test is "keeps training through crashes".
        let err = p.theta_err(&rep.theta);
        assert!(err < 0.2, "theta_err={err}");
        let start = vec_ops::dist2(&vec![0.0; p.dim()], &p.theta_star);
        assert!(err < start * 0.1, "barely moved: {err} of {start}");
    }

    #[test]
    fn async_mode_converges() {
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() };
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Async { damping: 0.0 })
            .with_iters(1800); // updates, ≈300 sync iterations
        cfg.optimizer = OptimizerKind::sgd(0.3);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy());
        assert!(rep.mean_staleness.is_some());
        let err = p.theta_err(&rep.theta);
        assert!(err < 0.1, "theta_err={err}");
    }

    #[test]
    fn auto_gamma_resolves_from_estimator() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec { workers: 8, ..ClusterSpec::default() };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::HybridAuto { alpha: 0.05, xi: 0.05 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        let g = rep.gamma.unwrap();
        assert!((1..=8).contains(&g), "gamma={g}");
    }

    #[test]
    fn adaptive_gamma_shrinks_on_homogeneous_data() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec { workers: 8, ..ClusterSpec::default() };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::HybridAdaptive { alpha: 0.05, xi: 0.5, window: 10 })
            .with_iters(100);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        // Loose ξ + similar shards: adaptive γ should settle at 1.
        assert_eq!(rep.gamma, Some(1), "{:?}", rep.gamma);
    }

    #[test]
    fn elastic_crash_and_rejoin_converges_like_static() {
        // Acceptance: 2 of 8 workers leave at iteration 150 and rejoin at
        // 250; with rebalancing on, the elastic run must reach the same
        // loss tolerance as the fully static run.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(8);
        // Stochastic latencies rotate which γ workers close the barrier, so
        // every shard contributes over time in both runs.
        let base = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let static_cluster = base.clone();
        let elastic_cluster = base
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[3, 7], 150, 250), 1);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 5 })
            .with_iters(800);

        let mut pool1 = p.native_pool();
        let st = run_virtual(&mut pool1, &static_cluster, &cfg, &p).unwrap();
        let mut pool2 = p.native_pool();
        let el = run_virtual(&mut pool2, &elastic_cluster, &cfg, &p).unwrap();

        assert!(st.status.is_healthy());
        assert!(el.status.is_healthy(), "{:?}", el.status);
        assert_eq!(el.crashes, 2);
        assert_eq!(el.rejoins, 2);
        assert!(el.rebalances >= 2, "rebalances={}", el.rebalances);
        let err_static = p.theta_err(&st.theta);
        let err_elastic = p.theta_err(&el.theta);
        assert!(err_static < 5e-2, "static theta_err={err_static}");
        assert!(err_elastic < 5e-2, "elastic theta_err={err_elastic}");
        // Same loss tolerance: both runs end within the same band of the
        // exact optimum.
        let gap_static = st.final_loss() - p.loss_star;
        let gap_elastic = el.final_loss() - p.loss_star;
        assert!(
            gap_elastic < gap_static.abs().max(1e-4) * 10.0,
            "elastic loss gap {gap_elastic} vs static {gap_static}"
        );
    }

    #[test]
    fn elastic_rebalance_keeps_all_rows_contributing() {
        // While 2 of 6 workers are away, rebalancing must hand their shards
        // to survivors: with γ = alive count, every iteration still
        // aggregates all 6 shards.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[4, 5], 10, 30), 1);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        for row in rep.recorder.rows() {
            // γ=4 of the ≥4 alive workers cover all 6 shards during the
            // outage (each survivor owns 1-2 shards).
            if (10..30).contains(&row.iter) {
                assert_eq!(row.alive, 4, "iter {}", row.iter);
                assert_eq!(row.included, 6, "iter {}: included {}", row.iter, row.included);
            }
        }
        assert!(rep.rebalances >= 2);
    }

    #[test]
    fn elastic_without_rebalance_orphans_shards() {
        // Ablation: with rebalance_every = 0 the leavers' shards stop
        // contributing (the seed behaviour the elastic subsystem removes).
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[4, 5], 10, 40), 0);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(30);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert_eq!(rep.rebalances, 0);
        for row in rep.recorder.rows() {
            if (10..30).contains(&row.iter) {
                assert_eq!(row.included, 4, "iter {}: included {}", row.iter, row.included);
            }
        }
    }

    #[test]
    fn elastic_run_is_deterministic() {
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let cluster = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 2], 20, 45), 5);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(100);
        let mut pool1 = p.native_pool();
        let r1 = run_virtual(&mut pool1, &cluster, &cfg, &NoEval).unwrap();
        let mut pool2 = p.native_pool();
        let r2 = run_virtual(&mut pool2, &cluster, &cfg, &NoEval).unwrap();
        assert_eq!(r1.theta, r2.theta);
        assert_eq!(r1.total_abandoned, r2.total_abandoned);
        assert_eq!(r1.rebalances, r2.rebalances);
    }

    #[test]
    fn scheduled_leave_immune_to_rejoin_after_autorevive() {
        // A FailureModel with `rejoin_after` (supervisor respawn) must not
        // revive a *scheduled* leaver early: scheduled eviction is
        // master-side and ends only at the scheduled join — same semantics
        // as the threaded driver.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(4);
        let cluster = ClusterSpec {
            workers: 4,
            failure: crate::straggler::FailureModel {
                crash_prob: 0.0,
                transient_prob: 0.0,
                rejoin_after: Some(3),
            },
            ..ClusterSpec::default()
        }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&[2], 5, 15), 1);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 3 })
            .with_iters(25);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        for row in rep.recorder.rows() {
            let expect_alive = if (5..15).contains(&row.iter) { 3 } else { 4 };
            assert_eq!(
                row.alive, expect_alive,
                "iter {}: alive {} (rejoin_after revived a scheduled leaver?)",
                row.iter, row.alive
            );
        }
        assert_eq!(rep.crashes, 1);
        assert_eq!(rep.rejoins, 1);
    }

    #[test]
    fn async_mode_rejects_elastic_schedule() {
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(4);
        let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() }
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[0], 5, 10), 1);
        let cfg = base_cfg(&p).with_mode(SyncMode::Async { damping: 0.0 });
        let mut pool = p.native_pool();
        assert!(run_virtual(&mut pool, &cluster, &cfg, &NoEval).is_err());
    }

    #[test]
    fn smaller_gamma_gives_faster_iterations() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let mut times = Vec::new();
        for gamma in [2usize, 6, 8] {
            let mut pool = p.native_pool();
            let rep = run_virtual(
                &mut pool,
                &cluster,
                &base_cfg(&p)
                    .with_mode(SyncMode::Hybrid { gamma })
                    .with_iters(120),
                &NoEval,
            )
            .unwrap();
            times.push(rep.total_time());
        }
        assert!(times[0] < times[1] && times[1] < times[2], "{times:?}");
    }

    #[test]
    fn lossy_net_hybrid_converges_and_counts_drops() {
        use crate::net::NetSpec;
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 0.5 },
            ..ClusterSpec::default()
        }
        .with_net(NetSpec::lossy(0.15));
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 5 })
            .with_iters(600);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert!(rep.net.dropped > 0, "no drops at 15% loss: {:?}", rep.net);
        assert_eq!(rep.net.sent, rep.net.delivered + rep.net.dropped);
        let err = p.theta_err(&rep.theta);
        assert!(err < 5e-2, "theta_err={err}");
    }

    #[test]
    fn duplicated_replies_are_abandoned_not_double_counted() {
        use crate::net::{LinkModel, NetSpec};
        let p = tiny_problem(6);
        let net = NetSpec {
            default_link: LinkModel { dup_prob: 0.5, dup_lag: 1e-4, ..LinkModel::ideal() },
            ..NetSpec::ideal()
        };
        let base = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 0.5 },
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 6 })
            .with_iters(200);
        // γ = M and pure duplication (no drops): the included set each
        // iteration is identical to the clean run, so θ matches exactly —
        // every duplicate must land in `Abandoned`, never in the sum.
        let mut pool_clean = p.native_pool();
        let clean = run_virtual(&mut pool_clean, &base, &cfg, &NoEval).unwrap();
        let mut pool_dup = p.native_pool();
        let dup = run_virtual(&mut pool_dup, &base.clone().with_net(net), &cfg, &NoEval).unwrap();
        assert!(dup.net.duplicated > 0, "{:?}", dup.net);
        assert_eq!(dup.net.dropped, 0);
        assert_eq!(clean.theta, dup.theta, "a duplicate leaked into the aggregate");
        assert!(dup.total_abandoned >= dup.net.duplicated);
        assert_eq!(clean.total_abandoned, 0);
    }

    #[test]
    fn partition_window_suppresses_partitioned_workers() {
        use crate::net::NetSpec;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_net(NetSpec::ideal().with_partition(&[4, 5], 10, 30));
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 6 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        for row in rep.recorder.rows() {
            // During the window only 4 replies can arrive, so γ=6 clamps
            // to the deliverable 4 and the partitioned shards drop out.
            let want = if (10..30).contains(&row.iter) { 4 } else { 6 };
            assert_eq!(row.included, want, "iter {}", row.iter);
            if (10..30).contains(&row.iter) {
                assert_eq!(row.dropped, 2, "iter {}", row.iter);
            } else {
                assert_eq!(row.dropped, 0, "iter {}", row.iter);
            }
        }
        // 2 workers × 20 iterations, one Work message each.
        assert_eq!(rep.net.dropped, 40);
    }

    #[test]
    fn bsp_retry_pays_for_network_loss() {
        use crate::net::NetSpec;
        let p = tiny_problem(4);
        let mk = |net: NetSpec| {
            let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() }.with_net(net);
            let mut cfg = base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(120);
            cfg.bsp_recovery = BspRecovery::Retry { detect_timeout: 0.05 };
            let mut pool = p.native_pool();
            run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
        };
        let clean = mk(NetSpec::ideal());
        let lossy = mk(NetSpec::lossy(0.2));
        assert!(clean.status.is_healthy());
        assert!(lossy.status.is_healthy());
        // Retry keeps every shard contributing (θ identical to clean BSP)
        // but pays detection + re-execution latency for every lost reply.
        assert_eq!(clean.theta, lossy.theta);
        assert!(
            lossy.total_time() > clean.total_time() * 1.5,
            "lossy {:.3}s vs clean {:.3}s",
            lossy.total_time(),
            clean.total_time()
        );
        assert!(lossy.net.dropped > 0);
    }

    #[test]
    fn async_mode_survives_lossy_net() {
        use crate::net::NetSpec;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_net(NetSpec::lossy(0.2));
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Async { damping: 0.0 })
            .with_iters(1800);
        cfg.optimizer = OptimizerKind::sgd(0.3);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert!(rep.net.dropped > 0, "{:?}", rep.net);
        let err = p.theta_err(&rep.theta);
        assert!(err < 0.1, "theta_err={err}");
    }
}
