//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Provides the subset the examples use: [`Error`] (a boxed dynamic error
//! that any `std::error::Error` converts into), [`Result`], and the
//! [`ensure!`]/[`anyhow!`] macros.  Like the real crate, `Error` does NOT
//! implement `std::error::Error` itself (that would conflict with the
//! blanket `From` conversion).

use std::fmt;

/// Boxed dynamic error with a readable `Debug` (what `fn main() ->
/// anyhow::Result<()>` prints on failure).
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error(message.to_string().into())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(Box::new(e))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        while let Some(s) = source {
            write!(f, "\n\ncaused by: {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => { $crate::Error::msg(format!($($arg)+)) };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => { return Err($crate::anyhow!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn converts_std_errors() {
        let e: Error = io_err().into();
        assert!(format!("{e}").contains("boom"));
        assert!(format!("{e:?}").contains("boom"));
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(format!("{:?}", check(-1).unwrap_err()).contains("-1"));
    }
}
