//! Synthetic token corpus for the end-to-end LM example.
//!
//! A seeded sparse bigram Markov chain over `vocab` tokens: each token has a
//! small set of likely successors (Zipf-ish weights), so the stream has real
//! learnable structure — a trained LM's loss should drop from `ln(vocab)`
//! (uniform) toward the chain's conditional entropy, which we can compute
//! exactly for reporting.

use crate::util::rng::Pcg64;

/// Seeded bigram-chain corpus generator.
pub struct BigramCorpus {
    vocab: usize,
    /// Per-token successor lists: (next_token, cumulative_prob).
    successors: Vec<Vec<(u32, f64)>>,
}

impl BigramCorpus {
    /// Build a chain where every token has `branching` successors with
    /// Zipf(1) weights over a seeded random successor set.
    pub fn new(vocab: usize, branching: usize, seed: u64) -> BigramCorpus {
        assert!(vocab >= 2 && branching >= 1);
        let branching = branching.min(vocab - 1);
        let mut rng = Pcg64::new(seed, 0xC0_2B);
        let mut successors = Vec::with_capacity(vocab);
        // Zipf weights 1, 1/2, 1/3, ...
        let weights: Vec<f64> = (1..=branching).map(|k| 1.0 / k as f64).collect();
        let wsum: f64 = weights.iter().sum();
        for _ in 0..vocab {
            let succ = rng.sample_indices(vocab, branching);
            let mut cum = 0.0;
            let list: Vec<(u32, f64)> = succ
                .iter()
                .zip(&weights)
                .map(|(&s, &w)| {
                    cum += w / wsum;
                    (s as u32, cum)
                })
                .collect();
            successors.push(list);
        }
        BigramCorpus { vocab, successors }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Exact conditional entropy H(next | current) in nats — the loss floor
    /// an ideal bigram model reaches (assuming a uniform stationary visit
    /// distribution, which Zipf-weighted uniform successor sets are close to).
    pub fn conditional_entropy(&self) -> f64 {
        let mut h = 0.0;
        for list in &self.successors {
            let mut prev = 0.0;
            for &(_, cum) in list {
                let p = cum - prev;
                prev = cum;
                if p > 0.0 {
                    h -= p * p.ln();
                }
            }
        }
        h / self.successors.len() as f64
    }

    /// Sample a stream of `len` tokens starting from a seeded state.
    pub fn sample_stream(&self, len: usize, rng: &mut Pcg64) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = rng.below(self.vocab as u64) as u32;
        for _ in 0..len {
            out.push(cur);
            let u = rng.next_f64();
            let list = &self.successors[cur as usize];
            cur = list
                .iter()
                .find(|&&(_, cum)| u <= cum)
                .map(|&(t, _)| t)
                .unwrap_or(list.last().unwrap().0);
        }
        out
    }

    /// Sample a (batch, seq+1) token block as a flat i32 buffer — exactly
    /// the `tokens` input of the `lm_step_*` artifacts.
    pub fn sample_batch(&self, batch: usize, seq: usize, rng: &mut Pcg64) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let stream = self.sample_stream(seq + 1, rng);
            out.extend(stream.iter().map(|&t| t as i32));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_tokens_in_vocab() {
        let c = BigramCorpus::new(64, 4, 1);
        let mut rng = Pcg64::seeded(2);
        let s = c.sample_stream(1000, &mut rng);
        assert_eq!(s.len(), 1000);
        assert!(s.iter().all(|&t| (t as usize) < 64));
    }

    #[test]
    fn transitions_follow_chain() {
        let c = BigramCorpus::new(32, 3, 3);
        let mut rng = Pcg64::seeded(4);
        let s = c.sample_stream(5000, &mut rng);
        for w in s.windows(2) {
            let succ = &c.successors[w[0] as usize];
            assert!(succ.iter().any(|&(t, _)| t == w[1]));
        }
    }

    #[test]
    fn entropy_between_zero_and_log_branching() {
        let c = BigramCorpus::new(128, 4, 5);
        let h = c.conditional_entropy();
        assert!(h > 0.0 && h <= (4.0f64).ln() + 1e-9, "h={h}");
    }

    #[test]
    fn batch_shape() {
        let c = BigramCorpus::new(64, 4, 6);
        let mut rng = Pcg64::seeded(7);
        let b = c.sample_batch(8, 16, &mut rng);
        assert_eq!(b.len(), 8 * 17);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 64));
    }

    #[test]
    fn deterministic_given_seed() {
        let c1 = BigramCorpus::new(64, 4, 9);
        let c2 = BigramCorpus::new(64, 4, 9);
        let mut r1 = Pcg64::seeded(1);
        let mut r2 = Pcg64::seeded(1);
        assert_eq!(c1.sample_stream(100, &mut r1), c2.sample_stream(100, &mut r2));
    }
}
