"""AOT sanity: manifest.json and the HLO artifacts it indexes are mutually
consistent and match shapes.py.  Skips (rather than fails) when artifacts
haven't been built yet — `make artifacts` is the builder."""

import json
import os

import pytest

from compile.shapes import DEFAULT_KRR, DEFAULT_LM, KRR_CONFIGS, LM_CONFIGS
from compile import transformer as tf

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built (run `make artifacts`)"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_format_version(manifest):
    assert manifest["format_version"] == 1


def test_all_files_exist_and_nonempty(manifest):
    for name, e in manifest["artifacts"].items():
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), name
        assert os.path.getsize(path) > 100, name


def test_expected_krr_artifacts_present(manifest):
    arts = manifest["artifacts"]
    for cname in DEFAULT_KRR:
        for stem in (
            "krr_worker_grad", "krr_worker_grad_ref", "krr_worker_grad_loss",
            "krr_full_loss", "krr_predict", "rbf_features",
            "master_update_sgd", "master_update_momentum", "master_update_adam",
        ):
            assert f"{stem}_{cname}" in arts


def test_krr_shapes_match_config(manifest):
    arts = manifest["artifacts"]
    for cname in DEFAULT_KRR:
        c = KRR_CONFIGS[cname]
        e = arts[f"krr_worker_grad_{cname}"]
        ins = {i["name"]: i for i in e["inputs"]}
        assert ins["theta"]["shape"] == [c.l]
        assert ins["phi"]["shape"] == [c.zeta, c.l]
        assert ins["y"]["shape"] == [c.zeta]
        assert ins["lam"]["shape"] == []
        assert e["outputs"][0]["shape"] == [c.l]


def test_lm_step_io_arity(manifest):
    arts = manifest["artifacts"]
    for cname in DEFAULT_LM:
        c = LM_CONFIGS[cname]
        n_params = len(tf.param_specs(c))
        e = arts[f"lm_step_{cname}"]
        assert len(e["inputs"]) == 1 + n_params
        assert len(e["outputs"]) == 1 + n_params
        assert e["inputs"][0]["dtype"] == "i32"
        assert e["inputs"][0]["shape"] == [c.batch, c.seq + 1]
        assert e["meta"]["param_names"] == [n for n, _ in tf.param_specs(c)]


def test_lm_param_shapes_roundtrip(manifest):
    arts = manifest["artifacts"]
    for cname in DEFAULT_LM:
        c = LM_CONFIGS[cname]
        e = arts[f"lm_step_{cname}"]
        specs = dict(tf.param_specs(c))
        for i in e["inputs"][1:]:
            assert tuple(i["shape"]) == specs[i["name"]], i["name"]


def test_hlo_text_is_parseable_header(manifest):
    """Every artifact must start with an HloModule header (text format)."""
    for name, e in manifest["artifacts"].items():
        with open(os.path.join(ART, e["file"])) as f:
            head = f.read(200)
        assert head.startswith("HloModule"), name
