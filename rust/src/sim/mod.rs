//! Virtual-timing driver: discrete-event simulation of the whole cluster.
//!
//! Latencies are *bookkept*, never slept, so a 10,000-iteration straggler
//! sweep runs in seconds and is bit-for-bit reproducible.  Semantics are
//! shared with the threaded runtime ([`crate::worker`]): the same
//! [`crate::coordinator::barrier::PartialBarrier`] closes iterations, the
//! same aggregator/optimizer update θ, and which results get abandoned
//! depends only on the sampled latency order — exactly what a physical
//! cluster's barrier sees.
//!
//! # Architecture (see `docs/SIM.md`)
//!
//! Both timing modes run on **one discrete-event core**:
//!
//! * [`engine`] — the virtual-time event heap ([`engine::EventHeap`]),
//!   per-run engine state ([`engine::EngineCore`]: membership, elastic
//!   runtime, failure states, RNG streams), and the boundary event handler
//!   (scheduled elastic leave/join + shard rebalancing);
//! * [`events`] — the event taxonomy: replies (and their duplicated
//!   copies, and async loss-detection points) with a deterministic total
//!   order;
//! * `sync` — the BSP / hybrid family as a *policy* over the engine: one
//!   barrier window per iteration, with cross-iteration message
//!   reordering — a straggler's reply can out-live its window and land in
//!   a later one as [`crate::coordinator::barrier::Admission::Stale`],
//!   matching the threaded driver's stale arrivals in virtual time;
//! * `async_mode` — the fully asynchronous baseline as a policy over the
//!   same engine, now with elastic membership (leave/join at update-count
//!   boundaries), shard rebalancing, and version-tagged duplicate
//!   detection;
//! * `report` — single assembly point for [`crate::coordinator::RunReport`].
//!
//! Under [`crate::net::NetSpec::ideal`] (the default) the sync policy
//! reproduces the pre-engine lockstep driver **bit for bit** — nothing is
//! ever carried across a window — and the async policy keeps its
//! historical event sequence.  The golden tests in
//! `tests/parity_drivers.rs` pin this down.
//!
//! BSP failure recovery follows the Hadoop model the paper argues against
//! ("they have to calculate it again when failure occurs"): a missing shard
//! is detected after a timeout and re-executed on a healthy node, with
//! permanent reassignment when the owner crashed for good — so BSP keeps
//! *correctness* but pays latency, while the hybrid barrier simply keeps
//! going (the paper's fault-tolerance claim, F2).
//!
//! **Elastic membership**: a [`crate::cluster::ClusterSpec::elastic`]
//! schedule applies deterministic leave/join events at boundaries — sync
//! iterations, or update-count equivalents in async mode — through the
//! engine's boundary handler, and with
//! [`crate::cluster::ClusterSpec::rebalance_every`] `> 0` the coordinator
//! re-plans shard ownership over the live set
//! ([`crate::data::plan_rebalance`]).  A crash observed mid-run re-plans
//! *immediately inside the barrier* when rebalancing is enabled, so an
//! adopter dying in the boundary it adopted shards cannot orphan them for
//! an iteration.
//!
//! **Unreliable network**: every coordinator↔worker roundtrip routes
//! through [`crate::net::VirtualTransport`] — the `Work` broadcast down,
//! the `Grad` reply back up.  A [`crate::net::NetSpec`] realizes each
//! message's fate (drop, delay, duplicate — per direction; scripted
//! partitions silence whole windows) as a pure function of
//! `(seed, worker, iteration)`, so the threaded runtime realizes the
//! *same* fates (see [`crate::net::NetShim`]).

pub mod engine;
pub mod events;

mod async_mode;
mod report;
mod sync;

use crate::cluster::ClusterSpec;
use crate::coordinator::{RunConfig, RunReport};
use crate::data::ComputePool;
use crate::{Error, Result};

/// Problem-specific evaluation callbacks (exact holdout loss, ‖θ−θ*‖).
pub trait EvalHooks {
    fn hook_eval_loss(&self, theta: &[f32]) -> Option<f64> {
        let _ = theta;
        None
    }
    fn hook_theta_err(&self, theta: &[f32]) -> Option<f64> {
        let _ = theta;
        None
    }
}

/// No evaluation.
pub struct NoEval;
impl EvalHooks for NoEval {}

impl EvalHooks for crate::data::KrrProblem {
    fn hook_eval_loss(&self, theta: &[f32]) -> Option<f64> {
        Some(crate::data::KrrProblem::eval_loss(self, theta))
    }
    fn hook_theta_err(&self, theta: &[f32]) -> Option<f64> {
        Some(crate::data::KrrProblem::theta_err(self, theta))
    }
}

/// Run a full experiment in virtual time.
///
/// Tracing is disabled ([`crate::trace::NoopSink`]): every emission site is
/// guarded behind `sink.enabled()`, so this path allocates nothing for
/// observability and θ is bit-identical to pre-tracer builds.
///
/// Deprecated entry point: prefer [`crate::runner::Runner`] with
/// [`crate::runner::Driver::Virtual`]. This thin wrapper is kept so the
/// parity/golden suites stay byte-stable; it can never serve traffic
/// (serving mode is only exposed through `Runner`).
pub fn run_virtual(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
) -> Result<RunReport> {
    run_virtual_serving(pool, cluster, cfg, hooks, &mut crate::trace::NoopSink, None)
}

/// Run a full experiment in virtual time, recording structured trace events
/// into `sink` (see [`crate::trace`]).
///
/// Event timestamps are in virtual seconds — the same clock the event heap
/// runs on — so a [`crate::trace::JournalSink`] journal from this driver can
/// be compared against the threaded runtime's after timestamp normalization
/// (`tests/parity_drivers.rs` does exactly that).
///
/// Deprecated entry point: prefer [`crate::runner::Runner`] with
/// [`crate::runner::Runner::trace`] attached; see [`run_virtual`].
pub fn run_virtual_traced(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
    sink: &mut dyn crate::trace::TraceSink,
) -> Result<RunReport> {
    run_virtual_serving(pool, cluster, cfg, hooks, sink, None)
}

/// The one real virtual entry point: [`run_virtual_traced`] plus an
/// optional serving workload ([`crate::serve`]), reachable only through
/// [`crate::runner::Runner`]. `serve = None` is bit-for-bit the legacy
/// behaviour — the spec is threaded as an `Option` end to end, so no
/// serving code runs, allocates, or draws randomness without one.
pub(crate) fn run_virtual_serving(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
    sink: &mut dyn crate::trace::TraceSink,
    serve: Option<&crate::serve::ServeSpec>,
) -> Result<RunReport> {
    let driver_start = std::time::Instant::now();
    let m = pool.n_workers();
    if m != cluster.workers {
        return Err(Error::Cluster(format!(
            "pool has {m} workers, cluster spec says {}",
            cluster.workers
        )));
    }
    crate::coordinator::validate_elastic(cluster, &cfg.mode)?;
    cfg.recovery.validate()?;
    if cfg.mode.is_async() {
        if !matches!(cfg.recovery.policy, crate::recovery::RecoveryPolicy::Abandon) {
            return Err(Error::Config(format!(
                "recovery policy '{}' is not supported in async mode (async has \
                 no crash/rejoin barrier to recover at); use 'abandon'",
                cfg.recovery.policy.name()
            )));
        }
        return async_mode::run_async(pool, cluster, cfg, hooks, driver_start, sink, serve);
    }
    sync::run_sync(pool, cluster, cfg, hooks, driver_start, sink, serve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterSpec;
    use crate::coordinator::convergence::RunStatus;
    use crate::coordinator::{BspRecovery, SyncMode};
    use crate::data::{KrrProblem, KrrProblemSpec};
    use crate::math::vec_ops;
    use crate::optim::OptimizerKind;
    use crate::straggler::DelayModel;

    fn tiny_problem(machines: usize) -> KrrProblem {
        let spec = KrrProblemSpec {
            config: "test".into(),
            d: 4,
            l: 16,
            zeta: 64,
            machines,
            noise: 0.05,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 128,
            seed: 11,
        };
        KrrProblem::generate(&spec).unwrap()
    }

    fn base_cfg(problem: &KrrProblem) -> RunConfig {
        RunConfig {
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: crate::coordinator::LossForm::krr(problem.spec.lambda),
            eval_every: 25,
            ..RunConfig::default()
        }
    }

    #[test]
    fn bsp_converges_to_theta_star() {
        let p = tiny_problem(4);
        let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
        let cfg = base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(800);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy());
        let err = p.theta_err(&rep.theta);
        assert!(err < 1e-2, "theta_err={err}");
    }

    #[test]
    fn hybrid_converges_with_abandonment() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 5 })
            .with_iters(400);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy());
        assert!(rep.total_abandoned > 0, "no abandonment happened");
        let err = p.theta_err(&rep.theta);
        assert!(err < 5e-2, "theta_err={err}");
    }

    #[test]
    fn hybrid_is_faster_than_bsp_under_stragglers() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.5 },
            ..ClusterSpec::default()
        }
        .with_slow_tail(1, 10.0);
        let iters = 150;
        let mut pool = p.native_pool();
        let bsp = run_virtual(
            &mut pool,
            &cluster,
            &base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(iters),
            &NoEval,
        )
        .unwrap();
        let mut pool2 = p.native_pool();
        let hyb = run_virtual(
            &mut pool2,
            &cluster,
            &base_cfg(&p)
                .with_mode(SyncMode::Hybrid { gamma: 6 })
                .with_iters(iters),
            &NoEval,
        )
        .unwrap();
        assert!(
            hyb.total_time() < bsp.total_time() * 0.7,
            "hybrid {:.3}s vs bsp {:.3}s",
            hyb.total_time(),
            bsp.total_time()
        );
    }

    #[test]
    fn determinism_same_seed_same_result() {
        let p = tiny_problem(6);
        let cluster = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(100);
        let mut pool1 = p.native_pool();
        let r1 = run_virtual(&mut pool1, &cluster, &cfg, &NoEval).unwrap();
        let mut pool2 = p.native_pool();
        let r2 = run_virtual(&mut pool2, &cluster, &cfg, &NoEval).unwrap();
        assert_eq!(r1.theta, r2.theta);
        assert_eq!(r1.total_time(), r2.total_time());
        assert_eq!(r1.total_abandoned, r2.total_abandoned);
    }

    #[test]
    fn bsp_stalls_on_crash_without_recovery() {
        let p = tiny_problem(4);
        let cluster = ClusterSpec {
            workers: 4,
            failure: crate::straggler::FailureModel {
                crash_prob: 0.05,
                transient_prob: 0.0,
                rejoin_after: None,
            },
            seed: 7,
            ..ClusterSpec::default()
        };
        let mut cfg = base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(500);
        cfg.bsp_recovery = BspRecovery::Stall;
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(matches!(rep.status, RunStatus::Stalled { .. }), "{:?}", rep.status);
    }

    #[test]
    fn hybrid_survives_crashes() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            failure: crate::straggler::FailureModel {
                crash_prob: 0.001,
                transient_prob: 0.01,
                rejoin_after: None,
            },
            seed: 13,
            ..ClusterSpec::default()
        };
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 3 })
            .with_iters(600);
        // Decay η to squeeze out the partial-gradient noise floor.
        cfg.optimizer = OptimizerKind::Sgd {
            eta: crate::optim::EtaSchedule { eta0: 1.0, decay: 0.01 },
        };
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert!(rep.crashes > 0, "no crash got injected");
        // Dead shards bias the reachable optimum away from the full-data θ*;
        // the claim under test is "keeps training through crashes".
        let err = p.theta_err(&rep.theta);
        assert!(err < 0.2, "theta_err={err}");
        let start = vec_ops::dist2(&vec![0.0; p.dim()], &p.theta_star);
        assert!(err < start * 0.1, "barely moved: {err} of {start}");
    }

    #[test]
    fn async_mode_converges() {
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() };
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Async { damping: 0.0 })
            .with_iters(1800); // updates, ≈300 sync iterations
        cfg.optimizer = OptimizerKind::sgd(0.3);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy());
        assert!(rep.mean_staleness.is_some());
        let err = p.theta_err(&rep.theta);
        assert!(err < 0.1, "theta_err={err}");
    }

    #[test]
    fn auto_gamma_resolves_from_estimator() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec { workers: 8, ..ClusterSpec::default() };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::HybridAuto { alpha: 0.05, xi: 0.05 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        let g = rep.gamma.unwrap();
        assert!((1..=8).contains(&g), "gamma={g}");
    }

    #[test]
    fn adaptive_gamma_shrinks_on_homogeneous_data() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec { workers: 8, ..ClusterSpec::default() };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::HybridAdaptive { alpha: 0.05, xi: 0.5, window: 10 })
            .with_iters(100);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        // Loose ξ + similar shards: adaptive γ should settle at 1.
        assert_eq!(rep.gamma, Some(1), "{:?}", rep.gamma);
    }

    #[test]
    fn elastic_crash_and_rejoin_converges_like_static() {
        // Acceptance: 2 of 8 workers leave at iteration 150 and rejoin at
        // 250; with rebalancing on, the elastic run must reach the same
        // loss tolerance as the fully static run.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(8);
        // Stochastic latencies rotate which γ workers close the barrier, so
        // every shard contributes over time in both runs.
        let base = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let static_cluster = base.clone();
        let elastic_cluster = base
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[3, 7], 150, 250), 1);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 5 })
            .with_iters(800);

        let mut pool1 = p.native_pool();
        let st = run_virtual(&mut pool1, &static_cluster, &cfg, &p).unwrap();
        let mut pool2 = p.native_pool();
        let el = run_virtual(&mut pool2, &elastic_cluster, &cfg, &p).unwrap();

        assert!(st.status.is_healthy());
        assert!(el.status.is_healthy(), "{:?}", el.status);
        assert_eq!(el.crashes, 2);
        assert_eq!(el.rejoins, 2);
        assert!(el.rebalances >= 2, "rebalances={}", el.rebalances);
        let err_static = p.theta_err(&st.theta);
        let err_elastic = p.theta_err(&el.theta);
        assert!(err_static < 5e-2, "static theta_err={err_static}");
        assert!(err_elastic < 5e-2, "elastic theta_err={err_elastic}");
        // Same loss tolerance: both runs end within the same band of the
        // exact optimum.
        let gap_static = st.final_loss() - p.loss_star;
        let gap_elastic = el.final_loss() - p.loss_star;
        assert!(
            gap_elastic < gap_static.abs().max(1e-4) * 10.0,
            "elastic loss gap {gap_elastic} vs static {gap_static}"
        );
    }

    #[test]
    fn elastic_rebalance_keeps_all_rows_contributing() {
        // While 2 of 6 workers are away, rebalancing must hand their shards
        // to survivors: with γ = alive count, every iteration still
        // aggregates all 6 shards.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[4, 5], 10, 30), 1);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        for row in rep.recorder.rows() {
            // γ=4 of the ≥4 alive workers cover all 6 shards during the
            // outage (each survivor owns 1-2 shards).
            if (10..30).contains(&row.iter) {
                assert_eq!(row.alive, 4, "iter {}", row.iter);
                assert_eq!(row.included, 6, "iter {}: included {}", row.iter, row.included);
            }
        }
        assert!(rep.rebalances >= 2);
    }

    #[test]
    fn elastic_without_rebalance_orphans_shards() {
        // Ablation: with rebalance_every = 0 the leavers' shards stop
        // contributing (the seed behaviour the elastic subsystem removes).
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[4, 5], 10, 40), 0);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(30);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert_eq!(rep.rebalances, 0);
        for row in rep.recorder.rows() {
            if (10..30).contains(&row.iter) {
                assert_eq!(row.included, 4, "iter {}: included {}", row.iter, row.included);
            }
        }
    }

    #[test]
    fn elastic_run_is_deterministic() {
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let cluster = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            ..ClusterSpec::default()
        }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 2], 20, 45), 5);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 4 })
            .with_iters(100);
        let mut pool1 = p.native_pool();
        let r1 = run_virtual(&mut pool1, &cluster, &cfg, &NoEval).unwrap();
        let mut pool2 = p.native_pool();
        let r2 = run_virtual(&mut pool2, &cluster, &cfg, &NoEval).unwrap();
        assert_eq!(r1.theta, r2.theta);
        assert_eq!(r1.total_abandoned, r2.total_abandoned);
        assert_eq!(r1.rebalances, r2.rebalances);
    }

    #[test]
    fn scheduled_leave_immune_to_rejoin_after_autorevive() {
        // A FailureModel with `rejoin_after` (supervisor respawn) must not
        // revive a *scheduled* leaver early: scheduled eviction is
        // master-side and ends only at the scheduled join — same semantics
        // as the threaded driver.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(4);
        let cluster = ClusterSpec {
            workers: 4,
            failure: crate::straggler::FailureModel {
                crash_prob: 0.0,
                transient_prob: 0.0,
                rejoin_after: Some(3),
            },
            ..ClusterSpec::default()
        }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&[2], 5, 15), 1);
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 3 })
            .with_iters(25);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        for row in rep.recorder.rows() {
            let expect_alive = if (5..15).contains(&row.iter) { 3 } else { 4 };
            assert_eq!(
                row.alive, expect_alive,
                "iter {}: alive {} (rejoin_after revived a scheduled leaver?)",
                row.iter, row.alive
            );
        }
        assert_eq!(rep.crashes, 1);
        assert_eq!(rep.rejoins, 1);
    }

    #[test]
    fn async_mode_accepts_elastic_schedule_and_converges() {
        // The unified engine's acceptance test: the async policy takes the
        // same scripted churn the sync policy does — 2 of 8 workers leave
        // at iteration-equivalent 50 (update 400) and rejoin at 100 — with
        // rebalancing keeping every shard contributing, and still reaches
        // the static run's tolerance.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(8);
        let base = ClusterSpec { workers: 8, ..ClusterSpec::default() };
        let elastic_cluster = base
            .clone()
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[3, 7], 50, 100), 1);
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Async { damping: 0.0 })
            .with_iters(2400); // updates, ≈300 sync iterations
        cfg.optimizer = OptimizerKind::sgd(0.3);

        let mut pool1 = p.native_pool();
        let st = run_virtual(&mut pool1, &base, &cfg, &p).unwrap();
        let mut pool2 = p.native_pool();
        let el = run_virtual(&mut pool2, &elastic_cluster, &cfg, &p).unwrap();

        assert!(st.status.is_healthy(), "{:?}", st.status);
        assert!(el.status.is_healthy(), "{:?}", el.status);
        assert_eq!(el.crashes, 2);
        assert_eq!(el.rejoins, 2);
        assert!(el.rebalances >= 2, "rebalances={}", el.rebalances);
        assert!(el.mean_staleness.is_some());
        let err_static = p.theta_err(&st.theta);
        let err_elastic = p.theta_err(&el.theta);
        assert!(err_static < 0.1, "static theta_err={err_static}");
        assert!(err_elastic < 0.15, "elastic theta_err={err_elastic}");
    }

    #[test]
    fn async_detects_duplicates_version_tagged() {
        // Pure duplication (no drops, no latency): the duplicated reply
        // copies pop as events but their version tags no longer match the
        // worker's outstanding dispatch, so every one is detected and
        // discarded — the update stream, and hence θ, is bit-identical to
        // the clean run.
        use crate::net::{LinkModel, NetSpec};
        let p = tiny_problem(6);
        let base = ClusterSpec { workers: 6, ..ClusterSpec::default() };
        let dup_net = NetSpec {
            default_link: LinkModel { dup_prob: 0.5, dup_lag: 1e-4, ..LinkModel::ideal() },
            ..NetSpec::ideal()
        };
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Async { damping: 0.0 })
            .with_iters(1200);
        cfg.optimizer = OptimizerKind::sgd(0.3);

        let mut pool1 = p.native_pool();
        let clean = run_virtual(&mut pool1, &base, &cfg, &NoEval).unwrap();
        let mut pool2 = p.native_pool();
        let dup =
            run_virtual(&mut pool2, &base.clone().with_net(dup_net), &cfg, &NoEval).unwrap();

        assert!(dup.net.duplicated > 0, "{:?}", dup.net);
        assert_eq!(dup.net.dropped, 0);
        assert_eq!(clean.theta, dup.theta, "a duplicate leaked into an update");
        // Every delivered duplicate that popped before the run ended was
        // discarded (≤ one per worker may still be in flight at the end).
        assert!(dup.total_abandoned <= dup.net.duplicated);
        assert!(
            dup.total_abandoned + 6 >= dup.net.duplicated,
            "abandoned {} vs duplicated {}",
            dup.total_abandoned,
            dup.net.duplicated
        );
        assert_eq!(clean.total_abandoned, 0);
    }

    #[test]
    fn crash_during_rebalance_replans_inside_barrier() {
        // Regression (crash-during-rebalance): worker 0 crashes in the very
        // iteration boundary where it would hold shards.  With rebalancing
        // enabled the sync policy re-plans *inside* the barrier, so the
        // orphaned shard contributes in the same iteration — before the
        // fix it sat on the dead owner until the next boundary.
        let p = tiny_problem(4);
        let cluster = ClusterSpec {
            workers: 4,
            failure: crate::straggler::FailureModel {
                crash_prob: 1.0,
                transient_prob: 0.0,
                rejoin_after: None,
            },
            failure_only: vec![0],
            rebalance_every: 1,
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 3 })
            .with_iters(20);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert_eq!(rep.crashes, 1);
        assert!(rep.rebalances >= 1);
        for row in rep.recorder.rows() {
            assert_eq!(
                row.included, 4,
                "iter {}: crashed owner's shard missing from the barrier",
                row.iter
            );
        }
    }

    #[test]
    fn weighted_rebalance_cuts_iteration_latency_on_skewed_hardware() {
        // 2 of 4 workers at 0.25× capacity.  Capacity-weighted
        // apportionment hands their shards to the fast pair (2 each), so
        // the full-coverage barrier closes at 2·base instead of waiting
        // 4·base for the slow pair — same shards folded in the same order,
        // so θ is bit-identical; only *who* computes changed.
        let p = tiny_problem(4);
        let mk = |weighted: bool| {
            let cluster = ClusterSpec {
                workers: 4,
                rebalance_every: 1,
                weighted_rebalance: weighted,
                ..ClusterSpec::default()
            }
            .with_capacity_tail(2, 0.25);
            let cfg = base_cfg(&p)
                .with_mode(SyncMode::Hybrid { gamma: 4 })
                .with_iters(40);
            let mut pool = p.native_pool();
            run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
        };
        let weighted = mk(true);
        let unweighted = mk(false);
        assert!(weighted.status.is_healthy());
        assert!(unweighted.status.is_healthy());
        // The weighted planner stripped the slow pair at the first
        // boundary; the ablation kept the identity layout.
        assert_eq!(weighted.shard_owners, vec![0, 1, 0, 1]);
        assert_eq!(unweighted.shard_owners, vec![0, 1, 2, 3]);
        // Full data coverage and zero abandonment in both runs…
        for rep in [&weighted, &unweighted] {
            for row in rep.recorder.rows() {
                assert_eq!(row.included, 4, "iter {}", row.iter);
            }
            assert_eq!(rep.total_abandoned, 0);
        }
        // …so θ agrees bit-for-bit while the weighted run is ~2× faster.
        assert_eq!(weighted.theta, unweighted.theta);
        assert!(
            weighted.total_time() < unweighted.total_time() * 0.6,
            "weighted {:.3}s vs unweighted {:.3}s",
            weighted.total_time(),
            unweighted.total_time()
        );
    }

    #[test]
    fn warmup_ramp_removes_rejoin_latency_spike() {
        // 2 of 6 workers leave@10 and rejoin@20 cold (6-boundary warm-up:
        // their service time starts 7× dilated).  The legacy planner hands
        // them a level load the moment they rejoin, so the γ=M barrier
        // waits out a ~7·base straggler; the capacity-weighted planner
        // ramps their share up with the warm-up, keeping the post-join
        // iterations fast.
        use crate::cluster::ElasticSchedule;
        let p = tiny_problem(6);
        let mk = |weighted: bool| {
            let cluster = ClusterSpec {
                workers: 6,
                weighted_rebalance: weighted,
                ..ClusterSpec::default()
            }
            .with_elastic(ElasticSchedule::crash_and_rejoin(&[4, 5], 10, 20), 1)
            .with_warmup(6);
            let cfg = base_cfg(&p)
                .with_mode(SyncMode::Hybrid { gamma: 6 })
                .with_iters(35);
            let mut pool = p.native_pool();
            run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
        };
        let peak_post_join = |rep: &RunReport| -> f64 {
            let rows = rep.recorder.rows();
            let mut peak = 0.0f64;
            for pair in rows.windows(2) {
                if (20..30).contains(&pair[1].iter) {
                    peak = peak.max(pair[1].time - pair[0].time);
                }
            }
            peak
        };
        let weighted = mk(true);
        let unweighted = mk(false);
        assert!(weighted.status.is_healthy());
        assert!(unweighted.status.is_healthy());
        assert_eq!(weighted.rejoins, 2);
        let spike = peak_post_join(&unweighted);
        let ramped = peak_post_join(&weighted);
        assert!(
            spike > ramped * 1.5,
            "rejoin spike not smoothed: unweighted peak {spike:.4}s, weighted {ramped:.4}s"
        );
        // Once warm, both layouts level back out to one shard per worker.
        assert_eq!(weighted.shard_owners, unweighted.shard_owners);
    }

    #[test]
    fn smaller_gamma_gives_faster_iterations() {
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let mut times = Vec::new();
        for gamma in [2usize, 6, 8] {
            let mut pool = p.native_pool();
            let rep = run_virtual(
                &mut pool,
                &cluster,
                &base_cfg(&p)
                    .with_mode(SyncMode::Hybrid { gamma })
                    .with_iters(120),
                &NoEval,
            )
            .unwrap();
            times.push(rep.total_time());
        }
        assert!(times[0] < times[1] && times[1] < times[2], "{times:?}");
    }

    #[test]
    fn lossy_net_hybrid_converges_and_counts_drops() {
        use crate::net::NetSpec;
        let p = tiny_problem(8);
        let cluster = ClusterSpec {
            workers: 8,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 0.5 },
            ..ClusterSpec::default()
        }
        .with_net(NetSpec::lossy(0.15));
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 5 })
            .with_iters(600);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert!(rep.net.dropped > 0, "no drops at 15% loss: {:?}", rep.net);
        assert_eq!(rep.net.sent, rep.net.delivered + rep.net.dropped);
        let err = p.theta_err(&rep.theta);
        assert!(err < 5e-2, "theta_err={err}");
    }

    #[test]
    fn duplicated_replies_are_abandoned_not_double_counted() {
        use crate::net::{LinkModel, NetSpec};
        let p = tiny_problem(6);
        let net = NetSpec {
            default_link: LinkModel { dup_prob: 0.5, dup_lag: 1e-4, ..LinkModel::ideal() },
            ..NetSpec::ideal()
        };
        let base = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 0.5 },
            ..ClusterSpec::default()
        };
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 6 })
            .with_iters(200);
        // γ = M and pure duplication (no drops): the included set each
        // iteration is identical to the clean run, so θ matches exactly —
        // every duplicate must land in `Abandoned`, never in the sum.
        let mut pool_clean = p.native_pool();
        let clean = run_virtual(&mut pool_clean, &base, &cfg, &NoEval).unwrap();
        let mut pool_dup = p.native_pool();
        let dup = run_virtual(&mut pool_dup, &base.clone().with_net(net), &cfg, &NoEval).unwrap();
        assert!(dup.net.duplicated > 0, "{:?}", dup.net);
        assert_eq!(dup.net.dropped, 0);
        assert_eq!(clean.theta, dup.theta, "a duplicate leaked into the aggregate");
        assert!(dup.total_abandoned >= dup.net.duplicated);
        assert_eq!(clean.total_abandoned, 0);
    }

    #[test]
    fn partition_window_suppresses_partitioned_workers() {
        use crate::net::NetSpec;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_net(NetSpec::ideal().with_partition(&[4, 5], 10, 30));
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 6 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        for row in rep.recorder.rows() {
            // During the window only 4 replies can arrive, so γ=6 clamps
            // to the deliverable 4 and the partitioned shards drop out.
            let want = if (10..30).contains(&row.iter) { 4 } else { 6 };
            assert_eq!(row.included, want, "iter {}", row.iter);
            if (10..30).contains(&row.iter) {
                assert_eq!(row.dropped, 2, "iter {}", row.iter);
            } else {
                assert_eq!(row.dropped, 0, "iter {}", row.iter);
            }
        }
        // 2 workers × 20 iterations, one Work message each.
        assert_eq!(rep.net.dropped, 40);
    }

    #[test]
    fn bsp_retry_pays_for_network_loss() {
        use crate::net::NetSpec;
        let p = tiny_problem(4);
        let mk = |net: NetSpec| {
            let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() }.with_net(net);
            let mut cfg = base_cfg(&p).with_mode(SyncMode::Bsp).with_iters(120);
            cfg.bsp_recovery = BspRecovery::Retry { detect_timeout: 0.05 };
            let mut pool = p.native_pool();
            run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
        };
        let clean = mk(NetSpec::ideal());
        let lossy = mk(NetSpec::lossy(0.2));
        assert!(clean.status.is_healthy());
        assert!(lossy.status.is_healthy());
        // Retry keeps every shard contributing (θ identical to clean BSP)
        // but pays detection + re-execution latency for every lost reply.
        assert_eq!(clean.theta, lossy.theta);
        assert!(
            lossy.total_time() > clean.total_time() * 1.5,
            "lossy {:.3}s vs clean {:.3}s",
            lossy.total_time(),
            clean.total_time()
        );
        assert!(lossy.net.dropped > 0);
    }

    #[test]
    fn async_mode_survives_lossy_net() {
        use crate::net::NetSpec;
        let p = tiny_problem(6);
        let cluster = ClusterSpec { workers: 6, ..ClusterSpec::default() }
            .with_net(NetSpec::lossy(0.2));
        let mut cfg = base_cfg(&p)
            .with_mode(SyncMode::Async { damping: 0.0 })
            .with_iters(1800);
        cfg.optimizer = OptimizerKind::sgd(0.3);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        assert!(rep.net.dropped > 0, "{:?}", rep.net);
        let err = p.theta_err(&rep.theta);
        assert!(err < 0.1, "theta_err={err}");
    }

    #[test]
    fn slow_uplink_replies_straggle_into_later_iterations_as_stale() {
        // Cross-iteration reordering: worker 3's uplink is 50 ms while the
        // barrier closes in ~5 ms, so its reply out-lives every window it
        // was computed for and lands iterations later — the engine must
        // classify it Stale (an old-iteration arrival), which the lockstep
        // driver could never produce in virtual time.  The asymmetry is
        // per-direction: the Work broadcast down is instant.
        use crate::net::{LinkDir, LinkModel, NetSpec};
        let p = tiny_problem(4);
        let slow_up = LinkModel {
            up: Some(LinkDir {
                latency: DelayModel::Constant { secs: 0.05 },
                drop_prob: 0.0,
            }),
            ..LinkModel::ideal()
        };
        let cluster = ClusterSpec {
            workers: 4,
            base_compute: 0.005,
            ..ClusterSpec::default()
        }
        .with_net(NetSpec::ideal().with_override(3, slow_up));
        let cfg = base_cfg(&p)
            .with_mode(SyncMode::Hybrid { gamma: 3 })
            .with_iters(50);
        let mut pool = p.native_pool();
        let rep = run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{:?}", rep.status);
        let stale_total: usize = rep.recorder.rows().iter().map(|r| r.stale).sum();
        assert!(stale_total > 0, "no stale admissions in virtual time");
        // Worker 3's reply never lands inside its own window, so it is
        // never merely "abandoned" — every accounted loss is a stale.
        assert_eq!(rep.total_abandoned, stale_total as u64);
        for row in rep.recorder.rows() {
            assert_eq!(row.included, 3, "iter {}", row.iter);
        }
        assert_eq!(rep.net.dropped, 0);
    }
}
