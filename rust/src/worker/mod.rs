//! Threaded "real" runtime: slave threads with actual sleeps, a master
//! event loop closing the partial barrier on wall-clock arrivals.
//!
//! This is the production-shaped path: each worker thread owns its own PJRT
//! engine (the `xla` client is not `Send`), receives θ broadcasts over a
//! channel, computes its assigned shards' gradients through the AOT
//! executable, sleeps its injected straggler delay, and reports back.  The
//! master measures *wall-clock* — the examples use this to demonstrate the
//! paper's actual time savings, while benches use the virtual simulator.
//!
//! **Elastic membership** executes the same plan as the virtual driver:
//! scheduled leave/join events ([`crate::cluster::ElasticSchedule`]) apply
//! at iteration boundaries, and with `rebalance_every > 0` the master
//! re-plans shard ownership ([`crate::data::plan_rebalance`]) and ships
//! each worker its current shard list inside every `Work` message.
//! Contributions aggregate in ascending shard order, matching the
//! simulator bit-for-bit on the fold order.  A scheduled leave is a
//! master-side eviction — the slave thread survives, so a later scheduled
//! join simply re-admits it.  (Joining a worker that *stochastically*
//! crashed is not supported: its thread has stopped serving work.)

pub mod compute;
pub mod slave;

pub use compute::{NativeKrrFactory, XlaKrrFactory};

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{ClusterSpec, ElasticRuntime, MasterMsg, Membership, ShardGrad, WorkerMsg};
use crate::coordinator::aggregator::{aggregate, Contribution};
use crate::coordinator::barrier::{Admission, PartialBarrier};
use crate::coordinator::convergence::{ConvergenceTracker, RunStatus};
use crate::coordinator::{BspRecovery, RunConfig, RunReport, SyncMode};
use crate::data::GradResult;
use crate::math::vec_ops;
use crate::metrics::{IterRow, Recorder};
use crate::sim::EvalHooks;
use crate::{Error, Result};

/// Worker-side gradient computation (built inside the worker thread).
/// Shard-addressable: under elastic rebalancing a worker computes whatever
/// shards the master currently assigns it.
pub trait WorkerCompute {
    fn dim(&self) -> usize;
    fn grad_shard(&mut self, shard: usize, theta: &[f32], iter: u64) -> Result<GradResult>;
    /// Hint: the worker's current assignment.  Implementations holding
    /// per-shard resources (device buffers) may release everything not in
    /// `shards`; migrating a shard back later just re-pays its one upload.
    fn retain_shards(&mut self, shards: &[usize]) {
        let _ = shards;
    }
}

/// Builds per-worker [`WorkerCompute`] instances.  `Sync` because the
/// factory is shared across spawning threads; the built compute is not.
pub trait ComputeFactory: Sync {
    fn dim(&self) -> usize;
    fn workers(&self) -> usize;
    fn shard_examples(&self, w: usize) -> usize;
    /// Called *inside* worker `w`'s thread (PJRT clients are per-thread).
    fn build(&self, w: usize) -> Result<Box<dyn WorkerCompute>>;
}

/// Master receive timeout before declaring a stall (real mode only).
const STALL_TIMEOUT: std::time::Duration = std::time::Duration::from_secs(30);

/// Run an experiment on real threads, measuring wall-clock.
pub fn run_real(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
) -> Result<RunReport> {
    let m = factory.workers();
    if m != cluster.workers {
        return Err(Error::Cluster(format!(
            "factory has {m} workers, cluster spec says {}",
            cluster.workers
        )));
    }
    crate::coordinator::validate_elastic(cluster, &cfg.mode)?;
    if cfg.mode.is_async() {
        return run_real_async(cluster, cfg, factory, hooks);
    }
    run_real_sync(cluster, cfg, factory, hooks)
}

fn run_real_sync(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
) -> Result<RunReport> {
    let driver_start = Instant::now();
    let m = factory.workers();
    let dim = factory.dim();
    let n_total: usize = (0..m).map(|w| factory.shard_examples(w)).sum();
    let zeta = factory.shard_examples(0);
    let gamma = cfg.mode.initial_gamma(n_total, zeta, m)?;

    let (res_tx, res_rx) = mpsc::channel::<WorkerMsg>();
    let mut work_txs: Vec<mpsc::Sender<MasterMsg>> = Vec::with_capacity(m);

    let mut theta = cfg.init_theta.clone().unwrap_or_else(|| vec![0.0f32; dim]);
    let mut agg = vec![0.0f32; dim];
    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut membership = Membership::new(m);
    let mut status = RunStatus::Completed;
    // Shard ownership + rebalance state, shared logic with the virtual
    // driver.  A scheduled Leave here is purely master-side (the slave
    // thread survives and is simply not broadcast to), so no extra
    // failure-state bookkeeping is needed in the event hook.
    let mut elastic = ElasticRuntime::new(&membership);

    std::thread::scope(|scope| -> Result<()> {
        // --- spawn slaves ------------------------------------------------
        let profiles = cluster.profiles();
        for w in 0..m {
            let (tx, rx) = mpsc::channel::<MasterMsg>();
            work_txs.push(tx);
            let res_tx = res_tx.clone();
            let profile = profiles[w].clone();
            let seed = cluster.seed;
            scope.spawn(move || {
                slave::worker_main(w, seed, profile, factory, rx, res_tx);
            });
        }
        drop(res_tx);

        // --- master loop ---------------------------------------------
        'iters: for iter in 0..cfg.stop.max_iters {
            // Elastic membership events land at this boundary, in schedule
            // order — identical semantics to the virtual driver.
            let rebalanced = elastic.at_boundary(
                iter,
                &cluster.elastic,
                cluster.rebalance_every,
                &mut membership,
                |_| {},
            )?;
            if rebalanced {
                log::debug!("iter {iter}: shard ownership rebalanced");
            }

            let theta_arc = Arc::new(theta.clone());
            // One O(shards) pass instead of an O(shards) scan per worker.
            let mut assignment = elastic.ownership.grouped();
            let mut broadcast = 0usize;
            for w in 0..m {
                if membership.is_alive(w) {
                    if work_txs[w]
                        .send(MasterMsg::Work {
                            iter,
                            theta: Arc::clone(&theta_arc),
                            shards: Arc::new(std::mem::take(&mut assignment[w])),
                        })
                        .is_ok()
                    {
                        broadcast += 1;
                    } else {
                        membership.mark_down(w);
                    }
                }
            }
            if broadcast == 0 {
                status = RunStatus::ClusterDead { iter };
                break;
            }

            let g_target = match (&cfg.mode, gamma) {
                (SyncMode::Bsp, _) => membership.alive(),
                (_, Some(g)) => g.min(membership.alive()),
                (mode, None) => {
                    return Err(Error::Config(format!(
                        "mode {} unsupported in real sync driver",
                        mode.name()
                    )))
                }
            };
            let mut barrier = PartialBarrier::new(iter, m, g_target.max(1));
            let mut grads: Vec<ShardGrad> = Vec::with_capacity(g_target);

            // Collect until the barrier closes.
            while !barrier.is_closed() {
                let msg = match res_rx.recv_timeout(STALL_TIMEOUT) {
                    Ok(msg) => msg,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        status = RunStatus::Stalled { iter };
                        break 'iters;
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        status = RunStatus::ClusterDead { iter };
                        break 'iters;
                    }
                };
                match msg {
                    WorkerMsg::Grad {
                        worker,
                        iter: msg_iter,
                        shards,
                        ..
                    } => match barrier.offer(worker, msg_iter) {
                        Admission::Included | Admission::IncludedAndClosed => {
                            membership.record_contribution(worker);
                            grads.extend(shards);
                        }
                        Admission::Abandoned | Admission::Stale => {
                            membership.record_abandoned(worker);
                        }
                    },
                    WorkerMsg::SimulatedCrash { worker, .. } => {
                        membership.mark_down(worker);
                        match (&cfg.mode, cfg.bsp_recovery) {
                            (SyncMode::Bsp, BspRecovery::Stall) => {
                                status = RunStatus::Stalled { iter };
                                break 'iters;
                            }
                            _ => {
                                if membership.alive() == 0 {
                                    status = RunStatus::ClusterDead { iter };
                                    break 'iters;
                                }
                                // Close on fewer arrivals (BSP-retry in real
                                // mode degrades to alive-only membership).
                                let new_target = match (&cfg.mode, gamma) {
                                    (SyncMode::Bsp, _) => membership.alive(),
                                    (_, Some(g)) => g.min(membership.alive()),
                                    _ => unreachable!(),
                                };
                                barrier.shrink_gamma(new_target.max(1));
                            }
                        }
                    }
                    WorkerMsg::Fatal { worker, error } => {
                        return Err(Error::Cluster(format!("worker {worker} died: {error}")));
                    }
                }
            }
            if grads.is_empty() {
                continue;
            }

            // Drain any already-queued stragglers without blocking.
            while let Ok(msg) = res_rx.try_recv() {
                match msg {
                    WorkerMsg::Grad { worker, .. } => membership.record_abandoned(worker),
                    WorkerMsg::SimulatedCrash { worker, .. } => membership.mark_down(worker),
                    WorkerMsg::Fatal { worker, error } => {
                        return Err(Error::Cluster(format!("worker {worker} died: {error}")));
                    }
                }
            }

            // Aggregate in ascending shard order — the same fold order the
            // virtual simulator uses, so both drivers' f32 sums match.
            grads.sort_by_key(|g| g.shard);
            let contribs: Vec<Contribution<'_>> = grads
                .iter()
                .map(|g| Contribution {
                    grad: &g.grad,
                    examples: g.examples,
                    staleness: 0,
                })
                .collect();
            aggregate(cfg.aggregator, &contribs, &mut agg);
            let grad_norm = vec_ops::norm2(&agg);
            let loss_sum: f64 = grads.iter().filter_map(|g| g.loss_sum).sum();
            let loss_examples: usize = grads
                .iter()
                .filter(|g| g.loss_sum.is_some())
                .map(|g| g.examples)
                .sum();
            let loss = cfg.loss_form.assemble(loss_sum, loss_examples, &theta);

            opt.step(&mut theta, &agg, iter);
            let now = driver_start.elapsed().as_secs_f64();

            let do_eval = cfg.eval_every > 0 && iter % cfg.eval_every == 0;
            let stop = tracker.observe(iter, loss, grad_norm);
            if (cfg.record_every > 0 && iter % cfg.record_every == 0)
                || do_eval
                || stop.is_some()
            {
                let (eval_loss, theta_err) = if do_eval || stop.is_some() {
                    (hooks.hook_eval_loss(&theta), hooks.hook_theta_err(&theta))
                } else {
                    (None, None)
                };
                rec.push(IterRow {
                    iter,
                    time: now,
                    loss,
                    eval_loss,
                    theta_err,
                    included: grads.len(),
                    abandoned: 0,
                    alive: membership.alive(),
                    gamma,
                    grad_norm,
                });
            }
            if let Some(s) = stop {
                status = s;
                break;
            }
        }

        // --- shutdown --------------------------------------------------
        for tx in &work_txs {
            let _ = tx.send(MasterMsg::Shutdown);
        }
        Ok(())
    })?;

    Ok(RunReport {
        recorder: rec,
        theta,
        status,
        gamma,
        mode_name: cfg.mode.name(),
        total_contributions: membership.total_contributed(),
        total_abandoned: membership.total_abandoned(),
        crashes: membership.crashes(),
        rejoins: membership.rejoins(),
        rebalances: elastic.rebalances(),
        mean_staleness: None,
        driver_secs: driver_start.elapsed().as_secs_f64(),
    })
}

fn run_real_async(
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    factory: &dyn ComputeFactory,
    hooks: &dyn EvalHooks,
) -> Result<RunReport> {
    let driver_start = Instant::now();
    let m = factory.workers();
    let dim = factory.dim();
    let damping = match cfg.mode {
        SyncMode::Async { damping } => damping,
        _ => unreachable!(),
    };

    let (res_tx, res_rx) = mpsc::channel::<WorkerMsg>();
    let mut work_txs: Vec<mpsc::Sender<MasterMsg>> = Vec::with_capacity(m);
    let mut theta = cfg.init_theta.clone().unwrap_or_else(|| vec![0.0f32; dim]);
    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut membership = Membership::new(m);
    let mut status = RunStatus::Completed;
    let mut version = 0u64;
    let mut version_given = vec![0u64; m];
    let mut staleness_sum = 0.0;
    let mut updates = 0u64;
    let mut scaled = vec![0.0f32; dim];
    let mut loss_ema: Option<f64> = None;

    std::thread::scope(|scope| -> Result<()> {
        let profiles = cluster.profiles();
        for w in 0..m {
            let (tx, rx) = mpsc::channel::<MasterMsg>();
            // Kick off the first round immediately.
            tx.send(MasterMsg::Work {
                iter: 0,
                theta: Arc::new(theta.clone()),
                shards: Arc::new(vec![w]),
            })
            .expect("fresh channel");
            work_txs.push(tx);
            let res_tx = res_tx.clone();
            let profile = profiles[w].clone();
            let seed = cluster.seed;
            scope.spawn(move || {
                slave::worker_main(w, seed, profile, factory, rx, res_tx);
            });
        }
        drop(res_tx);

        while updates < cfg.stop.max_iters {
            let msg = match res_rx.recv_timeout(STALL_TIMEOUT) {
                Ok(msg) => msg,
                Err(_) => {
                    status = RunStatus::Stalled { iter: updates };
                    break;
                }
            };
            match msg {
                WorkerMsg::Grad { worker, shards, .. } => {
                    // Async workers always compute exactly their own shard.
                    let Some(sg) = shards.into_iter().next() else {
                        continue;
                    };
                    let staleness = version - version_given[worker];
                    staleness_sum += staleness as f64;
                    membership.record_contribution(worker);
                    let weight = if damping > 0.0 {
                        (1.0 / (1.0 + staleness as f64)).powf(damping) as f32
                    } else {
                        1.0
                    };
                    scaled.copy_from_slice(&sg.grad);
                    if weight != 1.0 {
                        vec_ops::scale(&mut scaled, weight);
                    }
                    opt.step(&mut theta, &scaled, updates);
                    version += 1;
                    updates += 1;
                    version_given[worker] = version;
                    let _ = work_txs[worker].send(MasterMsg::Work {
                        iter: updates,
                        theta: Arc::new(theta.clone()),
                        shards: Arc::new(vec![worker]),
                    });

                    if let Some(ls) = sg.loss_sum {
                        let shard_loss = cfg.loss_form.assemble(ls, sg.examples, &theta);
                        loss_ema = Some(match loss_ema {
                            None => shard_loss,
                            Some(p) => 0.9 * p + 0.1 * shard_loss,
                        });
                    }
                    let loss = loss_ema.unwrap_or(f64::NAN);
                    let grad_norm = vec_ops::norm2(&scaled);
                    let stop = tracker.observe(updates.saturating_sub(1), loss, grad_norm);
                    if updates % (cfg.record_every.max(1) * m as u64) == 0 || stop.is_some() {
                        rec.push(IterRow {
                            iter: updates,
                            time: driver_start.elapsed().as_secs_f64(),
                            loss,
                            eval_loss: hooks.hook_eval_loss(&theta),
                            theta_err: hooks.hook_theta_err(&theta),
                            included: 1,
                            abandoned: 0,
                            alive: membership.alive(),
                            gamma: None,
                            grad_norm,
                        });
                    }
                    if let Some(s) = stop {
                        status = s;
                        break;
                    }
                }
                WorkerMsg::SimulatedCrash { worker, .. } => {
                    membership.mark_down(worker);
                    if membership.alive() == 0 {
                        status = RunStatus::ClusterDead { iter: updates };
                        break;
                    }
                }
                WorkerMsg::Fatal { worker, error } => {
                    return Err(Error::Cluster(format!("worker {worker} died: {error}")));
                }
            }
        }
        for tx in &work_txs {
            let _ = tx.send(MasterMsg::Shutdown);
        }
        Ok(())
    })?;

    Ok(RunReport {
        recorder: rec,
        theta,
        status,
        gamma: None,
        mode_name: "async",
        total_contributions: membership.total_contributed(),
        total_abandoned: membership.total_abandoned(),
        crashes: membership.crashes(),
        rejoins: membership.rejoins(),
        rebalances: 0,
        mean_staleness: if updates > 0 {
            Some(staleness_sum / updates as f64)
        } else {
            None
        },
        driver_secs: driver_start.elapsed().as_secs_f64(),
    })
}
