//! The threaded runtime's channel shim.
//!
//! The master consults the shim before every `Work` broadcast and on every
//! `Grad` receipt.  Because a message's fate is a pure function of
//! `(seed, worker, iteration)` ([`NetSpec::realize`]), the shim needs no
//! per-iteration state: a stale reply from three iterations ago re-realizes
//! its own iteration's fate correctly.
//!
//! **Accounting happens at broadcast (plan) time** — the reply's fate is
//! already determined then — so the counts match the virtual driver's
//! exactly even though real replies land on wall-clock.  (The counts
//! assume the addressed worker actually replies; a stochastic thread
//! crash diverges the drivers' counts, just as it already diverges their
//! abandonment totals.)

use super::link::LinkRealization;
use super::spec::NetSpec;
use super::NetStats;

/// What the master should do with one worker's `Work` broadcast.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WorkPlan {
    /// Downlink dropped (lossy or partitioned): don't send.
    Dropped,
    /// Send; the slave adds `net_delay` to its injected sleep so arrival
    /// timing matches the virtual driver's `down + compute + up` model.
    Deliver { net_delay: f64 },
}

/// Fate of a received `Grad` reply.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GradFate {
    /// The uplink lost it: discard silently.
    Dropped,
    /// Offer it to the barrier; if `duplicate`, offer a second copy too.
    Deliver { duplicate: bool },
}

/// Master-side network shim for the threaded ("real") runtime.
pub struct NetShim {
    spec: NetSpec,
    seed: u64,
    ideal: bool,
    stats: NetStats,
}

impl NetShim {
    pub fn new(spec: NetSpec, seed: u64) -> NetShim {
        let ideal = spec.is_ideal();
        NetShim { spec, seed, ideal, stats: NetStats::default() }
    }

    pub fn is_ideal(&self) -> bool {
        self.ideal
    }

    /// Plan worker `worker`'s iteration-`iter` broadcast, accounting both
    /// the `Work` message and the (already-determined) fate of its reply.
    /// The second return says whether the reply will reach the barrier.
    pub fn plan(&mut self, worker: usize, iter: u64) -> (WorkPlan, bool) {
        let r = if self.ideal {
            LinkRealization::ideal()
        } else {
            self.spec.realize(self.seed, worker, iter)
        };
        let delivers = self.stats.count_roundtrip(&r, true);
        if r.down_dropped {
            return (WorkPlan::Dropped, false);
        }
        let net_delay = if delivers { r.roundtrip_delay() } else { r.down_delay };
        (WorkPlan::Deliver { net_delay }, delivers)
    }

    /// Whether worker `worker`'s iteration-`iter` reply survives the
    /// network.  Pure re-realization — no accounting.
    pub fn reply_expected(&self, worker: usize, iter: u64) -> bool {
        self.ideal || self.spec.realize(self.seed, worker, iter).delivers()
    }

    /// Fate of a received `Grad` for `(worker, msg_iter)`.  Pure
    /// re-realization, so stale replies from earlier iterations resolve
    /// against their own iteration's fates.  No accounting: [`NetShim::plan`]
    /// already counted this reply.
    pub fn grad_fate(&self, worker: usize, msg_iter: u64) -> GradFate {
        if self.ideal {
            return GradFate::Deliver { duplicate: false };
        }
        let r = self.spec.realize(self.seed, worker, msg_iter);
        if r.delivers() {
            GradFate::Deliver { duplicate: r.up_duplicated }
        } else {
            GradFate::Dropped
        }
    }

    pub fn stats(&self) -> NetStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_shim_always_delivers() {
        let mut shim = NetShim::new(NetSpec::ideal(), 1);
        for iter in 0..10 {
            let (plan, delivers) = shim.plan(0, iter);
            assert_eq!(plan, WorkPlan::Deliver { net_delay: 0.0 });
            assert!(delivers);
            assert_eq!(shim.grad_fate(0, iter), GradFate::Deliver { duplicate: false });
        }
        assert_eq!(shim.stats().sent, 20);
        assert_eq!(shim.stats().delivered, 20);
    }

    #[test]
    fn plan_and_fate_agree_with_realization() {
        let spec = NetSpec::lossy(0.4);
        let mut shim = NetShim::new(spec.clone(), 17);
        for iter in 0..200 {
            let r = spec.realize(17, 0, iter);
            let (plan, delivers) = shim.plan(0, iter);
            assert_eq!(delivers, r.delivers());
            assert_eq!(matches!(plan, WorkPlan::Dropped), r.down_dropped);
            assert_eq!(shim.reply_expected(0, iter), r.delivers());
            // The fate of the reply (if the slave sends one).
            match shim.grad_fate(0, iter) {
                GradFate::Dropped => assert!(!r.delivers()),
                GradFate::Deliver { duplicate } => {
                    assert!(r.delivers());
                    assert_eq!(duplicate, r.up_duplicated);
                }
            }
        }
        let s = shim.stats();
        assert_eq!(s.sent, s.delivered + s.dropped);
        assert!(s.dropped > 0);
    }

    #[test]
    fn shim_counts_match_virtual_transport() {
        use crate::net::transport::{Transport, VirtualTransport};
        let spec = NetSpec {
            default_link: crate::net::LinkModel {
                drop_prob: 0.25,
                dup_prob: 0.2,
                dup_lag: 0.001,
                ..crate::net::LinkModel::ideal()
            },
            ..NetSpec::ideal()
        };
        let seed = 23;
        let mut shim = NetShim::new(spec.clone(), seed);
        let mut virt = VirtualTransport::new(spec, seed);
        for iter in 0..100 {
            for w in 0..4 {
                shim.plan(w, iter);
                virt.send_roundtrip(w, iter, 0.01);
            }
            while virt.poll().is_some() {}
        }
        assert_eq!(shim.stats(), virt.stats());
    }
}
