//! T1 — accuracy vs abandon rate (paper §1/§3.2: "the relationship between
//! accuracy and abandon rate").
//!
//! Sweep γ over M=32 machines (abandon rate 1−γ/M from 0 to ~97%), train to
//! a fixed iteration budget, report final relative parameter error
//! ‖θ−θ*‖/‖θ*‖, holdout loss gap to the exact optimum, and total virtual
//! time.  5 seeds per point.  Also includes the DESIGN.md §6 "hybrid-reuse"
//! ablation row (staleness-damped inclusion of late gradients).
//!
//! The γ-points run concurrently on the sweep engine (`--threads N` to
//! override the pool size); the per-seed problems are shared through its
//! cache, so each (config, seed) pays its Cholesky solve once.
//!
//! Expected shape (paper claim): accuracy degrades *gracefully* as the
//! abandon rate rises — large speedups cost little accuracy until γζ drops
//! below the Lemma-3.2 sample size.

use hybriditer::bench_harness::sweep::{ProblemCache, SweepEngine};
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{AggregatorKind, LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::math::{stats::Summary, vec_ops};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;

const M: usize = 32;
const SEEDS: u64 = 5;
const ITERS: u64 = 250;

fn run_point(
    cache: &ProblemCache,
    gamma: usize,
    aggregator: AggregatorKind,
    seeds: u64,
) -> (Summary, Summary, Summary) {
    let mut rel_errs = Vec::new();
    let mut loss_gaps = Vec::new();
    let mut times = Vec::new();
    for seed in 0..seeds {
        let spec = KrrProblemSpec::small().with_machines(M).with_seed(100 + seed);
        let problem = cache.get(&spec);
        let cluster = ClusterSpec {
            workers: M,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.0 },
            seed: 7000 + seed,
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: if gamma == M {
                SyncMode::Bsp
            } else {
                SyncMode::Hybrid { gamma }
            },
            optimizer: OptimizerKind::Sgd {
                eta: hybriditer::optim::EtaSchedule { eta0: 1.0, decay: 0.005 },
            },
            aggregator,
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 0,
            record_every: ITERS, // only need the final state
            seed,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        let rel = problem.theta_err(&rep.theta) / vec_ops::norm2(&problem.theta_star);
        rel_errs.push(rel);
        loss_gaps.push(problem.eval_loss(&rep.theta) - problem.eval_loss(&problem.theta_star));
        times.push(rep.total_time());
    }
    (
        Summary::of(&rel_errs),
        Summary::of(&loss_gaps),
        Summary::of(&times),
    )
}

fn main() {
    let engine = SweepEngine::from_env();
    println!("T1: accuracy vs abandon rate — M={M}, {ITERS} iters, {SEEDS} seeds/point");
    println!("paper claim: accuracy degrades gracefully as abandon rate rises");
    println!("sweep pool: {} threads\n", engine.threads());

    let mut table = Table::new(
        "T1 accuracy vs abandon rate",
        &[
            "gamma",
            "abandon_%",
            "rel_err_mean",
            "rel_err_std",
            "eval_gap",
            "virt_time_s",
            "speedup",
        ],
    );
    let gammas = [32usize, 28, 24, 20, 16, 12, 8, 4, 2, 1];
    // The leading point doubles as the BSP reference for the speedup
    // column (run_point switches to SyncMode::Bsp at gamma == M).
    assert_eq!(gammas[0], M, "speedup reference must be the gamma=M point");
    let results = engine.run(&gammas, |cache, &g| {
        run_point(cache, g, AggregatorKind::Mean, SEEDS)
    });
    let bsp_time = results[0].2.mean;
    for (&g, (rel, gap, time)) in gammas.iter().zip(&results) {
        table.row(vec![
            g.to_string(),
            f(100.0 * (1.0 - g as f64 / M as f64), 1),
            format!("{:.4e}", rel.mean),
            format!("{:.1e}", rel.std),
            format!("{:.3e}", gap.mean),
            f(time.mean, 2),
            f(bsp_time / time.mean, 2),
        ]);
    }
    table.print();
    table.save_csv("t1_accuracy_vs_abandon").unwrap();

    // Ablation: abandon (paper) vs staleness-damped reuse of late grads.
    let mut ab = Table::new(
        "T1 ablation: abandon vs hybrid-reuse (gamma=8, rho=0.5)",
        &["policy", "rel_err_mean", "virt_time_s"],
    );
    let policies = [
        ("abandon (paper)", AggregatorKind::Mean),
        ("reuse rho=0.5", AggregatorKind::StalenessDamped { rho: 0.5 }),
    ];
    let ab_results = engine.run(&policies, |cache, &(_, agg)| run_point(cache, 8, agg, SEEDS));
    for ((name, _), (rel, _, time)) in policies.iter().zip(&ab_results) {
        ab.row(vec![
            name.to_string(),
            format!("{:.4e}", rel.mean),
            f(time.mean, 2),
        ]);
    }
    ab.print();
    ab.save_csv("t1_ablation_reuse").unwrap();
}
