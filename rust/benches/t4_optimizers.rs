//! T4 — algorithm applicability (paper §1: the hybrid approach "can be
//! applied to a list of algorithms including iterations such as Stochastic
//! Gradient Descent, Conjugate Gradient Descent, L-BFGS and so on").
//!
//! Drives the same KRR problem with six master-side optimizers, each under
//! BSP and under hybrid γ=¾M on a straggler-ridden cluster.  The 12
//! (optimizer × mode) cells run concurrently on the sweep engine
//! (`--threads N` overrides the pool size).  Expected shape: every
//! optimizer still converges under partial aggregation, and hybrid wins
//! wall-clock across the board.

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::optim::{EtaSchedule, OptimizerKind};
use hybriditer::sim;
use hybriditer::straggler::DelayModel;

fn main() {
    let m = 16;
    let iters = 200;
    let engine = SweepEngine::from_env();
    let spec = KrrProblemSpec::small().with_machines(m);
    println!("T4: optimizer applicability — M={m}, {iters} iters, lognormal stragglers");
    println!("sweep pool: {} threads\n", engine.threads());

    let optimizers: Vec<(&str, OptimizerKind)> = vec![
        ("sgd", OptimizerKind::Sgd { eta: EtaSchedule::constant(1.0) }),
        (
            "momentum",
            OptimizerKind::Momentum { eta: EtaSchedule::constant(0.3), mu: 0.9, nesterov: false },
        ),
        (
            "nesterov",
            OptimizerKind::Momentum { eta: EtaSchedule::constant(0.3), mu: 0.9, nesterov: true },
        ),
        ("adam", OptimizerKind::Adam { eta: 0.05, beta1: 0.9, beta2: 0.999, eps: 1e-8 }),
        ("lbfgs", OptimizerKind::Lbfgs { eta: 0.8, history: 10 }),
        ("cg", OptimizerKind::Cg { eta: 0.5, restart: 16 }),
    ];

    // One sweep point per (optimizer, mode) cell, BSP first per optimizer
    // so the speedup column's reference lands before its hybrid row.
    let mut points: Vec<(String, OptimizerKind, &'static str, SyncMode)> = Vec::new();
    for (name, kind) in &optimizers {
        points.push((name.to_string(), kind.clone(), "bsp", SyncMode::Bsp));
        points.push((
            name.to_string(),
            kind.clone(),
            "hybrid",
            SyncMode::Hybrid { gamma: m * 3 / 4 },
        ));
    }
    let results = engine.run(&points, |cache, (_, kind, _, mode)| {
        let problem = cache.get(&spec);
        let cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.2 },
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: mode.clone(),
            optimizer: kind.clone(),
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 1,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(iters);
        let mut pool = problem.native_pool();
        sim::run_virtual(&mut pool, &cluster, &cfg, problem.as_ref()).unwrap()
    });

    let mut table = Table::new(
        "T4 optimizer x barrier policy",
        &["optimizer", "mode", "theta_err", "virt_time_s", "iters_to_err<0.1", "speedup"],
    );
    let mut bsp_time = 0.0;
    for ((name, _, mode_name, _), rep) in points.iter().zip(&results) {
        if *mode_name == "bsp" {
            bsp_time = rep.total_time();
        }
        let iters_to = rep
            .recorder
            .rows()
            .iter()
            .find(|r| r.theta_err.map(|e| e < 0.1).unwrap_or(false))
            .map(|r| r.iter.to_string())
            .unwrap_or_else(|| "-".into());
        table.row(vec![
            name.clone(),
            mode_name.to_string(),
            format!("{:.3e}", rep.final_theta_err().unwrap_or(f64::NAN)),
            f(rep.total_time(), 2),
            iters_to,
            f(bsp_time / rep.total_time(), 2),
        ]);
    }
    table.print();
    table.save_csv("t4_optimizers").unwrap();
    println!(
        "\nReading: every master-side algorithm converges under the hybrid\n\
         barrier (theta_err column), at ~constant iteration counts but a\n\
         uniform wall-clock speedup (speedup column) — the paper's\n\
         applicability claim."
    );
}
