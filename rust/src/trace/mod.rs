//! Deterministic flight recorder: structured event tracing for both drivers.
//!
//! Per-iteration aggregates (`IterRow`) say *how many* results were
//! abandoned; they cannot say *which* reply was dropped, retried with
//! backoff, admitted stale, or folded as a partial block set.  This module
//! records that causal chain as a stream of typed [`TraceEvent`]s, each
//! stamped `(iter, worker, time, seq)`, through a [`TraceSink`] threaded
//! into both drivers.
//!
//! Because every message fate in this repo is a **pure function** of
//! `(seed, worker, iter)` ([`crate::net::NetSpec::realize`]), the trace is
//! deterministic — and therefore doubles as a cross-driver correctness
//! oracle: under ideal networks the virtual and threaded drivers must
//! produce byte-identical event sequences after timestamp normalization,
//! and under lossy networks identical per-message *fate* sequences
//! (`tests/parity_drivers.rs`).  Fate events (Dispatch / Drop / Duplicate /
//! BlockFate) are emitted at dispatch/plan time by re-realizing the pure
//! fate function — [`emit_roundtrip_fates`] is the single shared routine —
//! so wall-clock jitter in the threaded driver cannot reorder them.
//!
//! Two sinks ship: [`NoopSink`] (the default — `enabled()` is `false`,
//! every emission site is guarded, so the disabled hot path performs zero
//! work and zero allocations; `tests/alloc_regression.rs` pins this) and
//! [`JournalSink`], which buffers [`TraceRecord`]s and exports three ways:
//! a JSONL journal ([`JournalSink::jsonl`]), a Chrome trace-event JSON for
//! Perfetto ([`JournalSink::chrome_trace`], one lane per worker), and a
//! run-level [`TraceSummary`] (per-worker latency histograms via
//! [`crate::metrics::Histogram`]) surfaced as `RunReport::trace`.
//!
//! See `docs/OBSERVABILITY.md` for the event taxonomy and exporter formats.

use std::fmt::Write as _;

use crate::metrics::Histogram;
use crate::net::NetSpec;

/// Lane index used for coordinator-side events (`BarrierClose`,
/// `RebalanceCut`): the master is worker `-1`.
pub const MASTER: i64 = -1;

/// One typed thing that happened.  Payloads carry only driver-agnostic
/// data (pure realizations, barrier outcomes), never wall-clock-dependent
/// state — that is what keeps the cross-driver parity oracle meaningful.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// A `Work` roundtrip was planned for `(worker, iter)`.
    Dispatch,
    /// A `Grad` reply reached the coordinator.
    Delivery { duplicate: bool },
    /// The pure realization dropped the message (`down`: the `Work`
    /// broadcast; otherwise the `Grad` reply, including below-threshold
    /// block admission).
    Drop { down: bool },
    /// The pure realization duplicates the delivered reply.
    Duplicate,
    /// Block admission realized this delivered set for the reply
    /// (primary, then — after a `Duplicate` — the duplicate copy's set).
    BlockFate { delivered_mask: u64, n_blocks: u32 },
    /// A stale arrival's unclaimed blocks were admitted via the ledger.
    StaleAdmission { claimed_blocks: usize },
    /// One BSP recovery attempt through the link model.
    RetryAttempt { attempt: u64, backoff: f64, delivered: bool },
    /// A shard-rebalance plan applied at a boundary; `owners[s]` is shard
    /// `s`'s owner after the cut.
    RebalanceCut { owners: Vec<usize> },
    /// Scheduled elastic membership events at a boundary.
    Join,
    Leave,
    /// A stochastic failure took the worker down mid-run.
    Crash,
    /// The iteration's barrier closed.
    BarrierClose { gamma: usize, included: usize, abandoned: usize },
    /// A recovery policy started acting on a worker's crash/leave/rejoin
    /// (`policy` is [`crate::recovery::RecoveryPolicy::name`]).
    RecoveryStart { policy: &'static str },
    /// The recovery completed; `rollback` is the iterations of progress
    /// a checkpoint restore rewound (0 for the rollback-free policies).
    RecoveryDone { policy: &'static str, rollback: u64 },
    /// An interior aggregation node folded `children` partial gradients
    /// into one combined message ([`crate::agg`]); for ring topologies a
    /// single master-lane fold summarizes the whole collective.
    AggFold { children: u32 },
    /// An interior aggregation node forwarded its combined message to
    /// `to` (a worker index, or [`MASTER`]); `delivered` is the pure
    /// edge-fate of that hop.
    Forward { to: i64, delivered: bool },
    /// One serving window closed at a barrier ([`crate::serve`]): the
    /// open-loop process offered `offered` arrivals, `admitted` reads
    /// were served, `shed` requests were rejected by admission control,
    /// and `queue` update requests remain batched but unfolded.  Pure in
    /// `(serve seed, tick)`, so it joins the cross-driver fate oracles.
    ServeWindow { offered: u64, admitted: u64, shed: u64, queue: u64 },
    /// A new θ snapshot was published to the serving read path
    /// ([`crate::serve::ThetaCell`]); `epoch` tags the snapshot readers
    /// observe from here on.
    ThetaPublish { epoch: u64 },
}

/// One emitted event with its full stamp.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Strictly increasing per sink — the journal's total order.
    pub seq: u64,
    pub iter: u64,
    /// Worker index, or [`MASTER`] for coordinator-side events.
    pub worker: i64,
    /// Virtual seconds (virtual driver) or wall seconds since run start
    /// (threaded driver).  Normalized away for parity comparison.
    pub time: f64,
    pub event: TraceEvent,
}

/// Where trace events go.  Every emission site in the drivers is guarded
/// by `if sink.enabled()`, so a disabled sink costs one branch and nothing
/// else — no formatting, no allocation, no RNG perturbation.
pub trait TraceSink {
    fn enabled(&self) -> bool;
    fn emit(&mut self, iter: u64, worker: i64, time: f64, event: TraceEvent);
    /// Run-level rollup for `RunReport::trace`; `None` when not recording.
    fn summary(&self) -> Option<TraceSummary> {
        None
    }
}

/// The default sink: tracing off.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopSink;

impl TraceSink for NoopSink {
    fn enabled(&self) -> bool {
        false
    }

    fn emit(&mut self, _iter: u64, _worker: i64, _time: f64, _event: TraceEvent) {}
}

/// Per-worker rollup of a recorded run.
#[derive(Clone, Debug)]
pub struct WorkerLane {
    pub worker: usize,
    pub dispatches: u64,
    pub deliveries: u64,
    pub drops: u64,
    pub duplicates: u64,
    pub stale: u64,
    /// Dispatch→delivery latency of primary replies.
    pub latency: Histogram,
}

impl WorkerLane {
    fn new(worker: usize) -> WorkerLane {
        WorkerLane {
            worker,
            dispatches: 0,
            deliveries: 0,
            drops: 0,
            duplicates: 0,
            stale: 0,
            latency: Histogram::latency(),
        }
    }
}

/// Run-level trace rollup, surfaced as `RunReport::trace`.
#[derive(Clone, Debug)]
pub struct TraceSummary {
    /// Total events recorded.
    pub events: u64,
    /// Barrier windows closed.
    pub barriers: u64,
    pub per_worker: Vec<WorkerLane>,
    /// Distribution of abandoned-result counts per closed barrier.
    pub abandoned_per_barrier: Histogram,
}

impl TraceSummary {
    /// Human-readable per-worker rollup (the CLI prints this after a
    /// traced run).
    pub fn render(&self) -> String {
        let mut s = format!(
            "trace: {} events, {} barriers closed, mean abandoned/barrier {:.2}\n",
            self.events,
            self.barriers,
            self.abandoned_per_barrier.mean()
        );
        for lane in &self.per_worker {
            s.push_str(&format!(
                "  worker {:3}: {} dispatched, {} delivered, {} dropped, {} dup, {} stale, \
                 latency p50 {:.4}s p99 {:.4}s\n",
                lane.worker,
                lane.dispatches,
                lane.deliveries,
                lane.drops,
                lane.duplicates,
                lane.stale,
                lane.latency.quantile(0.5),
                lane.latency.quantile(0.99)
            ));
        }
        s
    }
}

/// A recording sink: buffers every event and exports JSONL, Chrome
/// trace-event JSON, and a [`TraceSummary`].
pub struct JournalSink {
    records: Vec<TraceRecord>,
    seq: u64,
    lanes: Vec<WorkerLane>,
    last_dispatch: Vec<Option<f64>>,
    abandoned_hist: Histogram,
    barriers: u64,
}

impl Default for JournalSink {
    fn default() -> Self {
        JournalSink::new()
    }
}

impl JournalSink {
    pub fn new() -> JournalSink {
        JournalSink {
            records: Vec::new(),
            seq: 0,
            lanes: Vec::new(),
            last_dispatch: Vec::new(),
            // Abandonment counts are small integers; 0 lands in the
            // histogram's underflow bucket by design.
            abandoned_hist: Histogram::new(0.5, 4096.0, 64),
            barriers: 0,
        }
    }

    pub fn records(&self) -> &[TraceRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    fn lane(&mut self, worker: i64) -> Option<&mut WorkerLane> {
        if worker < 0 {
            return None;
        }
        let w = worker as usize;
        while self.lanes.len() <= w {
            let next = self.lanes.len();
            self.lanes.push(WorkerLane::new(next));
            self.last_dispatch.push(None);
        }
        Some(&mut self.lanes[w])
    }

    /// The JSONL journal: one event object per line, in `seq` order.
    pub fn jsonl(&self) -> String {
        self.render_jsonl(false)
    }

    /// The journal with every `time` zeroed — byte-identical across
    /// drivers under ideal networks (the trace-parity oracle).
    pub fn jsonl_normalized(&self) -> String {
        self.render_jsonl(true)
    }

    fn render_jsonl(&self, normalized: bool) -> String {
        let mut out = String::with_capacity(self.records.len() * 64);
        for r in &self.records {
            let t = if normalized { 0.0 } else { r.time };
            let _ = write!(
                out,
                "{{\"seq\":{},\"iter\":{},\"worker\":{},\"time\":{},",
                r.seq, r.iter, r.worker, t
            );
            event_fields(&r.event, &mut out);
            out.push_str("}\n");
        }
        out
    }

    /// Only the pure per-message fate events (Dispatch / Drop / Duplicate /
    /// BlockFate), rendered without `seq`/`time` — identical across drivers
    /// under *lossy* networks, where arrival-side ordering may differ.
    pub fn fate_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            if !is_fate(&r.event) {
                continue;
            }
            let _ = write!(out, "{{\"iter\":{},\"worker\":{},", r.iter, r.worker);
            event_fields(&r.event, &mut out);
            out.push_str("}\n");
        }
        out
    }

    /// Chrome trace-event JSON (the array form): load in Perfetto or
    /// `chrome://tracing`.  One lane (`tid`) per worker plus a master lane;
    /// dispatch→delivery roundtrips and barrier windows render as complete
    /// spans, everything else as instants.  Timestamps are microseconds.
    pub fn chrome_trace(&self) -> String {
        let mut out = String::from("[\n");
        let mut first = true;
        let mut sep = |out: &mut String| {
            if first {
                first = false;
            } else {
                out.push_str(",\n");
            }
        };
        sep(&mut out);
        out.push_str(
            "{\"ph\":\"M\",\"pid\":0,\"tid\":0,\"name\":\"thread_name\",\
             \"args\":{\"name\":\"master\"}}",
        );
        for lane in &self.lanes {
            sep(&mut out);
            let _ = write!(
                out,
                "{{\"ph\":\"M\",\"pid\":0,\"tid\":{},\"name\":\"thread_name\",\
                 \"args\":{{\"name\":\"worker {}\"}}}}",
                lane.worker + 1,
                lane.worker
            );
        }
        let mut open_dispatch: Vec<Option<f64>> = vec![None; self.lanes.len()];
        let mut window_start = 0.0f64;
        for r in &self.records {
            let tid = r.worker + 1; // master (-1) -> 0
            let ts = r.time * 1e6;
            match &r.event {
                TraceEvent::Dispatch => {
                    if r.worker >= 0 {
                        if let Some(slot) = open_dispatch.get_mut(r.worker as usize) {
                            *slot = Some(r.time);
                        }
                    }
                }
                TraceEvent::Delivery { duplicate } => {
                    let start = open_dispatch
                        .get(r.worker.max(0) as usize)
                        .copied()
                        .flatten()
                        .unwrap_or(r.time);
                    if !duplicate {
                        sep(&mut out);
                        let _ = write!(
                            out,
                            "{{\"ph\":\"X\",\"pid\":0,\"tid\":{tid},\"ts\":{},\
                             \"dur\":{},\"name\":\"roundtrip\",\
                             \"args\":{{\"iter\":{}}}}}",
                            start * 1e6,
                            (r.time - start).max(0.0) * 1e6,
                            r.iter
                        );
                    }
                }
                TraceEvent::BarrierClose { gamma, included, abandoned } => {
                    sep(&mut out);
                    let _ = write!(
                        out,
                        "{{\"ph\":\"X\",\"pid\":0,\"tid\":0,\"ts\":{},\"dur\":{},\
                         \"name\":\"barrier\",\"args\":{{\"iter\":{},\"gamma\":{gamma},\
                         \"included\":{included},\"abandoned\":{abandoned}}}}}",
                        window_start * 1e6,
                        (r.time - window_start).max(0.0) * 1e6,
                        r.iter
                    );
                    window_start = r.time;
                }
                ev => {
                    sep(&mut out);
                    let _ = write!(
                        out,
                        "{{\"ph\":\"i\",\"pid\":0,\"tid\":{tid},\"ts\":{ts},\"s\":\"t\",\
                         \"name\":\"{}\",\"args\":{{\"iter\":{}}}}}",
                        event_name(ev),
                        r.iter
                    );
                }
            }
        }
        out.push_str("\n]\n");
        out
    }

    /// Write the JSONL journal to `path`.
    pub fn write_jsonl(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.jsonl())?;
        Ok(())
    }

    /// Write the Chrome trace to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> crate::Result<()> {
        std::fs::write(path, self.chrome_trace())?;
        Ok(())
    }
}

impl TraceSink for JournalSink {
    fn enabled(&self) -> bool {
        true
    }

    fn emit(&mut self, iter: u64, worker: i64, time: f64, event: TraceEvent) {
        match &event {
            TraceEvent::Dispatch => {
                if let Some(lane) = self.lane(worker) {
                    lane.dispatches += 1;
                }
                if worker >= 0 {
                    self.last_dispatch[worker as usize] = Some(time);
                }
            }
            TraceEvent::Delivery { duplicate } => {
                let dup = *duplicate;
                let start = if worker >= 0 {
                    self.last_dispatch.get(worker as usize).copied().flatten()
                } else {
                    None
                };
                if let Some(lane) = self.lane(worker) {
                    lane.deliveries += 1;
                    if !dup {
                        if let Some(t0) = start {
                            lane.latency.record((time - t0).max(0.0));
                        }
                    }
                }
            }
            TraceEvent::Drop { .. } => {
                if let Some(lane) = self.lane(worker) {
                    lane.drops += 1;
                }
            }
            TraceEvent::Duplicate => {
                if let Some(lane) = self.lane(worker) {
                    lane.duplicates += 1;
                }
            }
            TraceEvent::StaleAdmission { .. } => {
                if let Some(lane) = self.lane(worker) {
                    lane.stale += 1;
                }
            }
            TraceEvent::BarrierClose { abandoned, .. } => {
                self.barriers += 1;
                self.abandoned_hist.record(*abandoned as f64);
            }
            _ => {}
        }
        self.records.push(TraceRecord { seq: self.seq, iter, worker, time, event });
        self.seq += 1;
    }

    fn summary(&self) -> Option<TraceSummary> {
        Some(TraceSummary {
            events: self.seq,
            barriers: self.barriers,
            per_worker: self.lanes.clone(),
            abandoned_per_barrier: self.abandoned_hist.clone(),
        })
    }
}

fn event_name(ev: &TraceEvent) -> &'static str {
    match ev {
        TraceEvent::Dispatch => "dispatch",
        TraceEvent::Delivery { .. } => "delivery",
        TraceEvent::Drop { .. } => "drop",
        TraceEvent::Duplicate => "duplicate",
        TraceEvent::BlockFate { .. } => "block_fate",
        TraceEvent::StaleAdmission { .. } => "stale_admission",
        TraceEvent::RetryAttempt { .. } => "retry_attempt",
        TraceEvent::RebalanceCut { .. } => "rebalance_cut",
        TraceEvent::Join => "join",
        TraceEvent::Leave => "leave",
        TraceEvent::Crash => "crash",
        TraceEvent::BarrierClose { .. } => "barrier_close",
        TraceEvent::RecoveryStart { .. } => "recovery_start",
        TraceEvent::RecoveryDone { .. } => "recovery_done",
        TraceEvent::AggFold { .. } => "agg_fold",
        TraceEvent::Forward { .. } => "forward",
        TraceEvent::ServeWindow { .. } => "serve_window",
        TraceEvent::ThetaPublish { .. } => "theta_publish",
    }
}

fn event_fields(ev: &TraceEvent, out: &mut String) {
    let _ = write!(out, "\"event\":\"{}\"", event_name(ev));
    match ev {
        TraceEvent::Delivery { duplicate } => {
            let _ = write!(out, ",\"duplicate\":{duplicate}");
        }
        TraceEvent::Drop { down } => {
            let _ = write!(out, ",\"down\":{down}");
        }
        TraceEvent::BlockFate { delivered_mask, n_blocks } => {
            let _ = write!(out, ",\"delivered_mask\":{delivered_mask},\"n_blocks\":{n_blocks}");
        }
        TraceEvent::StaleAdmission { claimed_blocks } => {
            let _ = write!(out, ",\"claimed_blocks\":{claimed_blocks}");
        }
        TraceEvent::RetryAttempt { attempt, backoff, delivered } => {
            let _ = write!(out, ",\"attempt\":{attempt},\"backoff\":{backoff}");
            let _ = write!(out, ",\"delivered\":{delivered}");
        }
        TraceEvent::RebalanceCut { owners } => {
            out.push_str(",\"owners\":[");
            for (i, o) in owners.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                let _ = write!(out, "{o}");
            }
            out.push(']');
        }
        TraceEvent::BarrierClose { gamma, included, abandoned } => {
            let _ = write!(out, ",\"gamma\":{gamma},\"included\":{included}");
            let _ = write!(out, ",\"abandoned\":{abandoned}");
        }
        TraceEvent::RecoveryStart { policy } => {
            let _ = write!(out, ",\"policy\":\"{policy}\"");
        }
        TraceEvent::RecoveryDone { policy, rollback } => {
            let _ = write!(out, ",\"policy\":\"{policy}\",\"rollback\":{rollback}");
        }
        TraceEvent::AggFold { children } => {
            let _ = write!(out, ",\"children\":{children}");
        }
        TraceEvent::Forward { to, delivered } => {
            let _ = write!(out, ",\"to\":{to},\"delivered\":{delivered}");
        }
        TraceEvent::ServeWindow { offered, admitted, shed, queue } => {
            let _ = write!(out, ",\"offered\":{offered},\"admitted\":{admitted}");
            let _ = write!(out, ",\"shed\":{shed},\"queue\":{queue}");
        }
        TraceEvent::ThetaPublish { epoch } => {
            let _ = write!(out, ",\"epoch\":{epoch}");
        }
        _ => {}
    }
}

fn is_fate(ev: &TraceEvent) -> bool {
    use TraceEvent::{AggFold, BlockFate, Dispatch, Drop, Duplicate, Forward, ServeWindow};
    matches!(
        ev,
        Dispatch
            | Drop { .. }
            | Duplicate
            | BlockFate { .. }
            | AggFold { .. }
            | Forward { .. }
            | ServeWindow { .. }
    )
}

/// Emit the pure fate events of `(worker, iter)`'s roundtrip: `Dispatch`,
/// then whatever the network realization says happens to it.  Both drivers
/// call this single routine at dispatch/plan time with the same
/// `(net, seed, worker, iter, n_blocks)`, so their fate sequences are
/// identical by construction — wall-clock arrival jitter cannot touch
/// them.  Re-realizes via [`NetSpec::realize`] (pure), consuming no shared
/// RNG stream; under an ideal spec only `Dispatch` is emitted.
pub fn emit_roundtrip_fates(
    sink: &mut dyn TraceSink,
    net: &NetSpec,
    seed: u64,
    worker: usize,
    iter: u64,
    n_blocks: usize,
    time: f64,
) {
    let w = worker as i64;
    sink.emit(iter, w, time, TraceEvent::Dispatch);
    if net.is_ideal() {
        return;
    }
    let r = net.realize(seed, worker, iter);
    if r.down_dropped {
        sink.emit(iter, w, time, TraceEvent::Drop { down: true });
        return;
    }
    if n_blocks > 1 {
        let blocks = net.realize_blocks(seed, worker, iter, n_blocks, r.up_dropped, false);
        let fate = TraceEvent::BlockFate {
            delivered_mask: blocks.mask(),
            n_blocks: blocks.len() as u32,
        };
        sink.emit(iter, w, time, fate);
        if !net.admits(blocks) {
            sink.emit(iter, w, time, TraceEvent::Drop { down: false });
            return;
        }
        if r.up_duplicated {
            sink.emit(iter, w, time, TraceEvent::Duplicate);
            let dup = net.realize_blocks(seed, worker, iter, n_blocks, r.up_dropped, true);
            let fate = TraceEvent::BlockFate {
                delivered_mask: dup.mask(),
                n_blocks: dup.len() as u32,
            };
            sink.emit(iter, w, time, fate);
        }
    } else if r.up_dropped {
        sink.emit(iter, w, time, TraceEvent::Drop { down: false });
    } else if r.up_duplicated {
        sink.emit(iter, w, time, TraceEvent::Duplicate);
    }
}

/// Emit the boundary-family events both drivers share: the scheduled
/// elastic leave/join changes landing at `iter` (in schedule order), then —
/// when the boundary re-planned shard ownership — a [`TraceEvent::RebalanceCut`]
/// carrying the post-cut owner snapshot.  Call *after* the boundary handler
/// ran, with the post-boundary ownership.
pub fn emit_boundary(
    sink: &mut dyn TraceSink,
    schedule: &crate::cluster::ElasticSchedule,
    iter: u64,
    rebalanced: bool,
    owners: &[usize],
    time: f64,
) {
    for e in schedule.at(iter) {
        let ev = match e.kind {
            crate::cluster::ElasticKind::Leave => TraceEvent::Leave,
            crate::cluster::ElasticKind::Join => TraceEvent::Join,
        };
        sink.emit(iter, e.worker as i64, time, ev);
    }
    if rebalanced {
        let cut = TraceEvent::RebalanceCut { owners: owners.to_vec() };
        sink.emit(iter, MASTER, time, cut);
    }
}

/// Journal one recovery action on worker `worker` at `iter`: a
/// `RecoveryStart` immediately followed by its `RecoveryDone`.  Both
/// drivers fire this single routine at the same decision points
/// (scheduled leave/join hooks inside the boundary, stochastic crash
/// detection, supervisor respawn), so under scheduled elastic traces the
/// recovery subsequences are byte-identical across drivers by
/// construction (`docs/RECOVERY.md`).
pub fn emit_recovery(
    sink: &mut dyn TraceSink,
    iter: u64,
    worker: usize,
    time: f64,
    policy: &'static str,
    rollback: u64,
) {
    let w = worker as i64;
    sink.emit(iter, w, time, TraceEvent::RecoveryStart { policy });
    sink.emit(iter, w, time, TraceEvent::RecoveryDone { policy, rollback });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_sink_is_disabled_and_summaryless() {
        let mut s = NoopSink;
        assert!(!s.enabled());
        s.emit(0, 0, 0.0, TraceEvent::Dispatch);
        assert!(s.summary().is_none());
    }

    #[test]
    fn journal_stamps_strictly_increasing_seq() {
        let mut s = JournalSink::new();
        s.emit(0, 0, 0.1, TraceEvent::Dispatch);
        s.emit(0, 1, 0.2, TraceEvent::Dispatch);
        s.emit(0, 0, 0.3, TraceEvent::Delivery { duplicate: false });
        let seqs: Vec<u64> = s.records().iter().map(|r| r.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2]);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
    }

    #[test]
    fn agg_events_render_and_join_the_fate_oracle() {
        let mut s = JournalSink::new();
        s.emit(2, 3, 0.1, TraceEvent::AggFold { children: 4 });
        s.emit(2, 3, 0.1, TraceEvent::Forward { to: MASTER, delivered: false });
        let jsonl = s.jsonl();
        assert!(jsonl.contains("\"event\":\"agg_fold\",\"children\":4"), "{jsonl}");
        assert!(jsonl.contains("\"event\":\"forward\",\"to\":-1,\"delivered\":false"), "{jsonl}");
        let fates = s.fate_jsonl();
        assert_eq!(fates.lines().count(), 2, "agg events must join the fate oracle:\n{fates}");
    }

    #[test]
    fn jsonl_normalization_zeroes_time_only() {
        let run = |t0: f64| {
            let mut s = JournalSink::new();
            let close = TraceEvent::BarrierClose { gamma: 3, included: 3, abandoned: 1 };
            s.emit(3, 1, t0, TraceEvent::Dispatch);
            s.emit(3, 1, t0 + 0.5, TraceEvent::Delivery { duplicate: false });
            s.emit(3, MASTER, t0 + 0.75, close);
            s
        };
        let a = run(1.0);
        let b = run(42.0);
        assert_ne!(a.jsonl(), b.jsonl(), "raw journals differ by time");
        assert_eq!(a.jsonl_normalized(), b.jsonl_normalized());
        let line = a.jsonl_normalized();
        assert!(line.starts_with("{\"seq\":0,\"iter\":3,\"worker\":1,\"time\":0,"), "{line}");
        assert!(line.contains("\"gamma\":3,\"included\":3,\"abandoned\":1"), "{line}");
        assert!(line.contains("\"event\":\"barrier_close\""));
    }

    #[test]
    fn fate_filter_keeps_only_pure_fate_events() {
        let mut s = JournalSink::new();
        s.emit(0, 0, 0.0, TraceEvent::Dispatch);
        s.emit(0, 0, 0.1, TraceEvent::Delivery { duplicate: false });
        s.emit(0, 1, 0.0, TraceEvent::Drop { down: false });
        s.emit(0, 2, 0.0, TraceEvent::Duplicate);
        s.emit(0, 2, 0.0, TraceEvent::BlockFate { delivered_mask: 0b101, n_blocks: 3 });
        s.emit(0, MASTER, 0.2, TraceEvent::BarrierClose { gamma: 2, included: 2, abandoned: 0 });
        let fates = s.fate_jsonl();
        assert_eq!(fates.lines().count(), 4);
        assert!(!fates.contains("delivery"));
        assert!(!fates.contains("barrier_close"));
        assert!(!fates.contains("\"seq\""));
        assert!(fates.contains("\"delivered_mask\":5,\"n_blocks\":3"));
    }

    #[test]
    fn summary_rolls_up_lanes_and_latency() {
        let mut s = JournalSink::new();
        s.emit(0, 0, 1.0, TraceEvent::Dispatch);
        s.emit(0, 0, 1.5, TraceEvent::Delivery { duplicate: false });
        s.emit(0, 1, 1.0, TraceEvent::Dispatch);
        s.emit(0, 1, 1.0, TraceEvent::Drop { down: false });
        s.emit(1, 0, 2.0, TraceEvent::Dispatch);
        s.emit(1, 0, 2.25, TraceEvent::Delivery { duplicate: false });
        s.emit(1, 0, 2.3, TraceEvent::StaleAdmission { claimed_blocks: 2 });
        s.emit(1, MASTER, 2.4, TraceEvent::BarrierClose { gamma: 1, included: 1, abandoned: 3 });
        let sum = s.summary().unwrap();
        assert_eq!(sum.events, 8);
        assert_eq!(sum.barriers, 1);
        assert_eq!(sum.per_worker.len(), 2);
        let w0 = &sum.per_worker[0];
        assert_eq!((w0.dispatches, w0.deliveries, w0.stale), (2, 2, 1));
        assert_eq!(w0.latency.count(), 2);
        assert!((w0.latency.mean() - 0.375).abs() < 1e-12);
        assert_eq!(sum.per_worker[1].drops, 1);
        assert_eq!(sum.abandoned_per_barrier.count(), 1);
        assert!((sum.abandoned_per_barrier.mean() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn chrome_trace_has_lanes_spans_and_instants() {
        let mut s = JournalSink::new();
        s.emit(0, 0, 0.25, TraceEvent::Dispatch);
        s.emit(0, 1, 0.25, TraceEvent::Dispatch);
        s.emit(0, 1, 0.375, TraceEvent::Drop { down: false });
        s.emit(0, 0, 0.5, TraceEvent::Delivery { duplicate: false });
        s.emit(0, MASTER, 1.0, TraceEvent::BarrierClose { gamma: 1, included: 1, abandoned: 0 });
        let out = s.chrome_trace();
        assert!(out.starts_with("[\n"));
        assert!(out.ends_with("]\n"));
        assert!(out.contains("\"name\":\"worker 0\""));
        assert!(out.contains("\"name\":\"worker 1\""));
        assert!(out.contains("\"name\":\"master\""));
        assert!(out.contains("\"ph\":\"X\"") && out.contains("\"name\":\"roundtrip\""));
        assert!(out.contains("\"name\":\"barrier\""));
        assert!(out.contains("\"ph\":\"i\"") && out.contains("\"name\":\"drop\""));
        // Roundtrip span: 0.25s dispatch -> 0.5s delivery = 250000µs
        // (times chosen exactly representable in binary).
        assert!(out.contains("\"ts\":250000,\"dur\":250000"), "{out}");
    }

    #[test]
    fn recovery_events_render_policy_and_rollback() {
        let mut s = JournalSink::new();
        emit_recovery(&mut s, 7, 2, 1.5, "checkpoint-restore", 4);
        emit_recovery(&mut s, 9, 0, 2.0, "partial-recovery", 0);
        assert_eq!(s.len(), 4);
        let out = s.jsonl_normalized();
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[0].contains("\"event\":\"recovery_start\""), "{}", lines[0]);
        assert!(lines[0].contains("\"policy\":\"checkpoint-restore\""));
        assert!(lines[0].contains("\"iter\":7,\"worker\":2"));
        assert!(lines[1].contains("\"event\":\"recovery_done\""));
        assert!(lines[1].contains("\"policy\":\"checkpoint-restore\",\"rollback\":4"));
        assert!(lines[3].contains("\"policy\":\"partial-recovery\",\"rollback\":0"));
        // Recovery events are arrival-side, never part of the pure
        // per-message fate subsequence.
        assert!(s.fate_jsonl().is_empty());
    }

    #[test]
    fn fates_match_transport_decisions() {
        use crate::net::{Transport, VirtualTransport};
        // Whatever the transport delivers must appear as a non-dropped
        // fate, and vice versa — the emitter re-realizes the same purity.
        let spec = NetSpec::lossy(0.4);
        let seed = 17;
        let mut sink = JournalSink::new();
        let mut t = VirtualTransport::new(spec.clone(), seed);
        for iter in 0..40u64 {
            for w in 0..3usize {
                emit_roundtrip_fates(&mut sink, &spec, seed, w, iter, 1, 0.0);
                t.send_roundtrip(w, iter, 0.01);
            }
        }
        let mut delivered = std::collections::HashSet::new();
        while let Some(d) = t.poll() {
            if !d.duplicate {
                delivered.insert((d.worker, d.iter));
            }
        }
        let mut traced_delivered = std::collections::HashSet::new();
        let mut dropped = 0usize;
        for r in sink.records() {
            match r.event {
                TraceEvent::Dispatch => {
                    traced_delivered.insert((r.worker as usize, r.iter));
                }
                TraceEvent::Drop { .. } => {
                    traced_delivered.remove(&(r.worker as usize, r.iter));
                    dropped += 1;
                }
                _ => {}
            }
        }
        assert!(dropped > 0, "40% loss produced no Drop fates");
        assert_eq!(traced_delivered, delivered);
    }

    #[test]
    fn ideal_fates_are_dispatch_only() {
        let mut sink = JournalSink::new();
        emit_roundtrip_fates(&mut sink, &NetSpec::ideal(), 9, 2, 7, 4, 1.5);
        assert_eq!(sink.len(), 1);
        assert_eq!(sink.records()[0].event, TraceEvent::Dispatch);
        assert_eq!(sink.records()[0].worker, 2);
        assert_eq!(sink.records()[0].iter, 7);
    }
}
