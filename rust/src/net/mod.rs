//! Unreliable-network transport layer: lossy, delayed, duplicated, and
//! partitionable coordinator↔worker links.
//!
//! The straggler subsystem ([`crate::straggler`]) perturbs *compute*; this
//! module perturbs *communication*.  Yu et al. (arXiv:1810.07766) show that
//! message loss and delay interact with convergence in ways compute-side
//! faults do not, and Qiao et al. (arXiv:1810.07354) motivate treating a
//! dropped update as a first-class perturbation rather than a crash — so
//! network severity is a sweepable input here, exactly like
//! [`crate::straggler::StragglerProfile`] sweeps compute severity.
//!
//! # Pieces
//!
//! * [`LinkModel`] — one link's personality: per-message latency
//!   distribution, drop probability, duplication probability — with
//!   optional per-direction [`LinkDir`] overrides (slow lossy uplink under
//!   a fast clean downlink);
//! * [`NetSpec`] — the whole cluster's network: a default link, per-worker
//!   overrides (asymmetric topologies), and scripted partition windows
//!   ("workers 3..6 unreachable during iterations 40..60");
//! * [`Transport`] / [`VirtualTransport`] — virtual-time delivery for the
//!   discrete-event simulator: sends schedule delivery events, polls pop
//!   them in arrival order;
//! * [`NetShim`] — the threaded runtime's channel wrapper: the master
//!   consults it before every `Work` broadcast and on every `Grad` receipt;
//! * [`NetStats`] — message-level accounting (sent / delivered / dropped /
//!   duplicated), reported per run and per iteration.
//!
//! # Cross-driver determinism
//!
//! Every message's fate is a **pure function** of
//! `(cluster seed, worker, iteration)` — see [`NetSpec::realize`].  No
//! shared RNG stream is consumed in arrival order, so the virtual simulator
//! and the threaded runtime realize *identical* drops, duplicates, and
//! delays for the same spec and seed, and `tests/parity_drivers.rs` can
//! assert equal delivery counts across drivers.  [`NetSpec::ideal`] (the
//! default) short-circuits all sampling and reproduces the pre-transport
//! behaviour bit for bit.
//!
//! See `docs/NETWORK.md` for a scenario cookbook.

pub mod link;
pub mod shim;
pub mod spec;
pub mod transport;

pub use link::{LinkDir, LinkModel, LinkRealization};
pub use shim::{GradFate, NetShim, WorkPlan};
pub use spec::{NetSpec, Partition};
pub use transport::{Delivery, Transport, VirtualTransport};

/// Message-level delivery accounting.  Counts individual messages (a
/// `Work` broadcast and its `Grad` reply are two messages); `duplicated`
/// counts extra delivered copies on top of `delivered`.  Invariant:
/// `sent == delivered + dropped`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    pub sent: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub duplicated: u64,
}

impl NetStats {
    /// Fraction of sent messages that were dropped.
    pub fn drop_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.dropped as f64 / self.sent as f64
        }
    }

    /// Counts accumulated since an `earlier` snapshot (per-iteration deltas
    /// for the recorder).
    pub fn since(&self, earlier: &NetStats) -> NetStats {
        NetStats {
            sent: self.sent - earlier.sent,
            delivered: self.delivered - earlier.delivered,
            dropped: self.dropped - earlier.dropped,
            duplicated: self.duplicated - earlier.duplicated,
        }
    }

    /// Account one Work→Grad roundtrip realization; returns whether the
    /// reply survives to delivery.  `count_dup` lets the sync drivers count
    /// the duplicated reply copy; the async drivers apply at-most-once per
    /// arrival and pass `false`.
    pub fn count_roundtrip(&mut self, r: &LinkRealization, count_dup: bool) -> bool {
        self.sent += 1; // Work
        if r.down_dropped {
            self.dropped += 1;
            return false;
        }
        self.delivered += 1;
        self.sent += 1; // Grad
        if r.up_dropped {
            self.dropped += 1;
            return false;
        }
        self.delivered += 1;
        if count_dup && r.up_duplicated {
            self.duplicated += 1;
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_accounting_invariant() {
        let mut s = NetStats::default();
        assert!(s.count_roundtrip(&LinkRealization::ideal(), true));
        assert_eq!(s.sent, 2);
        assert_eq!(s.delivered, 2);
        assert_eq!(s.dropped, 0);

        let mut r = LinkRealization::ideal();
        r.up_dropped = true;
        assert!(!s.count_roundtrip(&r, true));
        assert_eq!(s.sent, 4);
        assert_eq!(s.delivered, 3);
        assert_eq!(s.dropped, 1);

        assert!(!s.count_roundtrip(&LinkRealization::partitioned(), true));
        assert_eq!(s.sent, 5);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.sent, s.delivered + s.dropped);
    }

    #[test]
    fn duplicate_counted_only_when_asked() {
        let mut r = LinkRealization::ideal();
        r.up_duplicated = true;
        let mut s = NetStats::default();
        assert!(s.count_roundtrip(&r, false));
        assert_eq!(s.duplicated, 0);
        assert!(s.count_roundtrip(&r, true));
        assert_eq!(s.duplicated, 1);
    }

    #[test]
    fn since_gives_deltas() {
        let a = NetStats { sent: 10, delivered: 7, dropped: 3, duplicated: 1 };
        let b = NetStats { sent: 14, delivered: 10, dropped: 4, duplicated: 1 };
        let d = b.since(&a);
        assert_eq!(d, NetStats { sent: 4, delivered: 3, dropped: 1, duplicated: 0 });
    }

    #[test]
    fn drop_rate_handles_empty() {
        assert_eq!(NetStats::default().drop_rate(), 0.0);
        let s = NetStats { sent: 10, delivered: 8, dropped: 2, duplicated: 0 };
        assert!((s.drop_rate() - 0.2).abs() < 1e-12);
    }
}
