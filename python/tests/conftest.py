import os
import sys

# Make `compile` importable when pytest runs from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
