"""L1 correctness: fused optimizer-update kernels vs oracles (Alg. 2 line 3
and its momentum/adam generalizations)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import updates as up
from compile.kernels import ref


def _vecs(l, seed, n=2):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(0, 1, l), jnp.float32) for _ in range(n)]


def _close(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


class TestSgdUpdate:
    def test_matches_ref(self):
        theta, grad = _vecs(64, 0)
        _close(up.sgd_update(theta, grad, 0.05), ref.sgd_update(theta, grad, 0.05))

    def test_zero_eta_identity(self):
        theta, grad = _vecs(32, 1)
        _close(up.sgd_update(theta, grad, 0.0), theta)

    @settings(max_examples=20, deadline=None)
    @given(
        l=st.integers(1, 5000),
        seed=st.integers(0, 2**31 - 1),
        eta=st.floats(0.0, 10.0),
    )
    def test_hypothesis(self, l, seed, eta):
        theta, grad = _vecs(l, seed)
        _close(up.sgd_update(theta, grad, eta), ref.sgd_update(theta, grad, eta))


class TestMomentumUpdate:
    def test_matches_ref(self):
        theta, vel, grad = _vecs(64, 2, 3)
        a = up.momentum_update(theta, vel, grad, 0.05, 0.9)
        b = ref.momentum_update(theta, vel, grad, 0.05, 0.9)
        _close(a[0], b[0])
        _close(a[1], b[1])

    def test_zero_mu_is_sgd(self):
        theta, vel, grad = _vecs(32, 3, 3)
        t2, _ = up.momentum_update(theta, vel, grad, 0.1, 0.0)
        _close(t2, ref.sgd_update(theta, grad, 0.1))

    @settings(max_examples=15, deadline=None)
    @given(
        l=st.integers(1, 2048),
        seed=st.integers(0, 2**31 - 1),
        mu=st.floats(0.0, 0.999),
    )
    def test_hypothesis(self, l, seed, mu):
        theta, vel, grad = _vecs(l, seed, 3)
        a = up.momentum_update(theta, vel, grad, 0.01, mu)
        b = ref.momentum_update(theta, vel, grad, 0.01, mu)
        _close(a[0], b[0])
        _close(a[1], b[1])

    def test_multi_step_composition(self):
        theta, vel, grad1 = _vecs(128, 4, 3)
        (grad2,) = _vecs(128, 5, 1)
        ka, kb = (theta, vel), (theta, vel)
        for g in (grad1, grad2, grad1):
            ka = up.momentum_update(ka[0], ka[1], g, 0.05, 0.9)
            kb = ref.momentum_update(kb[0], kb[1], g, 0.05, 0.9)
        _close(ka[0], kb[0])
        _close(ka[1], kb[1])


class TestAdamUpdate:
    def test_matches_ref(self):
        theta, m, v, grad = _vecs(64, 6, 4)
        v = jnp.abs(v)
        a = up.adam_update(theta, m, v, grad, 1e-3, 0.9, 0.999, 1e-8, 3.0)
        b = ref.adam_update(theta, m, v, grad, 1e-3, 0.9, 0.999, 1e-8, 3.0)
        for x, y in zip(a, b):
            _close(x, y)

    @settings(max_examples=15, deadline=None)
    @given(
        l=st.integers(1, 2048),
        seed=st.integers(0, 2**31 - 1),
        t=st.integers(1, 1000),
    )
    def test_hypothesis(self, l, seed, t):
        theta, m, v, grad = _vecs(l, seed, 4)
        v = jnp.abs(v)
        a = up.adam_update(theta, m, v, grad, 1e-3, 0.9, 0.999, 1e-8, float(t))
        b = ref.adam_update(theta, m, v, grad, 1e-3, 0.9, 0.999, 1e-8, float(t))
        for x, y in zip(a, b):
            _close(x, y, tol=1e-4)

    def test_multi_step_training_descends(self):
        """3 adam steps on a quadratic reduce the objective."""
        rng = np.random.default_rng(7)
        target = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
        theta = jnp.zeros(64, jnp.float32)
        m = jnp.zeros(64, jnp.float32)
        v = jnp.zeros(64, jnp.float32)
        loss0 = float(jnp.sum((theta - target) ** 2))
        for t in range(1, 4):
            grad = 2.0 * (theta - target)
            theta, m, v = up.adam_update(
                theta, m, v, grad, 0.1, 0.9, 0.999, 1e-8, float(t)
            )
        assert float(jnp.sum((theta - target) ** 2)) < loss0
