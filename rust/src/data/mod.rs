//! Data substrate: synthetic problem generation, sharding, the pure-rust
//! compute mirror, the exact ridge solver, and the LM token corpus.
//!
//! The paper's experiments need a dataset with a *known* optimum so the
//! convergence theory (§3.3) can be validated exactly; [`KrrProblem`]
//! generates kernel-feature regression data with a planted parameter vector
//! and solves the normal equations for `θ*` (DESIGN.md §3).

pub mod checkpoint;
pub mod corpus;
pub mod native;
pub mod shard;
pub mod solver;
pub mod synth;

pub use checkpoint::Checkpoint;

pub use shard::{
    plan_rebalance, plan_rebalance_weighted, OwnershipMap, RebalancePlan, Shard, ShardMove,
};
pub use synth::{KrrProblem, KrrProblemSpec};

/// Result of one worker-side gradient computation.
#[derive(Clone, Debug)]
pub struct GradResult {
    /// Flat gradient (KRR: length `l`; LM: all parameter tensors flattened).
    pub grad: Vec<f32>,
    /// Shard loss contribution: KRR sum of squared residuals, LM summed NLL.
    pub loss_sum: Option<f64>,
    /// Number of examples that contributed (the paper's ζ).
    pub examples: usize,
}

impl GradResult {
    /// A result holding no allocation yet — the starting state of a
    /// reusable output slot for [`ComputePool::grad_into`].
    pub fn empty() -> GradResult {
        GradResult {
            grad: Vec::new(),
            loss_sum: None,
            examples: 0,
        }
    }
}

/// Anything that can compute per-worker gradients for the coordinator.
///
/// Implementations: [`native::NativeKrrPool`] (pure rust, used by tests and
/// the straggler benches), [`crate::worker::compute::XlaKrrPool`] (PJRT
/// artifacts — the production path), [`crate::lm::LmPool`] (transformer).
///
/// The required method is [`ComputePool::grad_into`], which writes into a
/// caller-owned [`GradResult`]: the drivers keep a scratch arena of such
/// slots and reuse them every iteration, so the steady-state hot path
/// allocates nothing (see `docs/PERF.md`).  [`ComputePool::grad`] is the
/// allocating convenience wrapper for tests and one-shot callers.
pub trait ComputePool {
    /// Parameter dimension.
    fn dim(&self) -> usize;
    /// Number of workers (the paper's M).
    fn n_workers(&self) -> usize;
    /// Compute worker `w`'s gradient at `theta` for iteration `iter`,
    /// writing into `out` (grad buffer resized/overwritten in place —
    /// reusing `out` across calls avoids per-call allocation).
    fn grad_into(
        &mut self,
        w: usize,
        theta: &[f32],
        iter: u64,
        out: &mut GradResult,
    ) -> crate::Result<()>;
    /// Allocating convenience wrapper around [`ComputePool::grad_into`].
    fn grad(&mut self, w: usize, theta: &[f32], iter: u64) -> crate::Result<GradResult> {
        let mut out = GradResult::empty();
        self.grad_into(w, theta, iter, &mut out)?;
        Ok(out)
    }
    /// Examples per worker (the paper's ζ).
    fn shard_examples(&self, w: usize) -> usize;
}
