//! Dynamic value tree shared by the TOML and JSON parsers.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed configuration/manifest value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    Table(BTreeMap<String, Value>),
}

impl Value {
    pub fn empty_table() -> Value {
        Value::Table(BTreeMap::new())
    }

    /// Navigate a dotted path (`"mode.gamma"`). Returns None if absent.
    pub fn get(&self, path: &str) -> Option<&Value> {
        let mut cur = self;
        for part in path.split('.') {
            match cur {
                Value::Table(map) => cur = map.get(part)?,
                _ => return None,
            }
        }
        Some(cur)
    }

    /// Insert at a dotted path, creating intermediate tables.
    pub fn set(&mut self, path: &str, v: Value) -> Result<()> {
        let mut cur = self;
        let parts: Vec<&str> = path.split('.').collect();
        for (i, part) in parts.iter().enumerate() {
            let map = match cur {
                Value::Table(map) => map,
                _ => {
                    return Err(Error::Config(format!(
                        "set '{path}': '{}' is not a table",
                        parts[..i].join(".")
                    )))
                }
            };
            if i == parts.len() - 1 {
                map.insert((*part).to_string(), v);
                return Ok(());
            }
            cur = map
                .entry((*part).to_string())
                .or_insert_with(Value::empty_table);
        }
        unreachable!()
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_table(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Table(t) => Some(t),
            _ => None,
        }
    }

    // --- "required" accessors with config-flavored errors ---------------

    pub fn req_str(&self, path: &str) -> Result<&str> {
        self.get(path)
            .and_then(Value::as_str)
            .ok_or_else(|| Error::Config(format!("missing string key '{path}'")))
    }

    pub fn req_usize(&self, path: &str) -> Result<usize> {
        self.get(path)
            .and_then(Value::as_usize)
            .ok_or_else(|| Error::Config(format!("missing integer key '{path}'")))
    }

    pub fn req_f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Config(format!("missing float key '{path}'")))
    }

    // --- "optional with default" accessors -------------------------------

    pub fn opt_str<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn opt_usize(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn opt_u64(&self, path: &str, default: u64) -> u64 {
        self.get(path)
            .and_then(Value::as_i64)
            .map(|i| i as u64)
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn opt_bool(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dotted_get_set() {
        let mut v = Value::empty_table();
        v.set("a.b.c", Value::Int(3)).unwrap();
        assert_eq!(v.get("a.b.c"), Some(&Value::Int(3)));
        assert!(v.get("a.b.d").is_none());
        assert!(v.get("a.b.c.e").is_none());
    }

    #[test]
    fn set_through_scalar_fails() {
        let mut v = Value::empty_table();
        v.set("a", Value::Int(1)).unwrap();
        assert!(v.set("a.b", Value::Int(2)).is_err());
    }

    #[test]
    fn coercions() {
        assert_eq!(Value::Float(3.0).as_i64(), Some(3));
        assert_eq!(Value::Float(3.5).as_i64(), None);
        assert_eq!(Value::Int(3).as_f64(), Some(3.0));
        assert_eq!(Value::Str("x".into()).as_bool(), None);
    }

    #[test]
    fn defaults() {
        let v = Value::empty_table();
        assert_eq!(v.opt_usize("nope", 7), 7);
        assert_eq!(v.opt_str("nope", "d"), "d");
        assert!(v.req_f64("nope").is_err());
    }
}
