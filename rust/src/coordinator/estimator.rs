//! Algorithm 1: the least number of slave machines the master must wait for.
//!
//! Lemma 3.1 (finite-population correction) + Lemma 3.2 (normal
//! approximation) give the sample size needed for the partial gradient's
//! mean to sit within relative error ξ of the full-gradient mean with
//! confidence 1−α:
//!
//! ```text
//!   n = N·u²_{α/2}·s² / (Δ²·N + u²_{α/2}·s²),   Δ = |ξ·Z̄|
//! ```
//!
//! which the paper upper-bounds (worst case s² vs (ξZ̄)², §3.2) by the
//! distribution-free
//!
//! ```text
//!   n ≤ N·u² / (ξ²·N + u²)        ⇒   γ = ⌈ n / ζ ⌉.
//! ```
//!
//! [`estimate_gamma`] implements the distribution-free form (Algorithm 1);
//! [`AdaptiveEstimator`] implements the sharper variance-aware form as the
//! DESIGN.md §6 ablation, feeding it the observed per-worker gradient
//! scatter.

use crate::math::quantile::normal_quantile;
use crate::math::stats::OnlineStats;
use crate::{Error, Result};

/// Confidence/accuracy parameters of Algorithm 1.
#[derive(Clone, Copy, Debug)]
pub struct EstimatorParams {
    /// Significance level α (confidence = 1−α).
    pub alpha: f64,
    /// Relative error bound ξ.
    pub xi: f64,
}

impl EstimatorParams {
    pub fn u_half_alpha(&self) -> f64 {
        normal_quantile(1.0 - self.alpha / 2.0)
    }
}

/// Algorithm 1: minimal sample size `n` (examples).
pub fn estimate_sample_size(n_total: usize, p: EstimatorParams) -> Result<f64> {
    if !(0.0 < p.alpha && p.alpha < 1.0) {
        return Err(Error::Config(format!("alpha must be in (0,1), got {}", p.alpha)));
    }
    if p.xi <= 0.0 {
        return Err(Error::Config(format!("xi must be > 0, got {}", p.xi)));
    }
    let u = p.u_half_alpha();
    let n = n_total as f64;
    Ok(n * u * u / (p.xi * p.xi * n + u * u))
}

/// Algorithm 1: minimal machines `γ = ⌈n/ζ⌉`, clamped to `[1, m]`.
pub fn estimate_gamma(n_total: usize, zeta: usize, m: usize, p: EstimatorParams) -> Result<usize> {
    if zeta == 0 || m == 0 {
        return Err(Error::Config("zeta and m must be positive".into()));
    }
    let n = estimate_sample_size(n_total, p)?;
    let gamma = (n / zeta as f64).ceil() as usize;
    Ok(gamma.clamp(1, m))
}

/// Variance-aware re-estimation (DESIGN.md §6 ablation).
///
/// Feeds on per-worker gradient snapshots each iteration: treats each
/// worker's gradient as a sample mean of ζ per-example gradients and
/// estimates the per-example scatter `s²` and overall mean magnitude `Z̄`
/// from the cross-worker scatter, then applies Lemma 3.2's exact form.
#[derive(Debug)]
pub struct AdaptiveEstimator {
    params: EstimatorParams,
    n_total: usize,
    zeta: usize,
    m: usize,
    /// Per-coordinate-norm statistics across workers this window.
    scatter: OnlineStats,
    mean_norm: OnlineStats,
    /// Reusable mean-gradient buffer (zero allocations per observation
    /// after warmup).
    mean_buf: Vec<f64>,
}

impl AdaptiveEstimator {
    pub fn new(n_total: usize, zeta: usize, m: usize, params: EstimatorParams) -> Self {
        AdaptiveEstimator {
            params,
            n_total,
            zeta,
            m,
            scatter: OnlineStats::new(),
            mean_norm: OnlineStats::new(),
            mean_buf: Vec::new(),
        }
    }

    /// Observe one iteration's included worker gradients.
    pub fn observe(&mut self, grads: &[&[f32]]) {
        self.observe_iter(grads.iter().copied(), grads.len());
    }

    /// Observe included gradients straight from a driver's result slots —
    /// no `Vec<&[f32]>` view buffer needed on the hot path.
    pub fn observe_results(&mut self, grads: &[crate::data::GradResult]) {
        self.observe_iter(grads.iter().map(|g| g.grad.as_slice()), grads.len());
    }

    fn observe_iter<'a, I>(&mut self, grads: I, k: usize)
    where
        I: Iterator<Item = &'a [f32]> + Clone,
    {
        if k < 2 {
            return;
        }
        // Mean gradient (reused buffer; dim fixed per run).
        let mut dim = 0usize;
        let mean = &mut self.mean_buf;
        for (i, g) in grads.clone().enumerate() {
            if i == 0 {
                dim = g.len();
                mean.resize(dim, 0.0);
                mean.fill(0.0);
            }
            for (m, &v) in mean.iter_mut().zip(g.iter()) {
                *m += v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= k as f64;
        }
        let mean_sq: f64 = mean.iter().map(|v| v * v).sum::<f64>() / dim as f64;
        self.mean_norm.push(mean_sq.sqrt());

        // Cross-worker variance of the shard means, averaged over coords.
        let mut var = 0.0f64;
        for g in grads {
            let mut d2 = 0.0;
            for (m, &v) in mean.iter().zip(g.iter()) {
                let d = v as f64 - *m;
                d2 += d * d;
            }
            var += d2 / dim as f64;
        }
        var /= (k - 1).max(1) as f64;
        // Worker mean over ζ examples with FPC: Var(mean) = s²/ζ · (N−ζ)/(N−1)
        // ⇒ s² ≈ var · ζ · (N−1)/(N−ζ).
        let n = self.n_total as f64;
        let fpc = (n - self.zeta as f64).max(1.0) / (n - 1.0);
        self.scatter.push(var * self.zeta as f64 / fpc);
    }

    /// Current γ estimate from the observed statistics (falls back to the
    /// distribution-free bound until enough windows are seen).
    pub fn gamma(&self) -> Result<usize> {
        if self.scatter.count() < 2 || self.mean_norm.mean() <= 0.0 {
            return estimate_gamma(self.n_total, self.zeta, self.m, self.params);
        }
        let u = self.params.u_half_alpha();
        let s2 = self.scatter.mean();
        let delta = (self.params.xi * self.mean_norm.mean()).max(1e-12);
        let n_tot = self.n_total as f64;
        let n = n_tot * u * u * s2 / (delta * delta * n_tot + u * u * s2);
        Ok(((n / self.zeta as f64).ceil() as usize).clamp(1, self.m))
    }

    /// Reset window statistics (called every `window` iterations).
    pub fn reset_window(&mut self) {
        self.scatter = OnlineStats::new();
        self.mean_norm = OnlineStats::new();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_formula() {
        // Hand-computed: N = 32768, α = 0.05 (u ≈ 1.95996), ξ = 0.05.
        // n = N u² / (ξ² N + u²) = 32768·3.8415 / (0.0025·32768 + 3.8415)
        //   ≈ 125888.5 / 85.76 ≈ 1467.9  ⇒ ζ=2048 → γ = 1.
        let p = EstimatorParams { alpha: 0.05, xi: 0.05 };
        let n = estimate_sample_size(32768, p).unwrap();
        assert!((n - 1467.9).abs() < 1.0, "n={n}");
        assert_eq!(estimate_gamma(32768, 2048, 16, p).unwrap(), 1);
        // Tighter ξ needs more machines.
        let tight = EstimatorParams { alpha: 0.05, xi: 0.01 };
        let n2 = estimate_sample_size(32768, tight).unwrap();
        assert!(n2 > n);
        let g2 = estimate_gamma(32768, 2048, 16, tight).unwrap();
        assert!(g2 > 1);
    }

    #[test]
    fn monotone_in_alpha_and_xi() {
        let base = EstimatorParams { alpha: 0.05, xi: 0.05 };
        let stricter_alpha = EstimatorParams { alpha: 0.01, xi: 0.05 };
        let looser_xi = EstimatorParams { alpha: 0.05, xi: 0.10 };
        let n_total = 1_000_000;
        let n0 = estimate_sample_size(n_total, base).unwrap();
        assert!(estimate_sample_size(n_total, stricter_alpha).unwrap() > n0);
        assert!(estimate_sample_size(n_total, looser_xi).unwrap() < n0);
    }

    #[test]
    fn sample_size_below_population() {
        for &n_total in &[100usize, 10_000, 10_000_000] {
            let p = EstimatorParams { alpha: 0.05, xi: 0.01 };
            let n = estimate_sample_size(n_total, p).unwrap();
            assert!(n <= n_total as f64);
            assert!(n > 0.0);
        }
    }

    #[test]
    fn gamma_clamped_to_machines() {
        // Absurdly tight requirements cap at m.
        let p = EstimatorParams { alpha: 1e-6, xi: 1e-6 };
        assert_eq!(estimate_gamma(100_000, 10, 8, p).unwrap(), 8);
    }

    #[test]
    fn rejects_bad_params() {
        assert!(estimate_sample_size(100, EstimatorParams { alpha: 0.0, xi: 0.1 }).is_err());
        assert!(estimate_sample_size(100, EstimatorParams { alpha: 0.1, xi: 0.0 }).is_err());
        assert!(estimate_gamma(100, 0, 4, EstimatorParams { alpha: 0.1, xi: 0.1 }).is_err());
    }

    #[test]
    fn adaptive_tracks_low_variance() {
        // Identical worker gradients ⇒ zero scatter ⇒ γ collapses to 1.
        let p = EstimatorParams { alpha: 0.05, xi: 0.05 };
        let mut est = AdaptiveEstimator::new(4096, 256, 16, p);
        let g = vec![1.0f32; 32];
        for _ in 0..5 {
            est.observe(&[&g, &g, &g, &g]);
        }
        assert_eq!(est.gamma().unwrap(), 1);
    }

    #[test]
    fn adaptive_grows_with_scatter() {
        let p = EstimatorParams { alpha: 0.05, xi: 0.02 };
        let mut est = AdaptiveEstimator::new(4096, 256, 16, p);
        // Wildly different worker gradients around a small mean.
        let g1 = vec![5.0f32; 32];
        let g2 = vec![-4.8f32; 32];
        let g3 = vec![4.9f32; 32];
        let g4 = vec![-5.1f32; 32];
        for _ in 0..5 {
            est.observe(&[&g1, &g2, &g3, &g4]);
        }
        let g = est.gamma().unwrap();
        assert!(g > 4, "gamma={g}");
    }
}
