//! Per-iteration stochastic delay distributions.
//!
//! Parameters follow the straggler literature: lognormal bodies with
//! occasional Pareto tails reproduce the MapReduce outlier measurements;
//! `Bimodal` captures "mostly fine, sometimes 10× slow" nodes; `Trace`
//! replays a recorded latency series (see [`super::trace`]).

use crate::util::rng::Pcg64;

/// Extra latency (seconds) added to a worker's compute time each iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum DelayModel {
    /// No injected delay.
    None,
    /// Fixed extra delay.
    Constant { secs: f64 },
    /// Uniform in `[lo, hi)`.
    Uniform { lo: f64, hi: f64 },
    /// `exp(N(mu, sigma))` seconds — the canonical straggler body.
    LogNormal { mu: f64, sigma: f64 },
    /// Pareto with minimum `scale` and tail index `alpha` (heavy tail).
    Pareto { scale: f64, alpha: f64 },
    /// With probability `p_slow`, a `slow` delay; otherwise `fast`.
    Bimodal { p_slow: f64, fast: f64, slow: f64 },
    /// Exponential with the given rate (mean = 1/rate).
    Exponential { rate: f64 },
    /// Replay recorded samples, cycling.
    Trace { samples: std::sync::Arc<Vec<f64>>, cursor_seed: u64 },
}

impl DelayModel {
    /// Sample one delay.  `Trace` uses the RNG only to de-phase workers.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match self {
            DelayModel::None => 0.0,
            DelayModel::Constant { secs } => *secs,
            DelayModel::Uniform { lo, hi } => rng.uniform(*lo, *hi),
            DelayModel::LogNormal { mu, sigma } => rng.lognormal(*mu, *sigma),
            DelayModel::Pareto { scale, alpha } => rng.pareto(*scale, *alpha),
            DelayModel::Bimodal { p_slow, fast, slow } => {
                if rng.next_f64() < *p_slow {
                    *slow
                } else {
                    *fast
                }
            }
            DelayModel::Exponential { rate } => rng.exponential(*rate),
            DelayModel::Trace { samples, .. } => {
                if samples.is_empty() {
                    0.0
                } else {
                    samples[rng.below(samples.len() as u64) as usize]
                }
            }
        }
    }

    /// Analytic (or sampled) mean of the distribution, for reporting.
    pub fn mean(&self) -> f64 {
        match self {
            DelayModel::None => 0.0,
            DelayModel::Constant { secs } => *secs,
            DelayModel::Uniform { lo, hi } => 0.5 * (lo + hi),
            DelayModel::LogNormal { mu, sigma } => (mu + sigma * sigma / 2.0).exp(),
            DelayModel::Pareto { scale, alpha } => {
                if *alpha > 1.0 {
                    alpha * scale / (alpha - 1.0)
                } else {
                    f64::INFINITY
                }
            }
            DelayModel::Bimodal { p_slow, fast, slow } => {
                p_slow * slow + (1.0 - p_slow) * fast
            }
            DelayModel::Exponential { rate } => 1.0 / rate,
            DelayModel::Trace { samples, .. } => {
                if samples.is_empty() {
                    0.0
                } else {
                    samples.iter().sum::<f64>() / samples.len() as f64
                }
            }
        }
    }

    /// Parse from config strings (see `config::schema`).
    pub fn from_kind(kind: &str, cfg: &crate::config::Value) -> crate::Result<DelayModel> {
        use crate::Error;
        Ok(match kind {
            "none" => DelayModel::None,
            "constant" => DelayModel::Constant {
                secs: cfg.opt_f64("secs", 0.01),
            },
            "uniform" => DelayModel::Uniform {
                lo: cfg.opt_f64("lo", 0.0),
                hi: cfg.opt_f64("hi", 0.02),
            },
            "lognormal" => DelayModel::LogNormal {
                mu: cfg.opt_f64("mu", -4.0),
                sigma: cfg.opt_f64("sigma", 1.0),
            },
            "pareto" => DelayModel::Pareto {
                scale: cfg.opt_f64("scale", 0.005),
                alpha: cfg.opt_f64("alpha", 1.5),
            },
            "bimodal" => DelayModel::Bimodal {
                p_slow: cfg.opt_f64("p_slow", 0.05),
                fast: cfg.opt_f64("fast", 0.001),
                slow: cfg.opt_f64("slow", 0.1),
            },
            "exponential" => DelayModel::Exponential {
                rate: cfg.opt_f64("rate", 100.0),
            },
            other => {
                return Err(Error::Config(format!("unknown delay model '{other}'")));
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::OnlineStats;

    fn sampled_mean(m: &DelayModel, n: usize) -> f64 {
        let mut rng = Pcg64::seeded(99);
        let mut st = OnlineStats::new();
        for _ in 0..n {
            st.push(m.sample(&mut rng));
        }
        st.mean()
    }

    #[test]
    fn sampled_means_match_analytic() {
        let cases = vec![
            DelayModel::Constant { secs: 0.02 },
            DelayModel::Uniform { lo: 0.0, hi: 0.1 },
            DelayModel::LogNormal { mu: -3.0, sigma: 0.5 },
            DelayModel::Bimodal { p_slow: 0.1, fast: 0.001, slow: 0.05 },
            DelayModel::Exponential { rate: 50.0 },
        ];
        for m in cases {
            let got = sampled_mean(&m, 40_000);
            let want = m.mean();
            assert!(
                (got - want).abs() / want.max(1e-9) < 0.08,
                "{m:?}: sampled {got} vs analytic {want}"
            );
        }
    }

    #[test]
    fn pareto_mean_finite_iff_alpha_gt_1() {
        assert!(DelayModel::Pareto { scale: 1.0, alpha: 0.9 }.mean().is_infinite());
        let m = DelayModel::Pareto { scale: 0.01, alpha: 2.5 };
        assert!((m.mean() - 0.01 * 2.5 / 1.5).abs() < 1e-12);
    }

    #[test]
    fn samples_nonnegative() {
        let mut rng = Pcg64::seeded(5);
        let models = [
            DelayModel::LogNormal { mu: -2.0, sigma: 2.0 },
            DelayModel::Pareto { scale: 0.001, alpha: 1.1 },
            DelayModel::Exponential { rate: 10.0 },
        ];
        for m in &models {
            for _ in 0..1000 {
                assert!(m.sample(&mut rng) >= 0.0);
            }
        }
    }

    #[test]
    fn trace_cycles_samples() {
        let m = DelayModel::Trace {
            samples: std::sync::Arc::new(vec![0.1, 0.2, 0.3]),
            cursor_seed: 0,
        };
        let mut rng = Pcg64::seeded(1);
        for _ in 0..50 {
            let s = m.sample(&mut rng);
            assert!([0.1, 0.2, 0.3].contains(&s));
        }
    }

    #[test]
    fn from_kind_parses() {
        let cfg = crate::config::toml::parse("sigma = 2.0\nmu = -1.0").unwrap();
        let m = DelayModel::from_kind("lognormal", &cfg).unwrap();
        assert_eq!(m, DelayModel::LogNormal { mu: -1.0, sigma: 2.0 });
        assert!(DelayModel::from_kind("nope", &cfg).is_err());
    }
}
