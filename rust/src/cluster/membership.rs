//! Master-side membership view: which workers are alive, crashed, or late.
//!
//! The hybrid barrier needs this to (a) size `γ` against *alive* workers and
//! (b) detect the BSP stall condition when a worker dies.
//!
//! **Elastic membership** (see [`crate::cluster::ElasticSchedule`]): the
//! view also carries a monotonically increasing **epoch** that bumps on
//! every liveness transition (crash, scheduled leave, rejoin, scheduled
//! join).  Both drivers use the epoch to decide when a shard rebalance is
//! due ([`crate::data::plan_rebalance`]), so "membership changed" means the
//! same thing in virtual and real timing mode.

use crate::straggler::FailureEvent;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    Alive,
    Down,
}

/// Tracks per-worker liveness plus abandon accounting.
#[derive(Clone, Debug)]
pub struct Membership {
    states: Vec<WorkerState>,
    /// Results abandoned per worker (arrived after the barrier closed).
    abandoned: Vec<u64>,
    /// Results contributed per worker.
    contributed: Vec<u64>,
    crashes: u64,
    rejoins: u64,
    /// Bumped on every liveness transition; drives rebalance scheduling.
    epoch: u64,
}

impl Membership {
    pub fn new(workers: usize) -> Membership {
        Membership {
            states: vec![WorkerState::Alive; workers],
            abandoned: vec![0; workers],
            contributed: vec![0; workers],
            crashes: 0,
            rejoins: 0,
            epoch: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    pub fn alive(&self) -> usize {
        self.states
            .iter()
            .filter(|s| **s == WorkerState::Alive)
            .count()
    }

    pub fn is_alive(&self, w: usize) -> bool {
        self.states[w] == WorkerState::Alive
    }

    /// Per-worker liveness mask (input to [`crate::data::plan_rebalance`]).
    pub fn alive_mask(&self) -> Vec<bool> {
        self.states.iter().map(|s| *s == WorkerState::Alive).collect()
    }

    /// Membership epoch: bumps on every liveness transition.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    fn transition(&mut self, w: usize, to: WorkerState) -> bool {
        if self.states[w] != to {
            self.states[w] = to;
            self.epoch += 1;
            true
        } else {
            false
        }
    }

    /// Record a failure-model event observed for worker `w`.
    pub fn observe(&mut self, w: usize, ev: FailureEvent) {
        match ev {
            FailureEvent::Crashed => {
                self.transition(w, WorkerState::Down);
                self.crashes += 1;
            }
            FailureEvent::Rejoined => {
                self.transition(w, WorkerState::Alive);
                self.rejoins += 1;
            }
            FailureEvent::Down => {
                self.transition(w, WorkerState::Down);
            }
            FailureEvent::Healthy | FailureEvent::TransientDrop => {
                self.transition(w, WorkerState::Alive);
            }
        }
    }

    pub fn mark_down(&mut self, w: usize) {
        if self.transition(w, WorkerState::Down) {
            self.crashes += 1;
        }
    }

    /// Re-admit worker `w` (a scheduled join, or a supervisor respawn
    /// observed out-of-band).  Counts as a rejoin only on a real
    /// Down → Alive transition, so joining an already-alive worker — e.g.
    /// a worker rejoining in the same iteration it was declared dead after
    /// its leave was already processed — is a no-op.
    pub fn mark_alive(&mut self, w: usize) {
        if self.transition(w, WorkerState::Alive) {
            self.rejoins += 1;
        }
    }

    pub fn record_contribution(&mut self, w: usize) {
        self.contributed[w] += 1;
    }

    pub fn record_abandoned(&mut self, w: usize) {
        self.abandoned[w] += 1;
    }

    pub fn total_abandoned(&self) -> u64 {
        self.abandoned.iter().sum()
    }

    pub fn total_contributed(&self) -> u64 {
        self.contributed.iter().sum()
    }

    pub fn crashes(&self) -> u64 {
        self.crashes
    }

    pub fn rejoins(&self) -> u64 {
        self.rejoins
    }

    /// Per-worker (contributed, abandoned) counters, for fairness reports.
    pub fn per_worker(&self) -> Vec<(u64, u64)> {
        self.contributed
            .iter()
            .zip(&self.abandoned)
            .map(|(&c, &a)| (c, a))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_and_rejoin_counts() {
        let mut m = Membership::new(3);
        assert_eq!(m.alive(), 3);
        m.observe(1, FailureEvent::Crashed);
        assert_eq!(m.alive(), 2);
        assert!(!m.is_alive(1));
        m.observe(1, FailureEvent::Down);
        assert_eq!(m.crashes(), 1);
        m.observe(1, FailureEvent::Rejoined);
        assert_eq!(m.alive(), 3);
        assert_eq!(m.rejoins(), 1);
    }

    #[test]
    fn abandon_accounting() {
        let mut m = Membership::new(2);
        m.record_contribution(0);
        m.record_contribution(0);
        m.record_abandoned(1);
        assert_eq!(m.total_contributed(), 2);
        assert_eq!(m.total_abandoned(), 1);
        assert_eq!(m.per_worker(), vec![(2, 0), (0, 1)]);
    }

    #[test]
    fn mark_down_idempotent_on_crash_count() {
        let mut m = Membership::new(2);
        m.mark_down(0);
        m.mark_down(0);
        assert_eq!(m.crashes(), 1);
        assert_eq!(m.alive(), 1);
    }

    #[test]
    fn epoch_bumps_only_on_transitions() {
        let mut m = Membership::new(3);
        assert_eq!(m.epoch(), 0);
        m.observe(0, FailureEvent::Healthy); // already alive: no bump
        assert_eq!(m.epoch(), 0);
        m.mark_down(1);
        assert_eq!(m.epoch(), 1);
        m.observe(1, FailureEvent::Down); // already down: no bump
        assert_eq!(m.epoch(), 1);
        m.mark_alive(1);
        assert_eq!(m.epoch(), 2);
        assert_eq!(m.rejoins(), 1);
    }

    #[test]
    fn rejoin_same_iteration_as_declared_dead() {
        // A worker declared dead and re-admitted within the same iteration
        // boundary nets out alive, with both the crash and the rejoin
        // counted and two epoch bumps (so a rebalance is still triggered).
        let mut m = Membership::new(2);
        m.mark_down(0);
        m.mark_alive(0);
        assert!(m.is_alive(0));
        assert_eq!(m.crashes(), 1);
        assert_eq!(m.rejoins(), 1);
        assert_eq!(m.epoch(), 2);
        // Re-admitting an alive worker is a no-op.
        m.mark_alive(0);
        assert_eq!(m.rejoins(), 1);
        assert_eq!(m.epoch(), 2);
    }

    #[test]
    fn alive_mask_matches_states() {
        let mut m = Membership::new(4);
        m.mark_down(2);
        assert_eq!(m.alive_mask(), vec![true, true, false, true]);
    }
}
