//! One link's personality and its per-message realization.

use crate::straggler::DelayModel;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// A coordinator↔worker link's behaviour.  Applied to both directions of a
/// roundtrip (each direction samples its own fate and delay).  Reordering
/// is emergent: latency variance lets a later-sent message overtake an
/// earlier one, and duplication delivers the extra `Grad` copy `dup_lag`
/// seconds behind the primary.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way network latency distribution (virtual seconds), sampled per
    /// message.
    pub latency: DelayModel,
    /// Probability each message is silently lost.
    pub drop_prob: f64,
    /// Probability a delivered `Grad` reply arrives twice.
    pub dup_prob: f64,
    /// How far behind the primary the duplicate copy arrives (seconds).
    pub dup_lag: f64,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::ideal()
    }
}

impl LinkModel {
    /// Perfect link: zero latency, no loss, no duplication.
    pub fn ideal() -> LinkModel {
        LinkModel {
            latency: DelayModel::None,
            drop_prob: 0.0,
            dup_prob: 0.0,
            dup_lag: 0.0,
        }
    }

    /// Zero-latency link that loses each message with probability `p`.
    pub fn lossy(p: f64) -> LinkModel {
        LinkModel { drop_prob: p, ..LinkModel::ideal() }
    }

    /// Does this link perturb traffic at all?
    pub fn is_ideal(&self) -> bool {
        self.drop_prob == 0.0 && self.dup_prob == 0.0 && self.latency == DelayModel::None
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [("drop_prob", self.drop_prob), ("dup_prob", self.dup_prob)] {
            if !(0.0..1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "link {name} must be in [0, 1), got {p}"
                )));
            }
        }
        if self.dup_lag < 0.0 {
            return Err(Error::Config(format!(
                "link dup_lag must be >= 0, got {}",
                self.dup_lag
            )));
        }
        Ok(())
    }

    /// Realize one roundtrip from a per-message RNG stream.  The sampling
    /// order is fixed (down fate, down delay, up fate, up delay, dup fate)
    /// so a given stream always yields the same realization.
    pub fn realize(&self, rng: &mut Pcg64) -> LinkRealization {
        if self.is_ideal() {
            return LinkRealization::ideal();
        }
        let down_dropped = rng.next_f64() < self.drop_prob;
        let down_delay = self.latency.sample(rng);
        let up_dropped = rng.next_f64() < self.drop_prob;
        let up_delay = self.latency.sample(rng);
        let up_duplicated = rng.next_f64() < self.dup_prob;
        LinkRealization {
            down_dropped,
            down_delay,
            up_dropped,
            up_delay,
            up_duplicated,
            dup_lag: self.dup_lag,
        }
    }
}

/// One worker-iteration roundtrip, fully realized: both directions' fates
/// and delays.  Produced by [`crate::net::NetSpec::realize`] as a pure
/// function of `(seed, worker, iteration)`, which is what lets the virtual
/// simulator and the threaded runtime agree on every message's fate
/// without sharing any state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkRealization {
    /// The `Work` broadcast was lost (the worker never computes).
    pub down_dropped: bool,
    /// One-way latency of the `Work` broadcast.
    pub down_delay: f64,
    /// The `Grad` reply was lost in flight.
    pub up_dropped: bool,
    /// One-way latency of the `Grad` reply.
    pub up_delay: f64,
    /// The `Grad` reply arrives twice.
    pub up_duplicated: bool,
    /// Lag of the duplicate copy behind the primary.
    pub dup_lag: f64,
}

impl LinkRealization {
    pub fn ideal() -> LinkRealization {
        LinkRealization {
            down_dropped: false,
            down_delay: 0.0,
            up_dropped: false,
            up_delay: 0.0,
            up_duplicated: false,
            dup_lag: 0.0,
        }
    }

    /// Both directions dead — a scripted partition window.
    pub fn partitioned() -> LinkRealization {
        LinkRealization {
            down_dropped: true,
            up_dropped: true,
            ..LinkRealization::ideal()
        }
    }

    /// Does the roundtrip deliver a usable reply?
    pub fn delivers(&self) -> bool {
        !self.down_dropped && !self.up_dropped
    }

    /// Total injected network latency on a delivered roundtrip.
    pub fn roundtrip_delay(&self) -> f64 {
        self.down_delay + self.up_delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_link_never_perturbs() {
        let link = LinkModel::ideal();
        assert!(link.is_ideal());
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100 {
            let r = link.realize(&mut rng);
            assert!(r.delivers());
            assert_eq!(r.roundtrip_delay(), 0.0);
            assert!(!r.up_duplicated);
        }
    }

    #[test]
    fn lossy_link_drops_at_roughly_its_rate() {
        let link = LinkModel::lossy(0.3);
        let mut rng = Pcg64::seeded(2);
        let n = 20_000;
        let dropped = (0..n)
            .filter(|_| link.realize(&mut rng).down_dropped)
            .count();
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn directions_realize_independently() {
        let link = LinkModel::lossy(0.5);
        let mut rng = Pcg64::seeded(3);
        let mut down_only = 0;
        let mut up_only = 0;
        for _ in 0..5000 {
            let r = link.realize(&mut rng);
            if r.down_dropped && !r.up_dropped {
                down_only += 1;
            }
            if r.up_dropped && !r.down_dropped {
                up_only += 1;
            }
        }
        assert!(down_only > 500, "down_only={down_only}");
        assert!(up_only > 500, "up_only={up_only}");
    }

    #[test]
    fn validate_rejects_bad_probabilities() {
        assert!(LinkModel::lossy(1.0).validate().is_err());
        assert!(LinkModel::lossy(-0.1).validate().is_err());
        assert!(LinkModel { dup_prob: 2.0, ..LinkModel::ideal() }.validate().is_err());
        assert!(LinkModel { dup_lag: -1.0, ..LinkModel::ideal() }.validate().is_err());
        assert!(LinkModel::lossy(0.99).validate().is_ok());
        assert!(LinkModel::ideal().validate().is_ok());
    }

    #[test]
    fn partitioned_realization_delivers_nothing() {
        let r = LinkRealization::partitioned();
        assert!(!r.delivers());
        assert!(r.down_dropped && r.up_dropped);
    }
}
