//! Row-sharding of a feature matrix across M workers.

/// One worker's slice of the dataset: `phi` is row-major (rows, l).
#[derive(Clone, Debug)]
pub struct Shard {
    pub phi: Vec<f32>,
    pub y: Vec<f32>,
    pub rows: usize,
    pub l: usize,
}

impl Shard {
    pub fn new(phi: Vec<f32>, y: Vec<f32>, rows: usize, l: usize) -> Shard {
        assert_eq!(phi.len(), rows * l);
        assert_eq!(y.len(), rows);
        Shard { phi, y, rows, l }
    }
}

/// Split `(phi, y)` into `m` equal shards of `zeta` rows each.
/// Panics unless `rows == m * zeta` (the AOT artifacts are fixed-shape, so
/// the generator always produces exactly `m * zeta` rows).
pub fn split_even(phi: &[f32], y: &[f32], l: usize, m: usize, zeta: usize) -> Vec<Shard> {
    let rows = y.len();
    assert_eq!(phi.len(), rows * l);
    assert_eq!(rows, m * zeta, "rows {rows} != m {m} * zeta {zeta}");
    (0..m)
        .map(|w| {
            let lo = w * zeta;
            let hi = lo + zeta;
            Shard::new(
                phi[lo * l..hi * l].to_vec(),
                y[lo..hi].to_vec(),
                zeta,
                l,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_evenly_preserving_rows() {
        let l = 2;
        let rows = 6;
        let phi: Vec<f32> = (0..rows * l).map(|i| i as f32).collect();
        let y: Vec<f32> = (0..rows).map(|i| i as f32 * 10.0).collect();
        let shards = split_even(&phi, &y, l, 3, 2);
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[1].phi, vec![4.0, 5.0, 6.0, 7.0]);
        assert_eq!(shards[1].y, vec![20.0, 30.0]);
        assert_eq!(shards[2].rows, 2);
    }

    #[test]
    #[should_panic]
    fn rejects_uneven() {
        split_even(&[0.0; 10], &[0.0; 5], 2, 2, 2);
    }
}
