//! Typed master <-> worker messages for the threaded ("real") runtime.
//!
//! Elastic clusters make shard assignment dynamic, so a `Work` message
//! carries the worker's current shard list (usually one shard; more after a
//! rebalance adopted an orphaned shard) and a `Grad` reply carries one
//! [`ShardGrad`] per assigned shard.  The master aggregates per *shard* in
//! shard-index order — the same order the virtual simulator uses — so both
//! drivers fold contributions identically.

use std::sync::Arc;

/// Master -> worker.
#[derive(Clone, Debug)]
pub enum MasterMsg {
    /// Compute gradients at `theta` for iteration `iter`, one per assigned
    /// shard.  `theta`/`shards` are shared (Arc) so a broadcast does not
    /// clone M times.
    Work {
        iter: u64,
        theta: Arc<Vec<f32>>,
        /// Shards this worker currently owns (ascending shard index).
        shards: Arc<Vec<usize>>,
        /// Injected network latency (seconds) this roundtrip owes, decided
        /// master-side by [`crate::net::NetShim`]; the slave adds it to its
        /// straggler sleep so wall-clock arrivals match the virtual
        /// driver's `down + compute + up` timing model.
        net_delay: f64,
        /// Warm-up service-time dilation (1.0 = warm), decided master-side
        /// from the elastic runtime's ramp state
        /// ([`crate::cluster::ElasticRuntime::latency_scale`]) — the slave
        /// has no view of boundary state, so the scale rides in the
        /// message like `net_delay` does.
        compute_scale: f64,
        /// Gradient-buffer free-list: payload `Vec`s reclaimed from earlier
        /// `Grad` replies, handed back so the slave's next reply reuses
        /// them instead of allocating (capacity already fits one gradient).
        recycle: Vec<Vec<f32>>,
    },
    /// Orderly shutdown.
    Shutdown,
}

/// One shard's finished gradient inside a [`WorkerMsg::Grad`] report.
#[derive(Clone, Debug)]
pub struct ShardGrad {
    /// Which shard this gradient covers.
    pub shard: usize,
    pub grad: Vec<f32>,
    /// Shard loss contribution (sum of squared residuals for KRR,
    /// summed NLL for the LM), if the executable provides it.
    pub loss_sum: Option<f64>,
    /// Examples that contributed (the paper's ζ).
    pub examples: usize,
}

/// Worker -> master.
#[derive(Debug)]
pub enum WorkerMsg {
    /// A finished iteration: one entry per shard the worker was assigned.
    /// Empty only in async mode's keep-alive heartbeats — the sync master
    /// never dispatches a shard-less worker, exactly like the virtual
    /// driver.
    Grad {
        worker: usize,
        iter: u64,
        shards: Vec<ShardGrad>,
        /// Pure compute time (excludes injected delay), seconds.
        compute_secs: f64,
    },
    /// Worker hit an unrecoverable error and is exiting.
    Fatal { worker: usize, error: String },
    /// Worker simulated a crash (fault injection) and stops responding.
    SimulatedCrash { worker: usize, iter: u64 },
}

impl WorkerMsg {
    pub fn worker(&self) -> usize {
        match self {
            WorkerMsg::Grad { worker, .. }
            | WorkerMsg::Fatal { worker, .. }
            | WorkerMsg::SimulatedCrash { worker, .. } => *worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_shares_theta() {
        let theta = Arc::new(vec![1.0f32; 1024]);
        let shards = Arc::new(vec![0usize]);
        let msgs: Vec<MasterMsg> = (0..8)
            .map(|_| MasterMsg::Work {
                iter: 1,
                theta: Arc::clone(&theta),
                shards: Arc::clone(&shards),
                net_delay: 0.0,
                compute_scale: 1.0,
                recycle: Vec::new(),
            })
            .collect();
        assert_eq!(Arc::strong_count(&theta), 9);
        drop(msgs);
        assert_eq!(Arc::strong_count(&theta), 1);
    }

    #[test]
    fn worker_accessor() {
        let m = WorkerMsg::Fatal {
            worker: 3,
            error: "x".into(),
        };
        assert_eq!(m.worker(), 3);
    }

    #[test]
    fn grad_carries_per_shard_entries() {
        let m = WorkerMsg::Grad {
            worker: 1,
            iter: 4,
            shards: vec![
                ShardGrad { shard: 1, grad: vec![0.0], loss_sum: None, examples: 8 },
                ShardGrad { shard: 5, grad: vec![1.0], loss_sum: Some(2.0), examples: 8 },
            ],
            compute_secs: 0.0,
        };
        match m {
            WorkerMsg::Grad { shards, .. } => {
                assert_eq!(shards.len(), 2);
                assert_eq!(shards[1].shard, 5);
            }
            _ => unreachable!(),
        }
    }
}
