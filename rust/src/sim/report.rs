//! Run-report assembly shared by the sync and async policies.
//!
//! Both policies finish a run with the same ingredients — a recorder, the
//! final θ, a status, and the engine's membership / elastic / network
//! accounting — so the [`crate::coordinator::RunReport`] is assembled in
//! exactly one place and the two policies cannot drift on what a report
//! means.

use crate::coordinator::convergence::RunStatus;
use crate::coordinator::RunReport;
use crate::metrics::Recorder;
use crate::net::NetStats;

use super::engine::EngineCore;

/// Assemble the final report from a finished policy run.
#[allow(clippy::too_many_arguments)]
pub(crate) fn assemble(
    recorder: Recorder,
    theta: Vec<f32>,
    status: RunStatus,
    gamma: Option<usize>,
    mode_name: &'static str,
    core: &EngineCore,
    net: NetStats,
    agg: crate::agg::AggStats,
    stale_blocks: u64,
    mean_staleness: Option<f64>,
    recoveries: u64,
    rollback_iters: u64,
    driver_start: std::time::Instant,
    trace: Option<crate::trace::TraceSummary>,
    serve: Option<crate::serve::ServeStats>,
) -> RunReport {
    RunReport {
        recorder,
        theta,
        status,
        gamma,
        mode_name,
        total_contributions: core.membership.total_contributed(),
        total_abandoned: core.membership.total_abandoned(),
        crashes: core.membership.crashes(),
        rejoins: core.membership.rejoins(),
        rebalances: core.elastic.rebalances(),
        shard_owners: core.elastic.ownership.owners().to_vec(),
        net,
        agg,
        stale_blocks,
        mean_staleness,
        recoveries,
        rollback_iters,
        driver_secs: driver_start.elapsed().as_secs_f64(),
        trace,
        serve,
    }
}
