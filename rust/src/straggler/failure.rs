//! Worker failure injection: crashes, transient faults, and rejoin.
//!
//! The paper's fault-tolerance claim is that the hybrid barrier keeps
//! iterating when nodes die (BSP stalls; with `γ ≤` alive workers the
//! hybrid master never notices).  [`FailureState`] is a small per-worker
//! state machine driven once per iteration.

use crate::util::rng::Pcg64;

/// Stochastic failure behaviour of one worker.
#[derive(Clone, Debug, PartialEq)]
pub struct FailureModel {
    /// Probability per iteration of a permanent (or until-rejoin) crash.
    pub crash_prob: f64,
    /// Probability per iteration of dropping just that iteration's result
    /// (message loss / timeout): the worker stays alive.
    pub transient_prob: f64,
    /// If `Some(k)`, a crashed worker restarts after `k` iterations
    /// (simulating a supervisor respawning it).  `None` = crash is forever.
    pub rejoin_after: Option<u64>,
}

impl FailureModel {
    pub fn none() -> FailureModel {
        FailureModel {
            crash_prob: 0.0,
            transient_prob: 0.0,
            rejoin_after: None,
        }
    }

    pub fn is_none(&self) -> bool {
        self.crash_prob == 0.0 && self.transient_prob == 0.0
    }
}

/// What happened to a worker this iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureEvent {
    /// Worker computes and reports normally.
    Healthy,
    /// Worker's result is lost this iteration only.
    TransientDrop,
    /// Worker crashed this iteration (no result, stays down).
    Crashed,
    /// Worker is still down from an earlier crash.
    Down,
    /// Worker restarted this iteration (reports normally again).
    Rejoined,
}

/// Per-worker failure state machine.
#[derive(Clone, Debug)]
pub struct FailureState {
    model: FailureModel,
    down_since: Option<u64>,
}

impl FailureState {
    pub fn new(model: FailureModel) -> FailureState {
        FailureState {
            model,
            down_since: None,
        }
    }

    pub fn is_down(&self) -> bool {
        self.down_since.is_some()
    }

    /// Advance one iteration; returns what the worker does.
    pub fn step(&mut self, iter: u64, rng: &mut Pcg64) -> FailureEvent {
        if let Some(since) = self.down_since {
            if let Some(k) = self.model.rejoin_after {
                if iter >= since + k {
                    self.down_since = None;
                    return FailureEvent::Rejoined;
                }
            }
            return FailureEvent::Down;
        }
        if self.model.crash_prob > 0.0 && rng.next_f64() < self.model.crash_prob {
            self.down_since = Some(iter);
            return FailureEvent::Crashed;
        }
        if self.model.transient_prob > 0.0 && rng.next_f64() < self.model.transient_prob {
            return FailureEvent::TransientDrop;
        }
        FailureEvent::Healthy
    }

    /// Force a crash at `iter` (used by the fault-tolerance example to kill
    /// a specific worker at a specific time, and by scheduled elastic
    /// leaves).
    pub fn force_crash(&mut self, iter: u64) {
        self.down_since = Some(iter);
    }

    /// Clear a down state (scheduled elastic join / supervisor respawn):
    /// the worker responds normally again from the next `step`.
    pub fn force_rejoin(&mut self) {
        self.down_since = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_failures_stays_healthy() {
        let mut st = FailureState::new(FailureModel::none());
        let mut rng = Pcg64::seeded(1);
        for i in 0..1000 {
            assert_eq!(st.step(i, &mut rng), FailureEvent::Healthy);
        }
    }

    #[test]
    fn crash_is_permanent_without_rejoin() {
        let mut st = FailureState::new(FailureModel {
            crash_prob: 1.0,
            transient_prob: 0.0,
            rejoin_after: None,
        });
        let mut rng = Pcg64::seeded(2);
        assert_eq!(st.step(0, &mut rng), FailureEvent::Crashed);
        for i in 1..100 {
            assert_eq!(st.step(i, &mut rng), FailureEvent::Down);
        }
    }

    #[test]
    fn rejoin_after_k() {
        let mut st = FailureState::new(FailureModel {
            crash_prob: 0.0,
            transient_prob: 0.0,
            rejoin_after: Some(3),
        });
        let mut rng = Pcg64::seeded(3);
        st.force_crash(10);
        assert_eq!(st.step(11, &mut rng), FailureEvent::Down);
        assert_eq!(st.step(12, &mut rng), FailureEvent::Down);
        assert_eq!(st.step(13, &mut rng), FailureEvent::Rejoined);
        assert_eq!(st.step(14, &mut rng), FailureEvent::Healthy);
    }

    #[test]
    fn force_rejoin_revives_worker() {
        let mut st = FailureState::new(FailureModel::none());
        let mut rng = Pcg64::seeded(8);
        st.force_crash(5);
        assert!(st.is_down());
        assert_eq!(st.step(6, &mut rng), FailureEvent::Down);
        st.force_rejoin();
        assert!(!st.is_down());
        assert_eq!(st.step(7, &mut rng), FailureEvent::Healthy);
    }

    #[test]
    fn transient_rate_approximates_prob() {
        let mut st = FailureState::new(FailureModel {
            crash_prob: 0.0,
            transient_prob: 0.3,
            rejoin_after: None,
        });
        let mut rng = Pcg64::seeded(4);
        let n = 20_000;
        let drops = (0..n)
            .filter(|&i| st.step(i, &mut rng) == FailureEvent::TransientDrop)
            .count();
        let rate = drops as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }
}
