//! F2 — speedup vs straggler severity, and fault tolerance vs crash rate
//! (abstract: "high fault-tolerant", "dramatically reduce calculation
//! time ... can be used in many platforms").
//!
//! Part 1: sweep lognormal σ (straggler severity) and report hybrid's
//! time-per-iteration speedup over BSP.  Expected: speedup grows with σ
//! (the heavier the tail, the more the partial barrier saves); ≈1 at σ=0.
//!
//! Part 2: sweep per-iteration crash probability; report each policy's
//! terminal status and progress.  Expected: BSP-stall dies immediately,
//! BSP-retry survives with growing overhead, hybrid sails until the alive
//! count drops below γ.
//!
//! All three parts' sweep points run concurrently on the sweep engine
//! (`--threads N` overrides the pool size); each point is seed-determined,
//! so the tables match a serial run exactly.

use hybriditer::bench_harness::sweep::{ProblemCache, SweepEngine};
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::{ClusterSpec, ElasticSchedule};
use hybriditer::coordinator::{BspRecovery, LossForm, RunConfig, RunStatus, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::{DelayModel, FailureModel};

const M: usize = 16;
const ITERS: u64 = 150;
const SEEDS: u64 = 3;

fn mean_time(
    cache: &ProblemCache,
    mode: SyncMode,
    delay: DelayModel,
    failure: FailureModel,
    recovery: BspRecovery,
) -> (f64, String, u64) {
    let spec = KrrProblemSpec::small().with_machines(M);
    let problem = cache.get(&spec);
    let mut times = Vec::new();
    let mut status = String::new();
    let mut iters_done = 0;
    for seed in 0..SEEDS {
        let cluster = ClusterSpec {
            workers: M,
            base_compute: 0.01,
            delay: delay.clone(),
            failure: failure.clone(),
            seed: 40 + seed,
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: mode.clone(),
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec.lambda),
            bsp_recovery: recovery,
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        times.push(rep.total_time());
        iters_done = iters_done.max(rep.recorder.len() as u64);
        status = match rep.status {
            RunStatus::Completed => "ok".into(),
            RunStatus::Converged { .. } => "ok".into(),
            RunStatus::Stalled { iter } => format!("stall@{iter}"),
            RunStatus::ClusterDead { iter } => format!("dead@{iter}"),
        };
    }
    (
        times.iter().sum::<f64>() / times.len() as f64,
        status,
        iters_done,
    )
}

fn main() {
    let engine = SweepEngine::from_env();
    println!(
        "F2: straggler severity sweep + fault tolerance — M={M}, {ITERS} iters, {SEEDS} seeds"
    );
    println!("sweep pool: {} threads\n", engine.threads());

    // Part 1: severity sweep.
    let gamma = M * 3 / 4;
    let mut t1 = Table::new(
        format!("F2a speedup vs lognormal sigma (gamma={gamma})"),
        &["sigma", "bsp_s", "hybrid_s", "async_s", "hybrid_speedup"],
    );
    let sigmas = [0.0, 0.5, 1.0, 1.5, 2.0];
    let severity = engine.run(&sigmas, |cache, &sigma| {
        let delay = if sigma == 0.0 {
            DelayModel::None
        } else {
            DelayModel::LogNormal { mu: -4.0, sigma }
        };
        let none = FailureModel::none();
        let (bsp, _, _) = mean_time(
            cache,
            SyncMode::Bsp,
            delay.clone(),
            none.clone(),
            BspRecovery::Stall,
        );
        let (hyb, _, _) = mean_time(
            cache,
            SyncMode::Hybrid { gamma },
            delay.clone(),
            none.clone(),
            BspRecovery::Stall,
        );
        let (asy, _, _) = mean_time(
            cache,
            SyncMode::Async { damping: 0.0 },
            delay,
            none,
            BspRecovery::Stall,
        );
        (bsp, hyb, asy)
    });
    for (&sigma, &(bsp, hyb, asy)) in sigmas.iter().zip(&severity) {
        t1.row(vec![
            f(sigma, 1),
            f(bsp, 2),
            f(hyb, 2),
            f(asy / M as f64, 2), // per equivalent-iteration
            f(bsp / hyb, 2),
        ]);
    }
    t1.print();
    t1.save_csv("f2a_severity_sweep").unwrap();

    // Part 2: crash-rate sweep.
    let mut t2 = Table::new(
        format!("F2b fault tolerance vs crash probability (gamma={})", M / 2),
        &["crash_prob", "bsp_stall", "bsp_retry_s", "hybrid_s", "hybrid_status"],
    );
    let probs = [0.0, 0.001, 0.005, 0.01, 0.02];
    let crash = engine.run(&probs, |cache, &p| {
        let failure = FailureModel {
            crash_prob: p,
            transient_prob: 0.0,
            rejoin_after: None,
        };
        let delay = DelayModel::LogNormal { mu: -4.0, sigma: 0.5 };
        let (_, stall_status, stall_iters) = mean_time(
            cache,
            SyncMode::Bsp,
            delay.clone(),
            failure.clone(),
            BspRecovery::Stall,
        );
        let (retry_t, _, _) = mean_time(
            cache,
            SyncMode::Bsp,
            delay.clone(),
            failure.clone(),
            BspRecovery::Retry { detect_timeout: 0.05 },
        );
        let (hyb_t, hyb_status, _) = mean_time(
            cache,
            SyncMode::Hybrid { gamma: M / 2 },
            delay,
            failure,
            BspRecovery::Stall,
        );
        (stall_status, stall_iters, retry_t, hyb_t, hyb_status)
    });
    for (&p, (stall_status, stall_iters, retry_t, hyb_t, hyb_status)) in probs.iter().zip(&crash) {
        t2.row(vec![
            f(p, 3),
            format!("{stall_status} ({stall_iters} iters)"),
            f(*retry_t, 2),
            f(*hyb_t, 2),
            hyb_status.clone(),
        ]);
    }
    t2.print();
    t2.save_csv("f2b_crash_sweep").unwrap();

    // Part 3: elastic churn — 2 of M workers leave at iteration 50 and
    // rejoin at 100.  Static is the no-churn reference; "orphaned" keeps
    // the seed behaviour (leavers' shards stop contributing); "rebalanced"
    // migrates them onto survivors and levels load after the rejoin.
    let gamma3 = M * 3 / 4;
    let mut t3 = Table::new(
        format!("F2c elastic churn: 2/{M} leave@50 join@100 (gamma={gamma3})"),
        &["policy", "time_s", "final_loss", "theta_err", "rebalances"],
    );
    let churn = ElasticSchedule::crash_and_rejoin(&[M - 2, M - 1], 50, 100);
    let policies = [
        ("static", ElasticSchedule::default(), 0u64),
        ("churn-orphaned", churn.clone(), 0),
        ("churn-rebalanced", churn.clone(), 1),
    ];
    let spec = KrrProblemSpec::small().with_machines(M);
    let churn_rows = engine.run(&policies, |cache, (_, elastic, rebalance_every)| {
        let problem = cache.get(&spec);
        let cluster = ClusterSpec {
            workers: M,
            base_compute: 0.01,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 0.5 },
            seed: 44,
            ..ClusterSpec::default()
        }
        .with_elastic(elastic.clone(), *rebalance_every);
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma: gamma3 },
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, problem.as_ref()).unwrap();
        (
            rep.total_time(),
            rep.final_loss(),
            rep.final_theta_err(),
            rep.rebalances,
        )
    });
    for ((name, _, _), (time, loss, err, rebalances)) in policies.iter().zip(&churn_rows) {
        t3.row(vec![
            name.to_string(),
            f(*time, 2),
            format!("{loss:.6}"),
            err.map(|e| format!("{e:.3e}")).unwrap_or_else(|| "-".into()),
            rebalances.to_string(),
        ]);
    }
    t3.print();
    t3.save_csv("f2c_elastic_churn").unwrap();

    println!(
        "\nReading: F2a — hybrid's speedup over BSP grows with tail heaviness\n\
         (≈1 with no stragglers).  F2b — BSP without recovery stalls at the\n\
         first crash; hybrid keeps full-speed progress while alive ≥ gamma.\n\
         F2c — rebalancing keeps the leavers' shards contributing, closing\n\
         the accuracy gap the orphaned run shows, at unchanged time cost."
    );
}
