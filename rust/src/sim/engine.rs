//! The shared discrete-event core both virtual policies run on.
//!
//! [`EventHeap`] is the virtual-time event heap: reply events pop in
//! deterministic `(time, worker, duplicate, iter)` order, and — for the
//! sync policy — stragglers that out-live their iteration window are
//! *rebased* into the next window's time frame instead of being force-
//! drained, which is what lets a reply straggle past a barrier boundary
//! and classify as [`crate::coordinator::barrier::Admission::Stale`] in
//! virtual time.
//!
//! [`EngineCore`] bundles the per-run state every policy needs — the heap,
//! the membership view, the elastic runtime, per-worker failure state
//! machines and RNG streams — and owns the **boundary event handler**
//! ([`EngineCore::boundary`]): scheduled elastic leave/join events land
//! there, followed by any due shard-rebalance plan (this is the former
//! `ElasticRuntime::at_boundary`, folded into the engine).  Policies layer
//! their own semantics on top: the sync policy opens a
//! [`crate::coordinator::barrier::PartialBarrier`] per window, the async
//! policy applies every delivered reply immediately.
//!
//! Both policies can thread a [`crate::trace::TraceSink`] through their run
//! loops: boundary events, message fates, deliveries and barrier closes are
//! journaled in virtual time, and `tests/parity_drivers.rs` holds the
//! resulting event sequences identical to the threaded runtime's (see
//! `docs/OBSERVABILITY.md`).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::{ElasticKind, ElasticRuntime, ElasticSchedule, Membership};
use crate::recovery::RecoveryState;
use crate::straggler::{FailureState, StragglerProfile};
use crate::trace::TraceSink;
use crate::util::rng::Pcg64;
use crate::Result;

pub use super::events::Event;

/// Virtual-time event heap with deterministic pop order and window
/// rebasing.  Pushes and pops recycle the underlying buffers, so a
/// steady-state sync iteration allocates nothing once the high-water mark
/// is reached (`tests/alloc_regression.rs`).
pub struct EventHeap {
    heap: BinaryHeap<Reverse<Event>>,
    /// Scratch for [`EventHeap::rebase`]; capacity is retained.
    scratch: Vec<Event>,
}

impl Default for EventHeap {
    fn default() -> Self {
        EventHeap::new()
    }
}

impl EventHeap {
    pub fn new() -> EventHeap {
        EventHeap { heap: BinaryHeap::new(), scratch: Vec::new() }
    }

    pub fn push(&mut self, ev: Event) {
        self.heap.push(Reverse(ev));
    }

    /// Pop the next event in `(at, worker, duplicate, iter)` order.
    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop().map(|Reverse(ev)| ev)
    }

    /// Pop the next event only if it lands strictly before `deadline`.
    pub fn pop_before(&mut self, deadline: f64) -> Option<Event> {
        match self.heap.peek() {
            Some(Reverse(ev)) if ev.at < deadline => self.pop(),
            _ => None,
        }
    }

    /// Earliest pending event time.
    pub fn peek_at(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse(ev)| ev.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }

    /// Shift every pending event `window_len` seconds into the past: the
    /// sync policy calls this when it closes an iteration window, so
    /// events that out-lived the window re-enter the next one at the
    /// correct relative offset.  Only stragglers under a non-ideal
    /// [`crate::net::NetSpec`] ever remain at a boundary; under an ideal
    /// spec this is never reached and the lockstep arithmetic is untouched
    /// (the bit-for-bit guarantee).
    pub fn rebase(&mut self, window_len: f64) {
        if self.heap.is_empty() {
            return;
        }
        self.scratch.clear();
        while let Some(Reverse(mut ev)) = self.heap.pop() {
            ev.at -= window_len;
            self.scratch.push(ev);
        }
        for ev in self.scratch.drain(..) {
            self.heap.push(Reverse(ev));
        }
    }
}

/// Per-run engine state shared by the sync and async policies.
pub struct EngineCore {
    pub heap: EventHeap,
    pub membership: Membership,
    pub elastic: ElasticRuntime,
    pub fstates: Vec<FailureState>,
    pub delay_rngs: Vec<Pcg64>,
    pub fail_rngs: Vec<Pcg64>,
    /// Workers evicted by a scheduled Leave.  Tracked separately from
    /// `FailureState` so a `FailureModel` with `rejoin_after` cannot
    /// auto-revive a scheduled leaver before its scheduled Join (the
    /// threaded driver's master-side eviction has the same semantics).
    pub evicted: Vec<bool>,
}

impl EngineCore {
    /// Build the engine for `m` workers.  `stream_salt` / `fail_offset`
    /// pick the policy's RNG stream family: the sync policy keeps the
    /// historical `(0x51D, 1000)` streams, the async policy `(0xA51C,
    /// 2000)`, so both reproduce their pre-refactor sequences bit for bit.
    pub fn new(
        profiles: &[StragglerProfile],
        seed: u64,
        stream_salt: u64,
        fail_offset: u64,
    ) -> EngineCore {
        let m = profiles.len();
        let mut seed_rng = Pcg64::new(seed, stream_salt);
        let delay_rngs: Vec<Pcg64> = (0..m).map(|w| seed_rng.split(w as u64)).collect();
        let fail_rngs: Vec<Pcg64> =
            (0..m).map(|w| seed_rng.split(fail_offset + w as u64)).collect();
        let fstates: Vec<FailureState> = profiles
            .iter()
            .map(|p| FailureState::new(p.failure.clone()))
            .collect();
        let membership = Membership::new(m);
        let elastic = ElasticRuntime::new(&membership);
        EngineCore {
            heap: EventHeap::new(),
            membership,
            elastic,
            fstates,
            delay_rngs,
            fail_rngs,
            evicted: vec![false; m],
        }
    }

    /// The engine's boundary event handler.  Every warm-up ramp advances
    /// one step first; then scheduled elastic leave/join events due at
    /// `iter` land, in schedule order (a leave@k followed by join@k nets
    /// out alive), each updating the failure state, the eviction mask, and
    /// the membership view together — a join that re-admits a down worker
    /// also starts its warm-up ramp
    /// ([`crate::cluster::ElasticRuntime::note_join`]).  The recovery
    /// policy is consulted per event ([`RecoveryState::on_leave`] /
    /// [`RecoveryState::on_join`] — a checkpoint restore rewrites `theta`
    /// right here, and every fired recovery is journaled through `sink`);
    /// a due shard-rebalance plan follows, seeing the post-event
    /// membership and the ramped capacity weights (the rebalance policy's
    /// forced replan makes a plan due regardless of the periodic
    /// cadence).  Returns whether a non-empty plan was applied.
    ///
    /// The threaded master executes the same sequence inline at its
    /// boundaries with the same [`RecoveryState`] hook order, so recovery
    /// decisions and their journaled events cannot drift between drivers
    /// (`docs/RECOVERY.md`).
    #[allow(clippy::too_many_arguments)]
    pub fn boundary(
        &mut self,
        iter: u64,
        schedule: &ElasticSchedule,
        rebalance_every: u64,
        recovery: &mut RecoveryState,
        theta: &mut [f32],
        sink: &mut dyn TraceSink,
        time: f64,
    ) -> Result<bool> {
        self.elastic.tick_warmup();
        for ev in schedule.at(iter) {
            let fired = match ev.kind {
                ElasticKind::Leave => {
                    self.evicted[ev.worker] = true;
                    self.fstates[ev.worker].force_crash(iter);
                    self.membership.mark_down(ev.worker);
                    recovery.on_leave(ev.worker, iter, theta)
                }
                ElasticKind::Join => {
                    if !self.membership.is_alive(ev.worker) {
                        self.elastic.note_join(ev.worker);
                    }
                    self.evicted[ev.worker] = false;
                    self.fstates[ev.worker].force_rejoin();
                    self.membership.mark_alive(ev.worker);
                    recovery.on_join(ev.worker, iter)
                }
            };
            if let Some(rollback) = fired {
                if sink.enabled() {
                    crate::trace::emit_recovery(
                        sink,
                        iter,
                        ev.worker,
                        time,
                        recovery.policy().name(),
                        rollback,
                    );
                }
            }
        }
        let every = if recovery.take_force_replan() { 1 } else { rebalance_every };
        self.elastic.maybe_rebalance(iter, every, &self.membership)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: f64, worker: usize, iter: u64) -> Event {
        Event { at, worker, iter, duplicate: false, delivers: true }
    }

    /// Drive a boundary with the default (no-op) recovery policy.
    fn boundary(
        core: &mut EngineCore,
        iter: u64,
        schedule: &ElasticSchedule,
        every: u64,
    ) -> bool {
        let workers = core.evicted.len();
        let mut rec = RecoveryState::new(Default::default(), workers);
        let mut theta: Vec<f32> = vec![];
        core.boundary(iter, schedule, every, &mut rec, &mut theta, &mut crate::trace::NoopSink, 0.0)
            .unwrap()
    }

    #[test]
    fn heap_pops_in_deterministic_order() {
        let mut h = EventHeap::new();
        h.push(ev(0.03, 0, 1));
        h.push(ev(0.01, 2, 1));
        h.push(ev(0.01, 1, 1));
        assert_eq!(h.len(), 3);
        assert_eq!(h.pop().unwrap().worker, 1);
        assert_eq!(h.pop().unwrap().worker, 2);
        assert_eq!(h.pop().unwrap().at, 0.03);
        assert!(h.pop().is_none());
    }

    #[test]
    fn pop_before_respects_deadline() {
        let mut h = EventHeap::new();
        h.push(ev(0.01, 0, 0));
        h.push(ev(0.05, 1, 0));
        assert_eq!(h.pop_before(0.02).unwrap().worker, 0);
        assert!(h.pop_before(0.02).is_none());
        assert_eq!(h.len(), 1);
        // An event exactly at the deadline stays (strictly-before).
        assert!(h.pop_before(0.05).is_none());
        assert_eq!(h.pop_before(0.050001).unwrap().worker, 1);
    }

    #[test]
    fn rebase_shifts_pending_events() {
        let mut h = EventHeap::new();
        h.push(ev(0.015, 0, 3));
        h.push(ev(0.025, 1, 3));
        h.rebase(0.010);
        let a = h.pop().unwrap();
        assert!((a.at - 0.005).abs() < 1e-12);
        assert_eq!(a.iter, 3, "rebase must not touch the iteration tag");
        let b = h.pop().unwrap();
        assert!((b.at - 0.015).abs() < 1e-12);
        // Rebasing an empty heap is a no-op.
        h.rebase(1.0);
        assert!(h.is_empty());
    }

    #[test]
    fn boundary_applies_events_and_rebalances() {
        use crate::cluster::ElasticSchedule;
        let profiles: Vec<StragglerProfile> =
            (0..4).map(|_| StragglerProfile::healthy(0.01)).collect();
        let mut core = EngineCore::new(&profiles, 7, 0x51D, 1000);
        let schedule = ElasticSchedule::crash_and_rejoin(&[3], 2, 5);

        assert!(!boundary(&mut core, 0, &schedule, 1));
        assert_eq!(core.membership.alive(), 4);

        // Leave fires: eviction mask + failure state + membership move
        // together, and the orphaned shard is adopted.
        assert!(boundary(&mut core, 2, &schedule, 1));
        assert!(core.evicted[3]);
        assert!(core.fstates[3].is_down());
        assert_eq!(core.membership.alive(), 3);
        assert_eq!(core.elastic.ownership.load(3), 0);

        // Join fires: everything reverts and load levels back.
        assert!(boundary(&mut core, 5, &schedule, 1));
        assert!(!core.evicted[3]);
        assert!(!core.fstates[3].is_down());
        assert_eq!(core.membership.alive(), 4);
        assert_eq!(core.elastic.ownership.load(3), 1);
        assert_eq!(core.elastic.rebalances(), 2);
    }

    #[test]
    fn boundary_ramps_warmup_on_scheduled_rejoin() {
        use crate::cluster::ElasticSchedule;
        let profiles: Vec<StragglerProfile> =
            (0..4).map(|_| StragglerProfile::healthy(0.01)).collect();
        let mut core = EngineCore::new(&profiles, 7, 0x51D, 1000);
        core.elastic.configure_capacity(vec![1.0; 4], 2, true);
        let schedule = ElasticSchedule::crash_and_rejoin(&[1], 1, 3);

        boundary(&mut core, 0, &schedule, 1);
        assert_eq!(core.elastic.ramp(1), 1.0);
        boundary(&mut core, 1, &schedule, 1); // leave
        boundary(&mut core, 2, &schedule, 1);
        assert_eq!(core.elastic.ramp(1), 1.0, "eviction alone must not ramp");

        // The join boundary starts the ramp at 1/(k+1); each subsequent
        // boundary climbs one step until it saturates at 1.
        boundary(&mut core, 3, &schedule, 1);
        assert!((core.elastic.ramp(1) - 1.0 / 3.0).abs() < 1e-12);
        assert!((core.elastic.latency_scale(1) - 3.0).abs() < 1e-12);
        boundary(&mut core, 4, &schedule, 1);
        assert!((core.elastic.ramp(1) - 2.0 / 3.0).abs() < 1e-12);
        boundary(&mut core, 5, &schedule, 1);
        assert_eq!(core.elastic.ramp(1), 1.0);
        assert_eq!(core.elastic.latency_scale(1), 1.0);
    }

    #[test]
    fn boundary_forced_replan_overrides_disabled_cadence() {
        use crate::cluster::ElasticSchedule;
        use crate::recovery::{RecoveryConfig, RecoveryPolicy, RecoveryState};
        let profiles: Vec<StragglerProfile> =
            (0..4).map(|_| StragglerProfile::healthy(0.01)).collect();
        let mut core = EngineCore::new(&profiles, 7, 0x51D, 1000);
        let schedule = ElasticSchedule::parse("3:leave@2").unwrap();
        let cfg = RecoveryConfig { policy: RecoveryPolicy::Rebalance, ..Default::default() };
        let mut rec = RecoveryState::new(cfg, 4);
        let mut theta: Vec<f32> = vec![];
        let mut sink = crate::trace::NoopSink;
        // rebalance_every = 0: the periodic cadence is off, but the
        // rebalance policy forces a replan at the leave boundary.
        assert!(!core
            .boundary(0, &schedule, 0, &mut rec, &mut theta, &mut sink, 0.0)
            .unwrap());
        assert!(core
            .boundary(2, &schedule, 0, &mut rec, &mut theta, &mut sink, 0.0)
            .unwrap());
        assert_eq!(core.elastic.ownership.load(3), 0);
        assert_eq!(rec.recoveries, 1);
        // Quiet boundaries stay replan-free.
        assert!(!core
            .boundary(3, &schedule, 0, &mut rec, &mut theta, &mut sink, 0.0)
            .unwrap());
    }
}
