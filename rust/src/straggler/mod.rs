//! Straggler & fault injection: the environment the paper's hybrid barrier
//! is designed to survive.
//!
//! A physical 2014 Hadoop cluster exhibits heavy-tailed per-task latencies
//! (slow disks, network retransmits, multi-tenant contention) and occasional
//! node failures.  We model both explicitly so experiments can *sweep*
//! severity instead of hoping one testbed exhibits it (DESIGN.md §3):
//!
//! * [`DelayModel`] — per-(worker, iteration) extra latency distributions;
//! * [`FailureModel`] — crash / transient-failure / rejoin behaviour;
//! * [`StragglerProfile`] — a worker's combined timing personality,
//!   including chronic slow nodes (a constant multiplier on compute time).

pub mod delay;
pub mod failure;
pub mod trace;

pub use delay::DelayModel;
pub use failure::{FailureEvent, FailureModel, FailureState};

use crate::util::rng::Pcg64;

/// A worker's complete timing personality.
#[derive(Clone, Debug)]
pub struct StragglerProfile {
    /// Baseline compute time per iteration in (virtual) seconds.
    pub base_compute: f64,
    /// Chronic slowdown multiplier (1.0 = healthy node).
    pub slow_factor: f64,
    /// Relative hardware capacity (1.0 = baseline): per-shard service time
    /// scales by `1/capacity`, and the capacity-weighted rebalance planner
    /// apportions shards proportionally to it (see `docs/ELASTIC.md`).
    /// Unlike `slow_factor` — a *fault* the barrier tolerates — capacity is
    /// a declared property of the hardware that work assignment should
    /// respect.
    pub capacity: f64,
    /// Stochastic extra delay added on top of compute.
    pub delay: DelayModel,
    /// Crash / transient-failure behaviour.
    pub failure: FailureModel,
}

impl StragglerProfile {
    pub fn healthy(base_compute: f64) -> Self {
        StragglerProfile {
            base_compute,
            slow_factor: 1.0,
            capacity: 1.0,
            delay: DelayModel::None,
            failure: FailureModel::none(),
        }
    }

    /// Sample this worker's total latency for one iteration.
    pub fn sample_latency(&self, rng: &mut Pcg64) -> f64 {
        self.base_compute * self.slow_factor / self.capacity + self.delay.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_profile_is_deterministic() {
        let p = StragglerProfile::healthy(0.01);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..10 {
            assert_eq!(p.sample_latency(&mut rng), 0.01);
        }
    }

    #[test]
    fn slow_factor_scales_base() {
        let mut p = StragglerProfile::healthy(0.01);
        p.slow_factor = 5.0;
        let mut rng = Pcg64::seeded(1);
        assert!((p.sample_latency(&mut rng) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn capacity_dilates_service_time() {
        let mut p = StragglerProfile::healthy(0.01);
        p.capacity = 0.25;
        let mut rng = Pcg64::seeded(1);
        assert!((p.sample_latency(&mut rng) - 0.04).abs() < 1e-12);
        // Unit capacity is the exact legacy latency (division by 1.0 is
        // bit-exact, preserving every pre-capacity golden trajectory).
        p.capacity = 1.0;
        assert_eq!(p.sample_latency(&mut rng), 0.01);
    }
}
