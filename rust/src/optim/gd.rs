//! Plain (stochastic) gradient descent — Algorithm 2's update.

use super::{EtaSchedule, Optimizer};
use crate::math::vec_ops;

/// `θ ← θ − η_t · ḡ`.
#[derive(Clone, Debug)]
pub struct Sgd {
    eta: EtaSchedule,
}

impl Sgd {
    pub fn new(eta: EtaSchedule) -> Sgd {
        Sgd { eta }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, theta: &mut [f32], grad: &[f32], iter: u64) {
        vec_ops::axpy(-(self.eta.at(iter) as f32), grad, theta);
    }

    fn name(&self) -> &'static str {
        "sgd"
    }

    fn reset(&mut self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_matches_formula() {
        let mut opt = Sgd::new(EtaSchedule::constant(0.1));
        let mut theta = vec![1.0f32, 2.0];
        opt.step(&mut theta, &[10.0, -10.0], 0);
        assert_eq!(theta, vec![0.0, 3.0]);
    }

    #[test]
    fn decaying_eta_shrinks_steps() {
        let mut opt = Sgd::new(EtaSchedule { eta0: 1.0, decay: 1.0 });
        let mut a = vec![0.0f32];
        opt.step(&mut a, &[1.0], 0); // step 1.0
        let first = a[0];
        let mut b = vec![0.0f32];
        opt.step(&mut b, &[1.0], 9); // step 0.1
        assert!((first + 1.0).abs() < 1e-6);
        assert!((b[0] + 0.1).abs() < 1e-6);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut opt = Sgd::new(EtaSchedule::constant(0.5));
        let err = crate::optim::test_util::run_quadratic(&mut opt, 200);
        assert!(err < 1e-3, "err={err}");
    }
}
