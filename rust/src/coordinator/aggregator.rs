//! Gradient aggregation policies (Algorithm 2 line 3 and ablations).
//!
//! With the transport's block admission active, a contribution may carry
//! only a subset of its gradient's blocks ([`BlockSet`]): the fold weights
//! such partial replies by their delivered fraction and adds each of them
//! only over the coordinate ranges that actually arrived — the bounded
//! perturbation model of Yu et al. (arXiv:1810.07766).  A full set
//! multiplies the weight by exactly `1.0` and folds the whole slice, so
//! pre-block behaviour is reproduced bit for bit.

use crate::math::vec_ops;
use crate::net::BlockSet;

/// How included gradients combine into the master's update direction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggregatorKind {
    /// Paper default: plain mean of the γ included gradients.
    Mean,
    /// Weight by shard example counts (relevant when shards are uneven or a
    /// rejoined worker carries a partial shard).
    ExampleWeighted,
    /// DESIGN.md §6 "hybrid-reuse" ablation: also fold in gradients that
    /// arrived after the previous barrier closed, damped by
    /// `rho^staleness` (staleness in iterations).
    StalenessDamped { rho: f64 },
}

/// One gradient contribution.
#[derive(Clone, Copy)]
pub struct Contribution<'a> {
    pub grad: &'a [f32],
    pub examples: usize,
    /// 0 = computed for this iteration, k = k iterations old.
    pub staleness: u64,
    /// Which gradient blocks the network delivered.  [`BlockSet::full`]
    /// (any count) folds the whole vector exactly as the pre-block model.
    pub blocks: BlockSet,
}

impl<'a> Contribution<'a> {
    /// A fully-delivered contribution — the legacy whole-gradient case.
    pub fn whole(grad: &'a [f32], examples: usize, staleness: u64) -> Contribution<'a> {
        Contribution { grad, examples, staleness, blocks: BlockSet::full(1) }
    }
}

/// Aggregate a contribution stream into `out` without materializing a
/// slice — the virtual driver's zero-alloc hot path feeds it an iterator
/// chained straight off its scratch arena.  Returns the effective weight
/// sum.  Panics on an empty stream (same contract as [`aggregate`]).
pub fn aggregate_iter<'a>(
    kind: AggregatorKind,
    contribs: impl IntoIterator<Item = Contribution<'a>>,
    out: &mut [f32],
) -> f64 {
    out.fill(0.0);
    let mut wsum = 0.0f64;
    let mut seen = 0usize;
    for c in contribs {
        seen += 1;
        let w = match kind {
            AggregatorKind::Mean => {
                if c.staleness > 0 {
                    0.0 // fresh-only: late results are abandoned
                } else {
                    1.0
                }
            }
            AggregatorKind::ExampleWeighted => {
                if c.staleness > 0 {
                    0.0
                } else {
                    c.examples as f64
                }
            }
            AggregatorKind::StalenessDamped { rho } => rho.powi(c.staleness as i32),
        };
        // Partial deliveries fold at fraction-scaled weight; a full set's
        // fraction is exactly 1.0, leaving the legacy arithmetic intact.
        let w = w * c.blocks.fraction();
        if w > 0.0 {
            if c.blocks.is_full() {
                vec_ops::axpy(w as f32, c.grad, out);
            } else {
                for b in 0..c.blocks.len() {
                    if !c.blocks.contains(b) {
                        continue;
                    }
                    let (lo, hi) = c.blocks.range(b, c.grad.len());
                    vec_ops::axpy(w as f32, &c.grad[lo..hi], &mut out[lo..hi]);
                }
            }
            wsum += w;
        }
    }
    assert!(seen > 0, "aggregate with no contributions");
    if wsum > 0.0 {
        vec_ops::scale(out, (1.0 / wsum) as f32);
    }
    wsum
}

/// Aggregate contributions into `out`. Returns the effective weight sum.
pub fn aggregate(kind: AggregatorKind, contribs: &[Contribution<'_>], out: &mut [f32]) -> f64 {
    aggregate_iter(kind, contribs.iter().copied(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(grad: &[f32], staleness: u64) -> Contribution<'_> {
        Contribution::whole(grad, 10, staleness)
    }

    #[test]
    fn mean_ignores_stale() {
        let g1 = vec![2.0, 0.0];
        let g2 = vec![0.0, 2.0];
        let stale = vec![100.0, 100.0];
        let mut out = vec![0.0; 2];
        let w = aggregate(
            AggregatorKind::Mean,
            &[c(&g1, 0), c(&g2, 0), c(&stale, 1)],
            &mut out,
        );
        assert_eq!(w, 2.0);
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn example_weighted() {
        let g1 = vec![1.0];
        let g2 = vec![4.0];
        let contribs = [
            Contribution::whole(&g1, 30, 0),
            Contribution::whole(&g2, 10, 0),
        ];
        let mut out = vec![0.0];
        aggregate(AggregatorKind::ExampleWeighted, &contribs, &mut out);
        // (30*1 + 10*4)/40 = 1.75
        assert!((out[0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn staleness_damped_includes_late() {
        let fresh = vec![1.0];
        let late = vec![3.0];
        let mut out = vec![0.0];
        let w = aggregate(
            AggregatorKind::StalenessDamped { rho: 0.5 },
            &[c(&fresh, 0), c(&late, 1)],
            &mut out,
        );
        // (1*1 + 0.5*3) / 1.5 = 5/3
        assert!((w - 1.5).abs() < 1e-12);
        assert!((out[0] - 5.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn single_contribution_passthrough() {
        let g = vec![0.5, -0.5];
        let mut out = vec![0.0; 2];
        aggregate(AggregatorKind::Mean, &[c(&g, 0)], &mut out);
        assert_eq!(out, g);
    }

    #[test]
    fn full_block_set_matches_whole_fold_bitwise() {
        // A full 4-block mask must produce the identical f32 sequence the
        // whole-gradient fold does (fraction 1.0 multiplies exactly).
        let g1 = vec![0.3, -1.7, 2.9, 0.01, 5.5, -0.125, 8.0, 1e-3];
        let g2 = vec![-2.2, 0.4, 1.1, 3.0, -0.7, 0.9, -4.4, 2.5];
        let mut whole = vec![0.0f32; 8];
        aggregate(AggregatorKind::Mean, &[c(&g1, 0), c(&g2, 0)], &mut whole);
        let mut blocked = vec![0.0f32; 8];
        let full4 = BlockSet::full(4);
        aggregate(
            AggregatorKind::Mean,
            &[
                Contribution { grad: &g1, examples: 10, staleness: 0, blocks: full4 },
                Contribution { grad: &g2, examples: 10, staleness: 0, blocks: full4 },
            ],
            &mut blocked,
        );
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&whole), bits(&blocked));
    }

    #[test]
    fn partial_blocks_fold_only_delivered_ranges() {
        // Two 4-block contributions over dim 8 (2 coords per block); the
        // second lost blocks 1 and 3.
        let g1 = vec![1.0f32; 8];
        let g2 = vec![3.0f32; 8];
        let part = BlockSet::empty(4).with(0).with(2);
        let mut out = vec![0.0f32; 8];
        let w = aggregate(
            AggregatorKind::Mean,
            &[
                Contribution { grad: &g1, examples: 10, staleness: 0, blocks: BlockSet::full(4) },
                Contribution { grad: &g2, examples: 10, staleness: 0, blocks: part },
            ],
            &mut out,
        );
        // Weights: 1.0 and 0.5 → wsum 1.5.
        assert!((w - 1.5).abs() < 1e-12);
        // Delivered ranges: (1*1 + 0.5*3)/1.5 = 5/3; missing: 1/1.5 = 2/3.
        for i in [0usize, 1, 4, 5] {
            assert!((out[i] - 5.0 / 3.0).abs() < 1e-6, "coord {i} = {}", out[i]);
        }
        for i in [2usize, 3, 6, 7] {
            assert!((out[i] - 2.0 / 3.0).abs() < 1e-6, "coord {i} = {}", out[i]);
        }
    }

    #[test]
    fn empty_block_set_contributes_nothing() {
        let g1 = vec![2.0f32, 2.0];
        let g2 = vec![9.0f32, 9.0];
        let mut out = vec![0.0f32; 2];
        let w = aggregate(
            AggregatorKind::Mean,
            &[
                c(&g1, 0),
                Contribution { grad: &g2, examples: 10, staleness: 0, blocks: BlockSet::empty(2) },
            ],
            &mut out,
        );
        assert_eq!(w, 1.0);
        assert_eq!(out, vec![2.0, 2.0]);
    }
}
