//! Straggler comparison on REAL threads and wall-clock: BSP vs ASYNC vs
//! HYBRID on the same cluster with lognormal delays and two chronically
//! slow nodes — the abstract's "dramatically reduce calculation time"
//! demonstrated with actual sleeps, not simulation.
//!
//!     cargo run --release --example straggler_comparison [-- --workers 8 --iters 60]

use hybriditer::bench_harness::{f, Table};
use hybriditer::cli::ArgSpec;
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{Coordinator, LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::straggler::DelayModel;
use hybriditer::worker::NativeKrrFactory;

fn main() -> anyhow::Result<()> {
    hybriditer::util::logger::init();
    let args = ArgSpec::new("straggler_comparison", "BSP vs ASYNC vs HYBRID wall-clock")
        .opt("workers", "8", "cluster size M")
        .opt("iters", "60", "iterations (async: updates = iters*M)")
        .opt("sigma", "1.0", "lognormal delay sigma")
        .parse_or_exit();
    let m = args.get_usize("workers")?;
    let iters = args.get_u64("iters")?;
    let sigma = args.get_f64("sigma")?;

    let spec = KrrProblemSpec::small().with_machines(m);
    let problem = KrrProblem::generate(&spec)?;
    let factory = NativeKrrFactory::for_problem(&problem);

    let cluster = || {
        ClusterSpec {
            workers: m,
            base_compute: 0.002,
            delay: DelayModel::LogNormal { mu: -6.0, sigma },
            ..ClusterSpec::default()
        }
        .with_slow_tail(2, 10.0)
    };
    let base_cfg = || RunConfig {
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    };

    let gamma = (m * 3) / 4;
    let runs: Vec<(&str, SyncMode, u64)> = vec![
        ("bsp", SyncMode::Bsp, iters),
        ("async", SyncMode::Async { damping: 0.0 }, iters * m as u64),
        ("hybrid", SyncMode::Hybrid { gamma }, iters),
    ];

    let mut table = Table::new(
        format!("wall-clock comparison (M={m}, gamma={gamma}, 2 slow nodes @10x)"),
        &["mode", "wall_secs", "iters", "final_loss", "theta_err", "abandon_%"],
    );
    let mut bsp_time = None;
    for (name, mode, it) in runs {
        let mut cfg = base_cfg().with_mode(mode).with_iters(it);
        if name == "async" {
            cfg.optimizer = OptimizerKind::sgd(0.4);
        }
        let coord = Coordinator::new(cluster(), cfg)?;
        let rep = coord.run_real(&factory, &problem)?;
        if name == "bsp" {
            bsp_time = Some(rep.driver_secs);
        }
        println!("{}", rep.summary());
        table.row(vec![
            name.to_string(),
            f(rep.driver_secs, 3),
            rep.recorder.len().to_string(),
            f(rep.final_loss(), 6),
            format!("{:.3e}", problem.theta_err(&rep.theta)),
            f(rep.abandon_rate() * 100.0, 1),
        ]);
        if let Some(bsp) = bsp_time {
            if name == "hybrid" {
                println!(
                    "==> hybrid speedup over BSP: {:.2}x wall-clock",
                    bsp / rep.driver_secs
                );
            }
        }
    }
    table.print();
    table.save_csv("example_straggler_comparison")?;
    Ok(())
}
