"""L2 correctness: the KRR model entry points.

Key cross-check: the pallas ``worker_grad`` must equal jax autodiff of the
objective — an independent derivation of Alg. 3's formula."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _mk(zeta, l, seed):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(0, 1, l), jnp.float32)
    phi = jnp.asarray(rng.normal(0, 1, (zeta, l)), jnp.float32)
    y = jnp.asarray(rng.normal(0, 1, zeta), jnp.float32)
    return theta, phi, y


class TestWorkerGrad:
    def test_equals_autodiff_of_objective(self):
        theta, phi, y = _mk(256, 32, 0)
        lam = 0.2
        (g,) = model.worker_grad(theta, phi, y, lam)
        auto = jax.grad(lambda t: ref.krr_loss(t, phi, y, lam))(theta)
        np.testing.assert_allclose(np.asarray(g), np.asarray(auto), rtol=2e-4, atol=2e-4)

    @settings(max_examples=10, deadline=None)
    @given(zeta=st.integers(16, 256), l=st.sampled_from([8, 32]),
           seed=st.integers(0, 2**31 - 1), lam=st.floats(0.0, 1.0))
    def test_equals_autodiff_hypothesis(self, zeta, l, seed, lam):
        theta, phi, y = _mk(zeta, l, seed)
        (g,) = model.worker_grad(theta, phi, y, lam)
        auto = jax.grad(lambda t: ref.krr_loss(t, phi, y, lam))(theta)
        np.testing.assert_allclose(np.asarray(g), np.asarray(auto), rtol=1e-3, atol=1e-3)

    def test_grad_and_loss_variant_consistent(self):
        theta, phi, y = _mk(512, 64, 1)
        g1, ss = model.worker_grad_loss(theta, phi, y, 0.1)
        (g2,) = model.worker_grad(theta, phi, y, 0.1)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5, atol=1e-5)
        assert abs(float(ss) - float(ref.krr_sumsq(theta, phi, y))) < 1e-1

    def test_ref_twin_matches(self):
        theta, phi, y = _mk(256, 32, 2)
        (g1,) = model.worker_grad(theta, phi, y, 0.1)
        (g2,) = model.worker_grad_ref(theta, phi, y, 0.1)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=2e-4, atol=2e-4)


class TestLossAndPredict:
    def test_full_loss_matches_ref(self):
        theta, phi, y = _mk(256, 32, 3)
        (loss,) = model.full_loss(theta, phi, y, 0.1)
        want = float(ref.krr_loss(theta, phi, y, 0.1))
        assert abs(float(loss) - want) / max(1.0, abs(want)) < 1e-4

    def test_predict(self):
        theta, phi, _ = _mk(128, 16, 4)
        (pred,) = model.predict(theta, phi)
        np.testing.assert_allclose(
            np.asarray(pred), np.asarray(phi @ theta), rtol=1e-5, atol=1e-5
        )

    def test_loss_minimized_at_exact_solution(self):
        """Closed-form ridge solution has smaller loss than perturbations."""
        _, phi, y = _mk(512, 16, 5)
        lam = 0.1
        zeta = phi.shape[0]
        A = np.asarray(phi.T @ phi) / zeta + lam * np.eye(16)
        b = np.asarray(phi.T @ y) / zeta
        theta_star = jnp.asarray(np.linalg.solve(A, b), jnp.float32)
        (l0,) = model.full_loss(theta_star, phi, y, lam)
        rng = np.random.default_rng(6)
        for _ in range(5):
            pert = theta_star + jnp.asarray(rng.normal(0, 0.1, 16), jnp.float32)
            (lp,) = model.full_loss(pert, phi, y, lam)
            assert float(lp) > float(l0)


class TestMasterUpdates:
    def test_sgd_is_alg2_line3(self):
        rng = np.random.default_rng(7)
        theta = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
        grads = [jnp.asarray(rng.normal(0, 1, 64), jnp.float32) for _ in range(5)]
        gamma, eta = 5, 0.3
        gsum = sum(grads)
        (t2,) = model.master_update_sgd(theta, gsum, eta / gamma)
        want = theta - (eta / gamma) * gsum
        np.testing.assert_allclose(np.asarray(t2), np.asarray(want), rtol=1e-5, atol=1e-5)
