//! Recovery-policy properties (see `docs/RECOVERY.md`).
//!
//! The parity suite (`parity_drivers.rs`) pins both drivers to the same
//! recovery decisions; this file pins the *semantics* of each policy:
//!
//! * crash-free runs are bit-identical across all four policies — a
//!   policy may only act when something actually fails;
//! * `partial-recovery` touches nothing until a rejoin lands: the
//!   trajectory prefix before the first catch-up matches `abandon`
//!   bit for bit;
//! * `checkpoint-restore` never rolls back past the last snapshot:
//!   every restore is bounded by the snapshot cadence;
//! * policy auto-respawn keeps a chronically crashing worker
//!   contributing (virtual and threaded supervisors);
//! * async modes reject every non-abandon policy up front.

use hybriditer::cluster::{ClusterSpec, ElasticSchedule};
use hybriditer::coordinator::{AggregatorKind, Coordinator, LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::recovery::{RecoveryConfig, RecoveryPolicy};
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::FailureModel;
use hybriditer::worker::NativeKrrFactory;

const ALL_POLICIES: [RecoveryPolicy; 4] = [
    RecoveryPolicy::Abandon,
    RecoveryPolicy::Rebalance,
    RecoveryPolicy::PartialRecovery,
    RecoveryPolicy::CheckpointRestore,
];

fn problem(machines: usize) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "recovery".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 64,
        seed: 17,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn cfg(p: &KrrProblem, policy: RecoveryPolicy, checkpoint_every: u64) -> RunConfig {
    RunConfig {
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        recovery: RecoveryConfig { policy, checkpoint_every },
        ..RunConfig::default()
    }
}

#[test]
fn crash_free_runs_bit_identical_across_policies() {
    // With nothing failing, a recovery policy must be invisible: same θ
    // bits, zero recoveries, zero rollback — for all four policies.
    let p = problem(4);
    let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
    let mut baseline: Option<Vec<f32>> = None;
    for policy in ALL_POLICIES {
        let c = cfg(&p, policy, 5).with_mode(SyncMode::Hybrid { gamma: 4 }).with_iters(40);
        let mut pool = p.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &c, &NoEval).unwrap();
        assert!(rep.status.is_healthy(), "{policy:?}: {:?}", rep.status);
        assert_eq!(rep.recoveries, 0, "{policy:?} fired without a failure");
        assert_eq!(rep.rollback_iters, 0, "{policy:?} rolled back without a failure");
        match &baseline {
            None => baseline = Some(rep.theta),
            Some(theta) => {
                assert_eq!(&rep.theta, theta, "{policy:?} perturbed a crash-free run")
            }
        }
    }
}

#[test]
fn partial_recovery_prefix_matches_abandon_then_diverges() {
    // Workers 1 and 3 leave at 4 and rejoin at 8.  Partial recovery does
    // all of its work at the rejoin, so every recorded iteration before
    // it must match the abandon baseline bit for bit; the catch-up fold
    // then moves θ off the baseline.
    let m = 4;
    let p = problem(m);
    let cluster = ClusterSpec { workers: m, ..ClusterSpec::default() }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 3], 4, 8), 1);
    let mk = |policy| {
        let mut c = cfg(&p, policy, 25).with_mode(SyncMode::Hybrid { gamma: m }).with_iters(20);
        c.aggregator = AggregatorKind::StalenessDamped { rho: 0.5 };
        let mut pool = p.native_pool();
        sim::run_virtual(&mut pool, &cluster, &c, &NoEval).unwrap()
    };
    let abandon = mk(RecoveryPolicy::Abandon);
    let partial = mk(RecoveryPolicy::PartialRecovery);
    assert!(partial.status.is_healthy(), "{:?}", partial.status);
    assert_eq!(partial.recoveries, 2, "one catch-up per rejoiner");
    assert_eq!(partial.rollback_iters, 0, "partial recovery never rolls back");

    for (pa, pb) in abandon.recorder.rows().iter().zip(partial.recorder.rows()) {
        assert_eq!(pa.iter, pb.iter);
        if pa.iter < 8 {
            assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "iter {} loss moved", pa.iter);
            assert_eq!(pa.included, pb.included, "iter {}", pa.iter);
            assert_eq!(pa.alive, pb.alive, "iter {}", pa.iter);
            assert_eq!(pb.recoveries, 0, "iter {}: recovery before the rejoin", pa.iter);
        }
    }
    let row_total: usize = partial.recorder.rows().iter().map(|r| r.recoveries).sum();
    assert_eq!(row_total as u64, partial.recoveries, "per-row deltas don't sum to rollup");
    assert_ne!(abandon.theta, partial.theta, "catch-up never reached the aggregator");
}

#[test]
fn checkpoint_rollback_bounded_by_cadence() {
    // Stochastic crashes under checkpoint-restore: every restore rewinds
    // to the *latest* snapshot, so each recovery's rollback is at most
    // checkpoint_every − 1 iterations, and the per-row deltas must sum
    // to the run-level rollups exactly.
    let every = 5u64;
    let p = problem(6);
    let cluster = ClusterSpec {
        workers: 6,
        failure: FailureModel { crash_prob: 0.03, transient_prob: 0.0, rejoin_after: None },
        seed: 13,
        rebalance_every: 1,
        ..ClusterSpec::default()
    };
    let c = cfg(&p, RecoveryPolicy::CheckpointRestore, every)
        .with_mode(SyncMode::Hybrid { gamma: 3 })
        .with_iters(150);
    let mut pool = p.native_pool();
    let rep = sim::run_virtual(&mut pool, &cluster, &c, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert!(rep.crashes > 0, "no crash injected at 3% over 150 iterations");
    assert!(rep.recoveries > 0, "crashes fired no restores");

    let mut recov_sum = 0u64;
    let mut rollback_sum = 0u64;
    for row in rep.recorder.rows() {
        // A row may aggregate several same-iteration restores; each one
        // is individually bounded by the snapshot cadence.
        assert!(
            row.rollback_iters <= (every - 1) * row.recoveries as u64,
            "iter {}: rolled back {} across {} recoveries (cadence {})",
            row.iter,
            row.rollback_iters,
            row.recoveries,
            every
        );
        recov_sum += row.recoveries as u64;
        rollback_sum += row.rollback_iters;
    }
    assert_eq!(recov_sum, rep.recoveries, "per-row recovery deltas don't sum to rollup");
    assert_eq!(rollback_sum, rep.rollback_iters, "per-row rollback deltas don't sum to rollup");
}

#[test]
fn auto_respawn_keeps_crashy_worker_contributing() {
    // Worker 2 crashes on every dispatch.  Under abandon it dies once
    // and stays dead; under partial recovery the supervisor respawns it
    // at every next boundary, so it keeps crashing — and every respawn's
    // rejoin queues a catch-up for its lost shard.
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        failure: FailureModel { crash_prob: 1.0, transient_prob: 0.0, rejoin_after: None },
        failure_only: vec![2],
        ..ClusterSpec::default()
    };
    let mk = |policy| {
        let c = cfg(&p, policy, 25).with_mode(SyncMode::Hybrid { gamma: 2 }).with_iters(12);
        let mut pool = p.native_pool();
        sim::run_virtual(&mut pool, &cluster, &c, &NoEval).unwrap()
    };
    let abandon = mk(RecoveryPolicy::Abandon);
    let partial = mk(RecoveryPolicy::PartialRecovery);
    assert!(abandon.status.is_healthy(), "{:?}", abandon.status);
    assert!(partial.status.is_healthy(), "{:?}", partial.status);
    assert_eq!(abandon.crashes, 1, "abandon: the worker dies exactly once");
    assert!(partial.crashes >= 10, "supervisor stopped respawning: {}", partial.crashes);
    assert!(partial.recoveries >= 10, "respawns queued no catch-ups: {}", partial.recoveries);
}

#[test]
fn threaded_auto_respawn_under_partial_recovery() {
    // Same supervisor loop on real threads: each respawn spawns a fresh
    // slave (generation-salted RNG, new channel) which promptly crashes
    // again on its first Work message.
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0,
        failure: FailureModel { crash_prob: 1.0, transient_prob: 0.0, rejoin_after: None },
        failure_only: vec![3],
        ..ClusterSpec::default()
    };
    let c = cfg(&p, RecoveryPolicy::PartialRecovery, 25)
        .with_mode(SyncMode::Hybrid { gamma: 2 })
        .with_iters(10);
    let coord = Coordinator::new(cluster, c).unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert!(rep.crashes >= 3, "threaded supervisor stopped respawning: {}", rep.crashes);
    assert!(rep.recoveries >= 2, "respawns queued no catch-ups: {}", rep.recoveries);
    assert_eq!(rep.rollback_iters, 0);
}

#[test]
fn async_rejects_non_abandon_policies() {
    // Async has no crash/rejoin barrier to recover at: both drivers must
    // refuse every non-abandon policy at config time, before spawning
    // anything.
    let p = problem(4);
    let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
    for policy in [
        RecoveryPolicy::Rebalance,
        RecoveryPolicy::PartialRecovery,
        RecoveryPolicy::CheckpointRestore,
    ] {
        let c = cfg(&p, policy, 5).with_mode(SyncMode::Async { damping: 0.0 }).with_iters(50);
        let mut pool = p.native_pool();
        let virt = sim::run_virtual(&mut pool, &cluster, &c, &NoEval);
        let msg = virt.expect_err("virtual async accepted a recovery policy").to_string();
        assert!(msg.contains("not supported in async mode"), "{policy:?}: {msg}");
        let real = Coordinator::new(cluster.clone(), c);
        let msg = real.err().expect("threaded async accepted a recovery policy").to_string();
        assert!(msg.contains("not supported in async mode"), "{policy:?}: {msg}");
    }
}
