//! The unified run entry point: one builder over both drivers.
//!
//! Historically each driver grew its own pair of entry points —
//! `sim::run_virtual` / `run_virtual_traced` for virtual time and
//! `worker::run_real` / `Coordinator::run_real_traced` for real threads.
//! [`Runner`] collapses the four into one builder:
//!
//! ```no_run
//! # use hybriditer::prelude::*;
//! # use hybriditer::data::{KrrProblem, KrrProblemSpec};
//! # fn demo(problem: &KrrProblem, cluster: &ClusterSpec, cfg: &RunConfig)
//! #     -> hybriditer::Result<()> {
//! let mut pool = problem.native_pool();
//! let report = Runner::new(cluster, cfg)
//!     .driver(Driver::Virtual)
//!     .pool(&mut pool)
//!     .hooks(problem)
//!     .run()?;
//! # Ok(()) }
//! ```
//!
//! The old functions survive as thin wrappers (so parity/golden suites
//! stay byte-stable), but new capabilities land here first: **online
//! serving mode** ([`crate::serve`]) is only reachable through
//! [`Runner::serve`] — none of the legacy signatures accept a
//! [`ServeSpec`], which is what guarantees their behaviour cannot drift.

use crate::cluster::ClusterSpec;
use crate::coordinator::{RunConfig, RunReport};
use crate::data::ComputePool;
use crate::serve::ServeSpec;
use crate::sim::EvalHooks;
use crate::trace::{NoopSink, TraceSink};
use crate::worker::ComputeFactory;
use crate::{Error, Result};

/// Which execution engine realizes the run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Driver {
    /// Discrete-event simulation in virtual time (`rust/src/sim/`):
    /// needs a [`ComputePool`] via [`Runner::pool`].
    Virtual,
    /// Real worker threads measuring wall-clock (`rust/src/worker/`):
    /// needs a [`ComputeFactory`] via [`Runner::factory`].
    Threaded,
}

enum Compute<'a> {
    Unset,
    Pool(&'a mut dyn ComputePool),
    Factory(&'a dyn ComputeFactory),
}

/// Builder-style configuration of a single run. See the module docs.
pub struct Runner<'a> {
    cluster: &'a ClusterSpec,
    cfg: &'a RunConfig,
    driver: Driver,
    compute: Compute<'a>,
    hooks: Option<&'a dyn EvalHooks>,
    sink: Option<&'a mut dyn TraceSink>,
    serve: Option<ServeSpec>,
}

impl<'a> Runner<'a> {
    /// A runner for `(cluster, cfg)`, defaulting to the virtual driver,
    /// no tracing, no eval hooks, and no serving.
    pub fn new(cluster: &'a ClusterSpec, cfg: &'a RunConfig) -> Self {
        Runner {
            cluster,
            cfg,
            driver: Driver::Virtual,
            compute: Compute::Unset,
            hooks: None,
            sink: None,
            serve: None,
        }
    }

    /// Select the execution engine.
    pub fn driver(mut self, driver: Driver) -> Self {
        self.driver = driver;
        self
    }

    /// Attach the compute pool the virtual driver dispatches onto.
    pub fn pool(mut self, pool: &'a mut dyn ComputePool) -> Self {
        self.compute = Compute::Pool(pool);
        self
    }

    /// Attach the factory the threaded driver builds per-worker compute
    /// from.
    pub fn factory(mut self, factory: &'a dyn ComputeFactory) -> Self {
        self.compute = Compute::Factory(factory);
        self
    }

    /// Attach evaluation hooks (loss/θ-error probes). Defaults to
    /// [`crate::sim::NoEval`].
    pub fn hooks(mut self, hooks: &'a dyn EvalHooks) -> Self {
        self.hooks = Some(hooks);
        self
    }

    /// Attach a flight-recorder sink ([`crate::trace`]). Defaults to
    /// [`NoopSink`], which keeps every emission site free.
    pub fn trace(mut self, sink: &'a mut dyn TraceSink) -> Self {
        self.sink = Some(sink);
        self
    }

    /// Enable online serving mode ([`crate::serve`]): the run steps an
    /// open-loop arrival process at every barrier close and publishes θ
    /// through a [`crate::serve::ThetaCell`]; the report carries
    /// [`crate::serve::ServeStats`]. Serving is *only* exposed here.
    pub fn serve(mut self, spec: ServeSpec) -> Self {
        self.serve = Some(spec);
        self
    }

    /// Execute the run. Fails fast on a driver/compute mismatch or an
    /// invalid [`ServeSpec`]; everything else is the wrapped driver's
    /// own validation, unchanged.
    pub fn run(self) -> Result<RunReport> {
        if let Some(spec) = &self.serve {
            spec.validate()?;
        }
        let hooks = self.hooks.unwrap_or(&crate::sim::NoEval);
        let mut noop = NoopSink;
        let sink: &mut dyn TraceSink = match self.sink {
            Some(s) => s,
            None => &mut noop,
        };
        match (self.driver, self.compute) {
            (Driver::Virtual, Compute::Pool(pool)) => {
                crate::sim::run_virtual_serving(
                    pool,
                    self.cluster,
                    self.cfg,
                    hooks,
                    sink,
                    self.serve.as_ref(),
                )
            }
            (Driver::Threaded, Compute::Factory(factory)) => crate::worker::run_real_serving(
                self.cluster,
                self.cfg,
                factory,
                hooks,
                sink,
                self.serve.as_ref(),
            ),
            (Driver::Virtual, _) => Err(Error::Config(
                "the virtual driver dispatches onto a compute pool; \
                 attach one with Runner::pool(..)"
                    .to_string(),
            )),
            (Driver::Threaded, _) => Err(Error::Config(
                "the threaded driver builds workers from a compute factory; \
                 attach one with Runner::factory(..)"
                    .to_string(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SyncMode;
    use crate::data::{KrrProblem, KrrProblemSpec};
    use crate::optim::OptimizerKind;
    use crate::serve::AdmissionPolicy;
    use crate::worker::NativeKrrFactory;

    fn tiny_problem(machines: usize) -> KrrProblem {
        let spec = KrrProblemSpec {
            config: "runner-test".into(),
            d: 4,
            l: 16,
            zeta: 64,
            machines,
            noise: 0.05,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 64,
            seed: 23,
        };
        KrrProblem::generate(&spec).unwrap()
    }

    fn cfg(problem: &KrrProblem, iters: u64) -> RunConfig {
        RunConfig {
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: crate::coordinator::LossForm::krr(problem.spec.lambda),
            eval_every: 0,
            ..RunConfig::default()
        }
        .with_mode(SyncMode::Bsp)
        .with_iters(iters)
    }

    #[test]
    fn virtual_runner_matches_legacy_entry_point() {
        let p = tiny_problem(4);
        let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
        let cfg = cfg(&p, 40);

        let mut pool = p.native_pool();
        let legacy = crate::sim::run_virtual(&mut pool, &cluster, &cfg, &p).unwrap();

        let mut pool = p.native_pool();
        let built = Runner::new(&cluster, &cfg)
            .driver(Driver::Virtual)
            .pool(&mut pool)
            .hooks(&p)
            .run()
            .unwrap();

        assert_eq!(legacy.theta, built.theta);
        assert_eq!(legacy.total_contributions, built.total_contributions);
        assert!(built.serve.is_none());
    }

    #[test]
    fn threaded_runner_matches_legacy_entry_point() {
        let p = tiny_problem(2);
        let cluster = ClusterSpec { workers: 2, ..ClusterSpec::default() };
        let cfg = cfg(&p, 10);
        let factory = NativeKrrFactory::for_problem(&p);

        let legacy = crate::worker::run_real(&cluster, &cfg, &factory, &p).unwrap();
        let built = Runner::new(&cluster, &cfg)
            .driver(Driver::Threaded)
            .factory(&factory)
            .hooks(&p)
            .run()
            .unwrap();

        assert_eq!(legacy.theta, built.theta);
        assert!(built.serve.is_none());
    }

    #[test]
    fn driver_compute_mismatch_is_rejected() {
        let p = tiny_problem(2);
        let cluster = ClusterSpec { workers: 2, ..ClusterSpec::default() };
        let cfg = cfg(&p, 5);
        let factory = NativeKrrFactory::for_problem(&p);
        let mut pool = p.native_pool();

        let err = Runner::new(&cluster, &cfg)
            .driver(Driver::Virtual)
            .factory(&factory)
            .run()
            .unwrap_err();
        assert!(format!("{err}").contains("pool"), "{err}");

        let err = Runner::new(&cluster, &cfg)
            .driver(Driver::Threaded)
            .pool(&mut pool)
            .run()
            .unwrap_err();
        assert!(format!("{err}").contains("factory"), "{err}");
    }

    #[test]
    fn serving_run_reports_serve_stats() {
        let p = tiny_problem(4);
        let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
        let cfg = cfg(&p, 60);
        let spec = crate::serve::ServeSpec {
            arrival_rate: 2_000.0,
            admission: AdmissionPolicy::Shed,
            ..crate::serve::ServeSpec::default()
        };

        let mut pool = p.native_pool();
        let rep = Runner::new(&cluster, &cfg)
            .driver(Driver::Virtual)
            .pool(&mut pool)
            .hooks(&p)
            .serve(spec)
            .run()
            .unwrap();

        let sv = rep.serve.as_ref().expect("serving run must carry ServeStats");
        assert_eq!(sv.windows, 60);
        assert!(sv.offered > 0);
        assert_eq!(sv.theta_epochs, 60);

        // Serving must not perturb training: the same run without a
        // serve spec produces bit-identical θ.
        let mut pool = p.native_pool();
        let plain = Runner::new(&cluster, &cfg)
            .driver(Driver::Virtual)
            .pool(&mut pool)
            .hooks(&p)
            .run()
            .unwrap();
        assert_eq!(plain.theta, rep.theta);
    }

    #[test]
    fn invalid_serve_spec_fails_fast() {
        let p = tiny_problem(2);
        let cluster = ClusterSpec { workers: 2, ..ClusterSpec::default() };
        let cfg = cfg(&p, 5);
        let mut pool = p.native_pool();
        let spec =
            crate::serve::ServeSpec { update_frac: 2.0, ..crate::serve::ServeSpec::default() };
        assert!(Runner::new(&cluster, &cfg)
            .driver(Driver::Virtual)
            .pool(&mut pool)
            .serve(spec)
            .run()
            .is_err());
    }
}
