//! Runtime integration: AOT artifacts load, execute, and agree with the
//! pure-rust mirror — the end-to-end L1/L2 ⇄ L3 numerical contract.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use hybriditer::data::{ComputePool, KrrProblem, KrrProblemSpec};
use hybriditer::runtime::{literal, ArtifactSet, Engine};
use hybriditer::util::rng::Pcg64;
use hybriditer::worker::compute::XlaKrrPool;

fn artifacts_or_skip() -> Option<ArtifactSet> {
    match ArtifactSet::discover() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let m = artifacts.manifest();
    for name in [
        "krr_worker_grad_small",
        "krr_worker_grad_loss_small",
        "krr_worker_grad_ref_small",
        "krr_full_loss_small",
        "rbf_features_small",
        "master_update_sgd_small",
        "lm_step_lm_tiny",
    ] {
        assert!(m.get(name).is_ok(), "missing {name}");
    }
}

#[test]
fn pallas_kernel_artifact_matches_ref_artifact() {
    // The pallas-kernel artifact and the pure-jnp oracle artifact must agree
    // when executed by the rust runtime: cross-checks L1 (kernel), L2
    // (lowering) and L3 (literal marshalling) in one shot.
    let Some(artifacts) = artifacts_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let kernel = artifacts.load(&engine, "krr_worker_grad_small").unwrap();
    let oracle = artifacts.load(&engine, "krr_worker_grad_ref_small").unwrap();

    let info = kernel.info().clone();
    let l = info.meta_usize("l").unwrap();
    let zeta = info.meta_usize("zeta").unwrap();

    let mut rng = Pcg64::seeded(42);
    let mut theta = vec![0.0f32; l];
    rng.fill_normal(&mut theta, 0.0, 1.0);
    let mut phi = vec![0.0f32; zeta * l];
    rng.fill_normal(&mut phi, 0.0, 1.0);
    let mut y = vec![0.0f32; zeta];
    rng.fill_normal(&mut y, 0.0, 1.0);

    let args = |_: ()| -> Vec<xla::Literal> {
        vec![
            literal::lit_f32(&theta, &[l]).unwrap(),
            literal::lit_f32(&phi, &[zeta, l]).unwrap(),
            literal::lit_f32(&y, &[zeta]).unwrap(),
            literal::lit_scalar_f32(0.1),
        ]
    };
    let g_kernel = literal::to_vec_f32(&kernel.run(&args(())).unwrap()[0]).unwrap();
    let g_oracle = literal::to_vec_f32(&oracle.run(&args(())).unwrap()[0]).unwrap();
    assert_eq!(g_kernel.len(), l);
    for (a, b) in g_kernel.iter().zip(&g_oracle) {
        assert!((a - b).abs() < 5e-4, "kernel {a} vs oracle {b}");
    }
}

#[test]
fn xla_pool_matches_native_pool() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let spec = KrrProblemSpec::small().with_machines(4);
    let problem = KrrProblem::generate(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut xla_pool = XlaKrrPool::new(
        &artifacts,
        &engine,
        "small",
        &problem.shards,
        spec.lambda as f32,
    )
    .unwrap();
    let mut native = problem.native_pool();

    let mut rng = Pcg64::seeded(7);
    let mut theta = vec![0.0f32; problem.dim()];
    rng.fill_normal(&mut theta, 0.0, 1.0);

    for w in 0..4 {
        let gx = xla_pool.grad(w, &theta, 0).unwrap();
        let gn = native.grad(w, &theta, 0).unwrap();
        assert_eq!(gx.examples, gn.examples);
        let max_diff = gx
            .grad
            .iter()
            .zip(&gn.grad)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-3, "worker {w}: grad diff {max_diff}");
        let lx = gx.loss_sum.unwrap();
        let ln = gn.loss_sum.unwrap();
        assert!(
            (lx - ln).abs() / ln.max(1.0) < 1e-3,
            "worker {w}: loss {lx} vs {ln}"
        );
    }
}

#[test]
fn master_update_artifact_applies_sgd() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let exe = artifacts.load(&engine, "master_update_sgd_small").unwrap();
    let l = exe.info().meta_usize("l").unwrap();

    let theta = vec![1.0f32; l];
    let gsum = vec![2.0f32; l];
    let outs = exe
        .run(&[
            literal::lit_f32(&theta, &[l]).unwrap(),
            literal::lit_f32(&gsum, &[l]).unwrap(),
            literal::lit_scalar_f32(0.25),
        ])
        .unwrap();
    let updated = literal::to_vec_f32(&outs[0]).unwrap();
    for v in updated {
        assert!((v - 0.5).abs() < 1e-6); // 1 - 0.25*2
    }
}

#[test]
fn rbf_features_artifact_is_bounded_and_deterministic() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let exe = artifacts.load(&engine, "rbf_features_small").unwrap();
    let info = exe.info().clone();
    let d = info.meta_usize("d").unwrap();
    let l = info.meta_usize("l").unwrap();
    let zeta = info.meta_usize("zeta").unwrap();

    let mut rng = Pcg64::seeded(3);
    let mut x = vec![0.0f32; zeta * d];
    rng.fill_uniform(&mut x, -1.0, 1.0);
    let mut w = vec![0.0f32; d * l];
    rng.fill_normal(&mut w, 0.0, 1.0);
    let mut b = vec![0.0f32; l];
    rng.fill_uniform(&mut b, 0.0, 6.28);

    let run = || {
        literal::to_vec_f32(
            &exe.run(&[
                literal::lit_f32(&x, &[zeta, d]).unwrap(),
                literal::lit_f32(&w, &[d, l]).unwrap(),
                literal::lit_f32(&b, &[l]).unwrap(),
            ])
            .unwrap()[0],
        )
        .unwrap()
    };
    let phi1 = run();
    let phi2 = run();
    assert_eq!(phi1, phi2, "executions must be deterministic");
    let bound = (2.0f32 / l as f32).sqrt() + 1e-5;
    assert!(phi1.iter().all(|v| v.abs() <= bound));
}

#[test]
fn wrong_input_count_is_rejected() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let exe = artifacts.load(&engine, "master_update_sgd_small").unwrap();
    let r = exe.run(&[literal::lit_scalar_f32(1.0)]);
    assert!(r.is_err());
}
