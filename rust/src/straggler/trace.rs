//! Latency-trace recording & replay.
//!
//! Real deployments tune the hybrid barrier against *measured* latency
//! distributions.  [`TraceRecorder`] captures per-worker iteration latencies
//! from any run; traces round-trip through a simple one-float-per-line text
//! format and feed [`super::DelayModel::Trace`] for replay experiments.

use std::io::{BufRead, Write};
use std::path::Path;
use std::sync::Arc;

use crate::straggler::DelayModel;
use crate::{Error, Result};

/// Collects observed latencies (seconds).
#[derive(Clone, Debug, Default)]
pub struct TraceRecorder {
    samples: Vec<f64>,
}

impl TraceRecorder {
    pub fn new() -> TraceRecorder {
        TraceRecorder::default()
    }

    pub fn record(&mut self, latency_secs: f64) {
        self.samples.push(latency_secs);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Convert into a replayable delay model.
    pub fn into_model(self) -> DelayModel {
        DelayModel::Trace {
            samples: Arc::new(self.samples),
            cursor_seed: 0,
        }
    }

    /// Write one sample per line.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for s in &self.samples {
            writeln!(f, "{s}")?;
        }
        Ok(())
    }
}

/// Load a trace file into a replayable delay model.
pub fn load(path: &Path) -> Result<DelayModel> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut samples = Vec::new();
    for (i, line) in f.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        let v: f64 = t
            .parse()
            .map_err(|_| Error::Config(format!("{}:{}: bad float '{t}'", path.display(), i + 1)))?;
        if v < 0.0 {
            return Err(Error::Config(format!(
                "{}:{}: negative latency {v}",
                path.display(),
                i + 1
            )));
        }
        samples.push(v);
    }
    if samples.is_empty() {
        return Err(Error::Config(format!("{}: empty trace", path.display())));
    }
    Ok(DelayModel::Trace {
        samples: Arc::new(samples),
        cursor_seed: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_file() {
        let dir = std::env::temp_dir().join("hybriditer_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        let mut rec = TraceRecorder::new();
        for i in 0..10 {
            rec.record(i as f64 * 0.001);
        }
        rec.save(&path).unwrap();
        let model = load(&path).unwrap();
        match model {
            DelayModel::Trace { samples, .. } => {
                assert_eq!(samples.len(), 10);
                assert!((samples[3] - 0.003).abs() < 1e-12);
            }
            _ => panic!("wrong model"),
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_rejects_bad_lines() {
        let dir = std::env::temp_dir().join("hybriditer_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.trace");
        std::fs::write(&path, "0.1\nnot_a_number\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "-0.5\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, "# only comments\n").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn recorder_into_model() {
        let mut rec = TraceRecorder::new();
        rec.record(0.5);
        let m = rec.into_model();
        let mut rng = crate::util::rng::Pcg64::seeded(1);
        assert_eq!(m.sample(&mut rng), 0.5);
    }
}
