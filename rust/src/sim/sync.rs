//! Synchronous policies (BSP / hybrid family) over the event engine.
//!
//! Each iteration opens a *window*: the boundary event handler applies
//! elastic membership changes and shard rebalances, every responder's
//! roundtrip is dispatched through the transport onto the engine's event
//! heap, and the [`PartialBarrier`] classifies arrivals as they pop.
//!
//! # Cross-iteration reordering
//!
//! Replies are first-class events, so a straggler can out-live its
//! iteration window: under a non-ideal [`crate::net::NetSpec`], events
//! still pending when the window closes (at `close + master_overhead`,
//! the instant the next `Work` broadcast goes out) are *rebased* into the
//! next window, where the barrier classifies them as
//! [`Admission::Stale`] — exactly what the threaded master sees when a
//! slow reply lands during a later collect loop.  Under an ideal spec
//! nothing is ever rebased: every reply of iteration `t` is drained inside
//! window `t` and the loop reproduces the pre-refactor lockstep driver
//! **bit for bit** (timing arithmetic, admission order, f32 fold order —
//! see `tests/parity_drivers.rs` golden tests).
//!
//! # Crash-during-rebalance
//!
//! The failure sweep runs *before* dispatch, so a crash observed this
//! iteration (including an adopter crashing in the same boundary it
//! adopted orphaned shards) triggers an immediate re-plan inside the
//! barrier ([`crate::cluster::ElasticRuntime::replan_orphans`]) — the
//! orphaned shards contribute this very iteration instead of a boundary
//! later.

use crate::cluster::ClusterSpec;
use crate::coordinator::aggregator::{aggregate_iter, Contribution};
use crate::coordinator::barrier::{Admission, PartialBarrier};
use crate::coordinator::convergence::{ConvergenceTracker, RunStatus};
use crate::coordinator::estimator::{AdaptiveEstimator, EstimatorParams};
use crate::coordinator::{BspRecovery, RunConfig, RunReport, SyncMode};
use crate::data::ComputePool;
use crate::math::vec_ops;
use crate::metrics::{IterRow, Recorder};
use crate::net::{BlockLedger, BlockSet, Transport, VirtualTransport};
use crate::straggler::FailureEvent;
use crate::trace::{self, TraceEvent, TraceSink};
use crate::{Error, Result};

use super::engine::{EngineCore, Event};
use super::{report, EvalHooks};

/// Slab of reusable [`crate::data::GradResult`] slots: `clear()` resets the
/// cursor without dropping the gradient buffers, `next()` hands out the
/// next slot (the slab grows only until its high-water mark is reached, so
/// steady-state iterations recycle the same allocations).
struct GradArena {
    slots: Vec<crate::data::GradResult>,
    len: usize,
}

impl GradArena {
    fn new() -> GradArena {
        GradArena { slots: Vec::new(), len: 0 }
    }

    fn clear(&mut self) {
        self.len = 0;
    }

    fn next(&mut self) -> &mut crate::data::GradResult {
        if self.len == self.slots.len() {
            self.slots.push(crate::data::GradResult::empty());
        }
        self.len += 1;
        &mut self.slots[self.len - 1]
    }

    fn results(&self) -> &[crate::data::GradResult] {
        &self.slots[..self.len]
    }
}

/// Per-iteration scratch the sync policy reuses across iterations.  Every
/// buffer the loop needs lives here and is cleared (capacity kept) rather
/// than reallocated, so a steady-state virtual iteration performs **zero**
/// heap allocations after warmup — asserted by `tests/alloc_regression.rs`.
/// Pure buffer reuse: the computed values are bit-identical to the
/// allocate-per-iteration seed driver (see `tests/parity_drivers.rs`).
struct IterScratch {
    /// Per-worker failure events this iteration.
    events: Vec<FailureEvent>,
    /// Per-worker response latency (∞ = no response).
    latency: Vec<f64>,
    /// Workers that respond this iteration.
    responders: Vec<usize>,
    /// Per-worker owned-shard lists (ownership snapshot).
    assignment: Vec<Vec<usize>>,
    /// Shards admitted by the barrier (ascending) with the delivered block
    /// set of the reply that carried each — [`BlockSet::full`] whenever
    /// block admission is off.
    included_shards: Vec<(usize, BlockSet)>,
    /// Workers admitted by the barrier.
    included_workers: Vec<usize>,
    /// Workers whose primary reply was delivered this window.
    arrived_workers: Vec<usize>,
    /// BSP: per-worker delivery mask.
    delivered: Vec<bool>,
    /// BSP: shards with no delivered owner.
    missing: Vec<usize>,
    /// Reuse ablation: arrived-but-abandoned workers, ascending.
    late: Vec<usize>,
    /// The partial barrier, `reset()` per iteration.
    barrier: PartialBarrier,
    /// This iteration's included gradients.
    grads: GradArena,
    /// Staleness-1 gradients carried into the next iteration.
    carryover: GradArena,
    /// Delivered block set per carryover slot (parallel to `carryover`).
    carry_blocks: Vec<BlockSet>,
    /// Block admission only: which `(worker, iter)` blocks have already
    /// been folded, so a duplicate or straggling copy with an overlapping
    /// delivered set never double-counts a block.
    ledger: BlockLedger,
    /// Stale-admitted block sets this window: `(worker, staleness, fresh)`.
    stale_admits: Vec<(usize, u64, BlockSet)>,
    /// Gradients recomputed for stale-admitted blocks.
    stale_arena: GradArena,
    /// `(staleness, blocks)` per stale-arena slot.
    stale_meta: Vec<(u64, BlockSet)>,
    /// Workers the recovery supervisor respawns this boundary.
    respawns: Vec<usize>,
    /// Lost-partition catch-ups drained for this aggregation.
    catchups: Vec<crate::recovery::CatchUp>,
    /// Gradients recomputed for lost-partition catch-ups.
    catchup_arena: GradArena,
    /// Staleness (= downtime) per catch-up-arena slot.
    catchup_meta: Vec<u64>,
}

impl IterScratch {
    fn new(m: usize) -> IterScratch {
        IterScratch {
            events: vec![FailureEvent::Healthy; m],
            latency: vec![f64::INFINITY; m],
            responders: Vec::with_capacity(m),
            assignment: Vec::new(),
            included_shards: Vec::with_capacity(m),
            included_workers: Vec::with_capacity(m),
            arrived_workers: Vec::with_capacity(m),
            delivered: vec![false; m],
            missing: Vec::with_capacity(m),
            late: Vec::with_capacity(m),
            barrier: PartialBarrier::new(0, m, 1),
            grads: GradArena::new(),
            carryover: GradArena::new(),
            carry_blocks: Vec::with_capacity(m),
            ledger: BlockLedger::default(),
            stale_admits: Vec::with_capacity(m),
            stale_arena: GradArena::new(),
            stale_meta: Vec::with_capacity(m),
            respawns: Vec::new(),
            catchups: Vec::new(),
            catchup_arena: GradArena::new(),
            catchup_meta: Vec::new(),
        }
    }
}

/// BSP network-aware retry: attempts per missing shard before the master
/// gives up on the lossy path and fetches over a reliable channel (forced
/// success), and the exponent cap on the detection-timeout backoff
/// (`detect_timeout · min(2^k, 2^BSP_RETRY_BACKOFF_CAP)`).
const BSP_RETRY_MAX_ATTEMPTS: u64 = 8;
const BSP_RETRY_BACKOFF_CAP: u64 = 5;

/// How many iterations a `(worker, iter)` block-claim entry outlives its
/// window before the ledger drops it.  Far beyond any plausible straggler
/// horizon; bounds ledger memory under long lossy runs.
const BLOCK_LEDGER_HORIZON: u64 = 64;

/// Burn a responder-less (or deliverable-less) detection window of `len`
/// virtual seconds: in-flight stragglers landing inside it are stale
/// arrivals with no barrier to offer them to — account and discard — and
/// everything later is rebased into the next window.
fn burn_window(core: &mut EngineCore, len: f64) {
    while let Some(ev) = core.heap.pop_before(len) {
        core.membership.record_abandoned(ev.worker);
    }
    core.heap.rebase(len);
}

pub(super) fn run_sync(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
    driver_start: std::time::Instant,
    sink: &mut dyn TraceSink,
    serve: Option<&crate::serve::ServeSpec>,
) -> Result<RunReport> {
    let m = pool.n_workers();
    let dim = pool.dim();
    // Serving engine (None without a [serve] config): stepped once per
    // completed iteration at barrier close, keyed on the iteration index
    // — burned windows never advance the serve clock (docs/SERVING.md).
    let mut serving = serve.map(crate::serve::ServeEngine::new);
    let profiles = cluster.profiles();
    let n_total: usize = (0..m).map(|w| pool.shard_examples(w)).sum();
    let zeta = pool.shard_examples(0);

    let mut theta = cfg
        .init_theta
        .clone()
        .unwrap_or_else(|| vec![0.0f32; dim]);
    if theta.len() != dim {
        return Err(Error::Shape(format!(
            "init_theta has {} elements, problem dim is {dim}",
            theta.len()
        )));
    }

    let mut gamma = cfg.mode.initial_gamma(n_total, zeta, m)?;
    let mut adaptive = match cfg.mode {
        SyncMode::HybridAdaptive { alpha, xi, window } => Some((
            AdaptiveEstimator::new(n_total, zeta, m, EstimatorParams { alpha, xi }),
            window,
        )),
        _ => None,
    };

    // Engine state: heap, membership, elastic runtime, failure states, and
    // the historical sync RNG stream family (bit-compatible with the
    // pre-refactor driver).
    let mut core = EngineCore::new(&profiles, cluster.seed, 0x51D, 1000);
    // Capacity model: per-worker hardware weights, the scheduled-rejoin
    // warm-up ramp, and the apportionment toggle.  The defaults (uniform,
    // warmup 0, weighted) leave every legacy plan bit-for-bit intact.
    core.elastic.configure_capacity(
        cluster.capacity_vec(),
        cluster.warmup_iters,
        cluster.weighted_rebalance,
    );

    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut agg = vec![0.0f32; dim];
    let mut now = 0.0f64;
    let mut status = RunStatus::Completed;
    // All coordinator↔worker traffic goes through the transport; with an
    // ideal NetSpec it is a zero-perturbation passthrough.
    let mut net = VirtualTransport::new(cluster.net.clone(), cluster.seed);
    // Block admission: chunk each reply into `n_blocks` fixed-size blocks
    // whose fates realize independently.  `block_size = 0` (or a size ≥
    // dim) keeps a single block and the legacy binary delivery decision.
    let n_blocks = cluster.net.n_blocks(dim);
    net.set_block_count(n_blocks);
    // Cross-iteration reordering is a non-ideal-net phenomenon: with an
    // ideal spec every reply of iteration t pops inside window t and the
    // loop is the lockstep driver, arithmetic untouched.
    let carry = !net.is_ideal();
    // Partial folds and stale-block claims only matter when replies chunk
    // into several blocks *and* the network can actually lose some.
    let blocking = carry && n_blocks > 1;
    let mut stale_blocks_total = 0u64;
    // Hybrid-reuse ablation: abandoned results computed at θ_t arrive during
    // iteration t+1 and are folded in with staleness 1 (aggregator-weighted).
    let reuse_late = matches!(
        cfg.aggregator,
        crate::coordinator::AggregatorKind::StalenessDamped { .. }
    );
    // Aggregation overlay (star = the legacy identity, never planned):
    // per-iteration plan scratch and run-level interior-edge accounting.
    let topo = !cluster.agg.is_star();
    let topo_ring = cluster.agg.topology == crate::agg::TopologyKind::Ring;
    let mut topo_scratch = crate::agg::AggScratch::new();
    let mut topo_stats = crate::agg::AggStats::default();
    // Every per-iteration buffer lives in this arena and is reused across
    // iterations: zero steady-state allocations (tests/alloc_regression.rs).
    let mut scratch = IterScratch::new(m);
    // Recovery policy state: consulted at every crash/rejoin boundary.
    // Under the default `abandon` policy every hook is a no-op and the
    // loop below skips all recovery work (`recovering == false`), so the
    // zero-alloc steady state is untouched.  See `docs/RECOVERY.md`.
    let mut recovery = crate::recovery::RecoveryState::new(cfg.recovery, m);
    let recovering = !recovery.is_noop();

    'iters: for iter in 0..cfg.stop.max_iters {
        // Split the scratch into disjoint &mut locals so the loop body
        // reads like the original allocate-per-iteration code.
        let IterScratch {
            events,
            latency,
            responders,
            assignment,
            included_shards,
            included_workers,
            arrived_workers,
            delivered,
            missing,
            late,
            barrier,
            grads,
            carryover,
            carry_blocks,
            ledger,
            stale_admits,
            stale_arena,
            stale_meta,
            respawns,
            catchups,
            catchup_arena,
            catchup_meta,
        } = &mut scratch;
        if blocking {
            ledger.prune_before(iter.saturating_sub(BLOCK_LEDGER_HORIZON));
        }
        stale_admits.clear();
        // Recovery actions recorded in an IterRow are this iteration's
        // delta, mirroring the per-iteration network-stat deltas.
        let recov_iter_start = recovery.recoveries;
        let rollback_iter_start = recovery.rollback_iters;
        if recovering {
            // --- 0a. supervisor respawns & θ snapshot ------------------
            // Workers that crashed stochastically last sweep respawn at
            // this iteration's top (ascending worker order), before the
            // scheduled boundary events land.  Respawn is instant: no
            // `note_join` warm-up ramp — the replacement inherits the old
            // worker's shards untouched.
            recovery.take_respawns(respawns);
            for &w in respawns.iter() {
                core.fstates[w].force_rejoin();
                core.membership.mark_alive(w);
                if let Some(rollback) = recovery.on_join(w, iter) {
                    if sink.enabled() {
                        trace::emit_recovery(
                            sink,
                            iter,
                            w,
                            now,
                            recovery.policy().name(),
                            rollback,
                        );
                    }
                }
            }
            // Snapshot *before* boundary events and the failure sweep, so
            // a same-iteration crash restores to this iteration's top.
            recovery.maybe_snapshot(iter, &theta);
        }
        // --- 0. boundary events: elastic membership & shard rebalancing --
        // Scheduled leave/join events land exactly at this boundary, in
        // schedule order (a leave@k followed by join@k nets out alive).
        let rebalanced = core.boundary(
            iter,
            &cluster.elastic,
            cluster.rebalance_every,
            &mut recovery,
            &mut theta,
            sink,
            now,
        )?;
        if rebalanced {
            log::debug!("iter {iter}: shard ownership rebalanced");
        }
        if sink.enabled() {
            let owners = core.elastic.ownership.owners();
            trace::emit_boundary(sink, &cluster.elastic, iter, rebalanced, owners, now);
        }

        // --- 1. failure events & responder latencies -------------------
        for w in 0..m {
            latency[w] = f64::INFINITY;
            if core.evicted[w] {
                // Scheduled eviction: no failure-state step (so
                // `rejoin_after` cannot revive it early), no response.
                events[w] = FailureEvent::Down;
                continue;
            }
            let ev = core.fstates[w].step(iter, &mut core.fail_rngs[w]);
            core.membership.observe(w, ev);
            events[w] = ev;
            if matches!(ev, FailureEvent::Crashed) {
                if sink.enabled() {
                    sink.emit(iter, w as i64, now, TraceEvent::Crash);
                }
                if recovering {
                    if let Some(rollback) = recovery.on_crash(w, iter, &mut theta) {
                        if sink.enabled() {
                            trace::emit_recovery(
                                sink,
                                iter,
                                w,
                                now,
                                recovery.policy().name(),
                                rollback,
                            );
                        }
                    }
                }
            }
        }
        // Crash-during-rebalance repair: a crash observed this sweep (e.g.
        // an adopter dying in the same boundary it adopted shards) re-plans
        // ownership immediately inside the barrier, so the orphaned shards
        // contribute this very iteration.  No-op when rebalancing is off
        // or every owner is alive — and in particular on every ideal-net
        // trajectory the pre-refactor golden tests pin down.  The
        // `rebalance` recovery policy forces this gate open even when the
        // periodic cadence is disabled.
        let orphan_every = if recovery.policy().forces_rebalance() && cluster.rebalance_every == 0
        {
            1
        } else {
            cluster.rebalance_every
        };
        if core
            .elastic
            .replan_orphans(orphan_every, &core.membership)?
        {
            log::debug!("iter {iter}: mid-barrier re-plan after owner crash");
            if sink.enabled() {
                let cut = TraceEvent::RebalanceCut {
                    owners: core.elastic.ownership.owners().to_vec(),
                };
                sink.emit(iter, trace::MASTER, now, cut);
            }
        }

        // Snapshot the assignment once per iteration (O(shards)); it only
        // changes at boundaries, except for BSP-retry's mid-iteration
        // reassignment, which reads the live map directly below.
        core.elastic.ownership.grouped_into(assignment);

        for w in 0..m {
            if matches!(events[w], FailureEvent::Healthy | FailureEvent::Rejoined) {
                // A worker that currently owns no shards (capacity-weighted
                // apportionment can strip slow or still-warming nodes; a
                // stochastic `rejoin_after` revival can land one sweep
                // after its shards were adopted) is not dispatched to at
                // all — no roundtrip, no barrier slot — matching the
                // threaded master, which skips its `Work` broadcast.  On
                // every existing golden/parity trace (uniform weights, no
                // stochastic revival) no alive worker is ever shard-less,
                // so the legacy dispatch sequence is untouched.
                if assignment[w].is_empty() {
                    continue;
                }
                // Serial execution of owned shards, dilated by the warm-up
                // ramp while the worker is cold (scale 1.0 once warm — the
                // multiplication is bit-exact).
                let per_shard = profiles[w].sample_latency(&mut core.delay_rngs[w]);
                latency[w] =
                    per_shard * core.elastic.latency_scale(w) * assignment[w].len() as f64;
            }
        }
        responders.clear();
        responders.extend((0..m).filter(|&w| latency[w].is_finite()));
        if core.membership.alive() == 0 {
            status = RunStatus::ClusterDead { iter };
            break;
        }
        if responders.is_empty() {
            // Everyone transiently dropped: burn a detection window.
            let len = cluster.base_compute.max(1e-6);
            burn_window(&mut core, len);
            now += len;
            continue;
        }

        // --- 2. transport + engine + barrier ---------------------------
        // Every responder's roundtrip goes through the transport: the Work
        // broadcast down, `latency[w]` of compute, the Grad reply up.  The
        // NetSpec realizes drops / delays / duplicates per message; the
        // surviving deliveries become events on the engine heap, where
        // they merge (in time order) with stragglers carried over from
        // earlier windows.
        let stats_iter_start = net.stats();
        let stale_blocks_iter_start = stale_blocks_total;
        for &w in responders.iter() {
            if sink.enabled() {
                trace::emit_roundtrip_fates(
                    sink,
                    &cluster.net,
                    cluster.seed,
                    w,
                    iter,
                    n_blocks,
                    now,
                );
            }
            net.send_roundtrip(w, iter, latency[w]);
        }
        // Fresh primaries this window — captured before the drain (the
        // barrier can only close on this iteration's deliveries).
        let mut fresh = net.deliverable();
        if topo {
            // Non-star overlay: the drain routes through the aggregation
            // plan before anything reaches the heap.  Relays deduplicate —
            // a duplicated reply meets its primary's fold at the first
            // relay and dies there — and an interior-edge drop kills the
            // whole folded subtree (or clears ring segments).  Fates are
            // pure in (seed, iter) and the dispatched/delivered sets, so
            // the threaded driver realizes the identical plan
            // (docs/AGGREGATION.md).
            topo_scratch.arrivals.clear();
            while let Some(d) = net.poll() {
                if d.duplicate {
                    core.membership.record_abandoned(d.worker);
                    continue;
                }
                topo_scratch.arrivals.push((d.worker, d.at));
            }
            crate::agg::plan(
                &cluster.agg,
                net.spec(),
                net.seed(),
                iter,
                m,
                responders,
                &mut topo_scratch,
                &mut topo_stats,
                sink,
                now,
            );
            for &(w, _) in topo_scratch.arrivals.iter() {
                if topo_scratch.killed[w] {
                    core.membership.record_abandoned(w);
                    continue;
                }
                core.heap.push(Event {
                    at: topo_scratch.at[w],
                    worker: w,
                    iter,
                    duplicate: false,
                    delivers: true,
                });
            }
            fresh -= topo_scratch.killed_count;
        } else {
            while let Some(d) = net.poll() {
                core.heap.push(Event {
                    at: d.at,
                    worker: d.worker,
                    iter: d.iter,
                    duplicate: d.duplicate,
                    delivers: true,
                });
            }
        }
        included_shards.clear();
        included_workers.clear();
        // Workers whose primary reply reached the coordinator this window
        // (delivered, whether or not the barrier admitted it).
        arrived_workers.clear();
        let mut iter_abandoned = 0usize;
        let mut iter_stale = 0usize;
        let iter_latency: f64;
        match (&cfg.mode, gamma) {
            (SyncMode::Bsp, _) => {
                delivered.fill(false);
                let mut last_arrival = 0.0f64;
                while let Some(d) = core.heap.pop() {
                    if sink.enabled() {
                        let deliv = TraceEvent::Delivery { duplicate: d.duplicate };
                        sink.emit(d.iter, d.worker as i64, now + d.at, deliv);
                    }
                    if !d.duplicate {
                        delivered[d.worker] = true;
                        arrived_workers.push(d.worker);
                    }
                    last_arrival = last_arrival.max(d.at);
                }
                // A shard is missing if its owner is down *or* its reply
                // was lost in the network — BSP cannot tell the two apart.
                missing.clear();
                for s in 0..m {
                    let o = core.elastic.ownership.owner(s);
                    if !(matches!(events[o], FailureEvent::Healthy | FailureEvent::Rejoined)
                        && delivered[o])
                    {
                        missing.push(s);
                    }
                }
                if !missing.is_empty() {
                    match cfg.bsp_recovery {
                        BspRecovery::Stall => {
                            status = RunStatus::Stalled { iter };
                            break 'iters;
                        }
                        BspRecovery::Retry { detect_timeout } => {
                            // Reassign permanently-dead owners' shards.
                            for &s in missing.iter() {
                                let o = core.elastic.ownership.owner(s);
                                if core.fstates[o].is_down() {
                                    // least-loaded alive worker takes over
                                    let new_o = (0..m)
                                        .filter(|&w| !core.fstates[w].is_down())
                                        .min_by_key(|&w| core.elastic.ownership.load(w))
                                        .ok_or_else(|| {
                                            Error::Cluster(
                                                "no alive worker for reassignment".into(),
                                            )
                                        })?;
                                    core.elastic.ownership.reassign(s, new_o);
                                }
                            }
                            // Every shard contributes; stragglers pay
                            // detect+retry.  Under an ideal net the retry
                            // path cannot lose messages, so exactly one
                            // retransmission at `detect_timeout + retry_lat`
                            // suffices — the historical cost, bit for bit.
                            // Under a lossy net each attempt re-traverses
                            // the owner's link (fate drawn from its own
                            // salted stream, so repeated loss is possible),
                            // with the detection timeout backing off
                            // exponentially up to a cap until the master
                            // gives up on the network and fetches the
                            // result over a reliable channel.
                            let mut retry_max = 0.0f64;
                            for &s in missing.iter() {
                                let o = core.elastic.ownership.owner(s);
                                let retry_lat = if latency[o].is_finite() {
                                    latency[o]
                                } else {
                                    profiles[o].base_compute
                                        * core.elastic.ownership.load(o) as f64
                                };
                                let cost = if carry {
                                    let mut cost = 0.0f64;
                                    let mut attempt = 0u64;
                                    loop {
                                        let backoff = detect_timeout
                                            * (1u64 << attempt.min(BSP_RETRY_BACKOFF_CAP))
                                                as f64;
                                        cost += backoff + retry_lat;
                                        if attempt >= BSP_RETRY_MAX_ATTEMPTS {
                                            break; // reliable-channel fetch
                                        }
                                        let r = net.realize_retry(o, iter, attempt);
                                        if sink.enabled() {
                                            let delivered = r.delivers();
                                            let ra = TraceEvent::RetryAttempt {
                                                attempt,
                                                backoff,
                                                delivered,
                                            };
                                            sink.emit(iter, o as i64, now, ra);
                                        }
                                        if r.delivers() {
                                            cost += r.roundtrip_delay();
                                            break;
                                        }
                                        attempt += 1;
                                    }
                                    cost
                                } else {
                                    detect_timeout + retry_lat
                                };
                                retry_max = retry_max.max(cost);
                            }
                            included_shards
                                .extend((0..m).map(|s| (s, BlockSet::full(1))));
                            iter_latency = last_arrival.max(retry_max);
                        }
                    }
                } else {
                    included_shards.extend((0..m).map(|s| (s, BlockSet::full(1))));
                    iter_latency = last_arrival;
                }
            }
            (_, Some(g)) => {
                // Hybrid family: the first γ_eff *delivered* replies close
                // the barrier; everything later — and every duplicate — is
                // abandoned, exactly what a physical barrier would see.
                if fresh == 0 {
                    // Every reply dropped or partitioned away: burn a
                    // detection window, like the all-transient-drop case.
                    let len = cluster.base_compute.max(1e-6);
                    burn_window(&mut core, len);
                    now += len;
                    continue;
                }
                // Ring is a collective: every surviving participant is part
                // of the one reduced vector and they all land together, so
                // the barrier admits them all — γ shapes nothing inside a
                // ring window (docs/AGGREGATION.md).
                let g_eff = if topo_ring { fresh } else { g.min(fresh) };
                barrier.reset(iter, g_eff);
                let mut close_time = 0.0f64;
                loop {
                    // Before the barrier closes, every pending event pops
                    // (time order guarantees it lands inside this window);
                    // after it closes, only events before the window's end
                    // — the next broadcast at close + master_overhead —
                    // still belong to it.  Later stragglers stay on the
                    // heap and go stale in a subsequent window.  Under an
                    // ideal spec everything drains, lockstep-style.
                    let ev = if carry && barrier.is_closed() {
                        core.heap
                            .pop_before(close_time + cluster.master_overhead)
                    } else {
                        core.heap.pop()
                    };
                    let Some(ev) = ev else { break };
                    if sink.enabled() {
                        let deliv = TraceEvent::Delivery { duplicate: ev.duplicate };
                        sink.emit(ev.iter, ev.worker as i64, now + ev.at, deliv);
                    }
                    if !ev.duplicate && ev.iter == iter {
                        arrived_workers.push(ev.worker);
                    }
                    match barrier.offer(ev.worker, ev.iter) {
                        Admission::Included | Admission::IncludedAndClosed => {
                            close_time = ev.at;
                            included_workers.push(ev.worker);
                            // Under block admission the reply carries only
                            // its delivered set; fold exactly those blocks
                            // and claim them so a straggling duplicate can
                            // never re-fold one.
                            let mask = if blocking {
                                let mk = net.blocks_for(ev.worker, ev.iter, ev.duplicate);
                                ledger.claim(ev.worker, ev.iter, mk)
                            } else if topo_ring {
                                // The segments of this participant that
                                // survived the collective (full(n_p) under
                                // ideal links — the whole-vector fold).
                                topo_scratch.masks[ev.worker]
                            } else {
                                BlockSet::full(1)
                            };
                            included_shards
                                .extend(assignment[ev.worker].iter().map(|&s| (s, mask)));
                            core.membership.record_contribution(ev.worker);
                        }
                        Admission::Abandoned => {
                            core.membership.record_abandoned(ev.worker);
                            iter_abandoned += 1;
                        }
                        Admission::Stale => {
                            core.membership.record_abandoned(ev.worker);
                            iter_stale += 1;
                            // Late blocks from an earlier window: instead
                            // of discarding the whole reply, admit the
                            // blocks that survived *and were not already
                            // folded* as a stale contribution (folded only
                            // under StalenessDamped; always accounted).
                            let mut claimed = 0usize;
                            if blocking {
                                let mk = net.blocks_for(ev.worker, ev.iter, ev.duplicate);
                                let fresh = ledger.claim(ev.worker, ev.iter, mk);
                                if !fresh.is_empty() {
                                    claimed = fresh.delivered() as usize;
                                    stale_blocks_total += fresh.delivered() as u64;
                                    if reuse_late {
                                        stale_admits.push((
                                            ev.worker,
                                            iter - ev.iter,
                                            fresh,
                                        ));
                                    }
                                }
                            }
                            if sink.enabled() {
                                let st = TraceEvent::StaleAdmission { claimed_blocks: claimed };
                                sink.emit(ev.iter, ev.worker as i64, now + ev.at, st);
                            }
                        }
                    }
                }
                if sink.enabled() {
                    let close = TraceEvent::BarrierClose {
                        gamma: g_eff,
                        included: included_workers.len(),
                        abandoned: iter_abandoned,
                    };
                    sink.emit(iter, trace::MASTER, now + close_time, close);
                }
                iter_latency = close_time;
                // Aggregate in shard-index order: f32 summation order is
                // then independent of arrival order (γ=M reproduces BSP
                // bit-for-bit; see prop_gamma_m_equals_bsp) and matches
                // the threaded runtime's order.
                included_shards.sort_unstable_by_key(|&(s, _)| s);
            }
            (mode, None) => {
                return Err(Error::Config(format!(
                    "mode {} has no gamma in sync driver",
                    mode.name()
                )))
            }
        }
        if matches!(cfg.mode, SyncMode::Bsp) {
            included_workers.clear();
            included_workers.extend_from_slice(responders);
            for &w in responders.iter() {
                core.membership.record_contribution(w);
            }
        }
        // Interior-node cost model: the root pays fold+xfer per message it
        // folds.  Under a star every included reply is its own root
        // message — the incast term hierarchical overlays exist to beat —
        // while tree/ring arrive pre-combined (`root_msgs` from the
        // plan).  Zero-cost specs (the default) skip the arithmetic
        // entirely, so the legacy closing path stays bit-for-bit.
        let iter_latency = if cluster.agg.root_cost() != 0.0 {
            let root_msgs = if topo {
                f64::from(topo_scratch.root_msgs)
            } else {
                included_workers.len() as f64
            };
            iter_latency + cluster.agg.root_cost() * root_msgs
        } else {
            iter_latency
        };
        // Close the window: whatever is still in flight re-enters the next
        // window's time frame (no-op under an ideal spec — the heap is
        // empty — so the lockstep arithmetic stays untouched).
        core.heap.rebase(iter_latency + cluster.master_overhead);

        if included_shards.is_empty() {
            // Defensive: shard-less workers are no longer dispatched, so
            // every admitted responder carries shards — but mirror the
            // threaded driver (worker/mod.rs) if it ever triggers: no
            // update, no convergence observation — just advance the clock.
            carryover.clear();
            carry_blocks.clear();
            now += iter_latency + cluster.master_overhead;
            continue;
        }

        // --- 3. compute included gradients ------------------------------
        // Gradients land in reusable arena slots (`grad_into`): the fused
        // kernel writes into last iteration's buffers, so the steady state
        // allocates nothing.
        grads.clear();
        for &(s, _) in included_shards.iter() {
            pool.grad_into(s, &theta, iter, grads.next())?;
        }
        // Stale-admitted blocks (reuse ablation only): recompute the late
        // worker's shards at the *current* θ — the same approximation the
        // carryover path makes — and fold just the freshly-claimed blocks,
        // damped by their true staleness.  Appended after the legacy chain
        // so the fresh+carryover f32 fold order is untouched.
        stale_arena.clear();
        stale_meta.clear();
        for &(w, stal, mask) in stale_admits.iter() {
            for &s in &assignment[w] {
                pool.grad_into(s, &theta, iter, stale_arena.next())?;
                stale_meta.push((stal, mask));
            }
        }
        // Partial recovery: a respawned (or rejoined) worker's lost
        // contribution is reconstructed by a fresh warm compute over its
        // *current* partition at the *current* θ, folded through the
        // staleness-damped path with staleness = its downtime.  Appended
        // after the stale chain so every legacy f32 fold order survives.
        catchup_arena.clear();
        catchup_meta.clear();
        if recovering {
            recovery.take_catchups(catchups);
            for c in catchups.iter() {
                for &s in &assignment[c.worker] {
                    pool.grad_into(s, &theta, iter, catchup_arena.next())?;
                    catchup_meta.push(c.staleness);
                }
            }
        }
        aggregate_iter(
            cfg.aggregator,
            grads
                .results()
                .iter()
                .zip(included_shards.iter())
                .map(|(g, &(_, mask))| Contribution {
                    grad: &g.grad,
                    examples: g.examples,
                    staleness: 0,
                    blocks: mask,
                })
                .chain(
                    carryover
                        .results()
                        .iter()
                        .zip(carry_blocks.iter())
                        .map(|(g, &mask)| Contribution {
                            grad: &g.grad,
                            examples: g.examples,
                            staleness: 1,
                            blocks: mask,
                        }),
                )
                .chain(
                    stale_arena
                        .results()
                        .iter()
                        .zip(stale_meta.iter())
                        .map(|(g, &(stal, mask))| Contribution {
                            grad: &g.grad,
                            examples: g.examples,
                            staleness: stal,
                            blocks: mask,
                        }),
                )
                .chain(
                    catchup_arena
                        .results()
                        .iter()
                        .zip(catchup_meta.iter())
                        .map(|(g, &stal)| Contribution {
                            grad: &g.grad,
                            examples: g.examples,
                            staleness: stal,
                            blocks: BlockSet::full(1),
                        }),
                ),
            &mut agg,
        );
        let grad_norm = vec_ops::norm2(&agg);

        // Adaptive γ: observe scatter, re-estimate per window.
        if let Some((est, window)) = adaptive.as_mut() {
            est.observe_results(grads.results());
            if *window > 0 && (iter + 1) % *window == 0 {
                let g_new = est.gamma()?;
                if Some(g_new) != gamma {
                    log::debug!("adaptive gamma: {:?} -> {}", gamma, g_new);
                    gamma = Some(g_new);
                }
                est.reset_window();
            }
        }

        // Training-loss estimate at θ_t from the included shards.
        let loss_sum: f64 = grads.results().iter().filter_map(|g| g.loss_sum).sum();
        let loss_examples: usize = grads
            .results()
            .iter()
            .filter(|g| g.loss_sum.is_some())
            .map(|g| g.examples)
            .sum();
        let loss = cfg.loss_form.assemble(loss_sum, loss_examples, &theta);

        // --- 4. update & clock -----------------------------------------
        // Reuse ablation: abandoned responders' θ_t gradients become next
        // iteration's staleness-1 carryover.  Only replies that actually
        // *arrived* within this window qualify — a network-dropped result
        // never reached the coordinator, and a straggler still in flight
        // will be classified stale when it lands.
        carryover.clear();
        carry_blocks.clear();
        if reuse_late {
            // Ascending worker order (not arrival order) keeps the f32
            // fold order identical to the pre-transport driver.
            late.clear();
            late.extend(
                arrived_workers
                    .iter()
                    .copied()
                    .filter(|w| !included_workers.contains(w)),
            );
            late.sort_unstable();
            for &w in late.iter() {
                // Under block admission the late reply only carried its
                // delivered set; claim those blocks now (the reuse *is* the
                // fold) so a duplicate straggling into a later window can
                // only stale-admit blocks this carryover did not cover.
                let mask = if blocking {
                    let mk = net.blocks_for(w, iter, false);
                    ledger.claim(w, iter, mk)
                } else {
                    BlockSet::full(1)
                };
                if blocking && mask.is_empty() {
                    continue;
                }
                for &s in &assignment[w] {
                    pool.grad_into(s, &theta, iter, carryover.next())?;
                    carry_blocks.push(mask);
                }
            }
        }
        opt.step(&mut theta, &agg, iter);
        now += iter_latency + cluster.master_overhead;
        if let Some(sv) = serving.as_mut() {
            sv.on_barrier_close(iter, &theta, sink, now);
        }

        // --- 5. record / evaluate / stop --------------------------------
        let do_eval = cfg.eval_every > 0 && iter % cfg.eval_every == 0;
        let stop = tracker.observe(iter, loss, grad_norm);
        let record = cfg.record_every > 0 && iter % cfg.record_every == 0;
        if record || do_eval || stop.is_some() {
            let (eval_loss, theta_err) = if do_eval || stop.is_some() {
                (hooks.hook_eval_loss(&theta), hooks.hook_theta_err(&theta))
            } else {
                (None, None)
            };
            let dnet = net.stats().since(&stats_iter_start);
            rec.push(IterRow {
                iter,
                time: now,
                loss,
                eval_loss,
                theta_err,
                included: included_shards.len(),
                abandoned: iter_abandoned,
                stale: iter_stale,
                dropped: dnet.dropped as usize,
                duplicated: dnet.duplicated as usize,
                blocks: dnet.blocks_delivered as usize,
                stale_blocks: (stale_blocks_total - stale_blocks_iter_start) as usize,
                alive: core.membership.alive(),
                gamma,
                grad_norm,
                recoveries: (recovery.recoveries - recov_iter_start) as usize,
                rollback_iters: recovery.rollback_iters - rollback_iter_start,
            });
        }
        if let Some(s) = stop {
            status = s;
            break;
        }
    }

    // Replies still in flight when the run ends are discarded uncounted —
    // the threaded master likewise drops queued replies at shutdown.
    core.heap.clear();

    Ok(report::assemble(
        rec,
        theta,
        status,
        gamma,
        cfg.mode.name(),
        &core,
        net.stats(),
        topo_stats,
        stale_blocks_total,
        None,
        recovery.recoveries,
        recovery.rollback_iters,
        driver_start,
        sink.summary(),
        serving.map(crate::serve::ServeEngine::finish),
    ))
}
