//! TOML-subset parser for experiment configs.
//!
//! Supported: `#` comments, `[table]` / `[a.b]` headers, `key = value` with
//! basic strings, integers, floats, booleans, and flat arrays.  This covers
//! every config under `configs/`; anything fancier (multiline strings,
//! datetimes, inline tables) is rejected with a line-numbered error.

use super::value::Value;
use crate::{Error, Result};

/// Parse TOML text into a [`Value::Table`].
pub fn parse(input: &str) -> Result<Value> {
    let mut root = Value::empty_table();
    let mut prefix = String::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated table header"))?
                .trim();
            if header.is_empty() || header.starts_with('[') {
                return Err(err(lineno, "bad table header (arrays-of-tables unsupported)"));
            }
            validate_key_path(header).map_err(|m| err(lineno, &m))?;
            prefix = header.to_string();
            // Materialize the table even if empty.
            root.set(&prefix, root.get(&prefix).cloned().unwrap_or_else(Value::empty_table))?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected 'key = value'"))?;
        let key = line[..eq].trim();
        validate_key_path(key).map_err(|m| err(lineno, &m))?;
        let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(lineno, &m))?;
        let path = if prefix.is_empty() {
            key.to_string()
        } else {
            format!("{prefix}.{key}")
        };
        if root.get(&path).is_some() {
            return Err(err(lineno, &format!("duplicate key '{path}'")));
        }
        root.set(&path, value)?;
    }
    Ok(root)
}

/// Load and parse a TOML file.
pub fn load(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)?;
    parse(&text).map_err(|e| Error::Config(format!("{}: {e}", path.display())))
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("toml line {}: {msg}", lineno + 1))
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn validate_key_path(path: &str) -> std::result::Result<(), String> {
    for part in path.split('.') {
        if part.is_empty()
            || !part
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(format!("invalid key '{path}'"));
        }
    }
    Ok(())
}

fn parse_value(text: &str) -> std::result::Result<Value, String> {
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quotes unsupported".into());
        }
        // Basic escapes only.
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('\\') => out.push('\\'),
                    Some(other) => return Err(format!("bad escape '\\{other}'")),
                    None => return Err("dangling backslash".into()),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if text == "true" {
        return Ok(Value::Bool(true));
    }
    if text == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for piece in split_array_items(inner)? {
            items.push(parse_value(piece.trim())?);
        }
        return Ok(Value::Array(items));
    }
    // numbers (underscore separators allowed)
    let cleaned: String = text.chars().filter(|&c| c != '_').collect();
    if cleaned.contains(['.', 'e', 'E']) || cleaned == "inf" || cleaned == "-inf" {
        return cleaned
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| format!("bad value '{text}'"));
    }
    cleaned
        .parse::<i64>()
        .map(Value::Int)
        .map_err(|_| format!("bad value '{text}'"))
}

/// Split a flat array body on commas, respecting quoted strings.
fn split_array_items(inner: &str) -> std::result::Result<Vec<&str>, String> {
    let mut items = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in inner.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                items.push(&inner[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    if depth != 0 {
        return Err("nested arrays unsupported".into());
    }
    items.push(&inner[start..]);
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_config() {
        let doc = r#"
# experiment config
title = "demo"

[problem]
kind = "krr"
machines = 16
lambda = 0.01
seed = 42

[mode]
kind = "hybrid"
gamma = 12

[straggler]
delay = "lognormal"
sigma = 1.5
factors = [1.0, 2.0, 4.0]
enabled = true
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.req_str("title").unwrap(), "demo");
        assert_eq!(v.req_usize("problem.machines").unwrap(), 16);
        assert_eq!(v.req_f64("problem.lambda").unwrap(), 0.01);
        assert_eq!(v.req_str("mode.kind").unwrap(), "hybrid");
        assert!(v.opt_bool("straggler.enabled", false));
        let arr = v.get("straggler.factors").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_f64(), Some(4.0));
    }

    #[test]
    fn nested_table_headers() {
        let v = parse("[a.b]\nc = 1\n[a.d]\ne = 2").unwrap();
        assert_eq!(v.req_usize("a.b.c").unwrap(), 1);
        assert_eq!(v.req_usize("a.d.e").unwrap(), 2);
    }

    #[test]
    fn comments_and_hash_in_string() {
        let v = parse("x = \"a#b\" # trailing\ny = 2 # another").unwrap();
        assert_eq!(v.req_str("x").unwrap(), "a#b");
        assert_eq!(v.req_usize("y").unwrap(), 2);
    }

    #[test]
    fn numbers_with_underscores_and_floats() {
        let v = parse("big = 1_000_000\nsci = 1.5e-3\nneg = -7").unwrap();
        assert_eq!(v.req_usize("big").unwrap(), 1_000_000);
        assert!((v.req_f64("sci").unwrap() - 0.0015).abs() < 1e-12);
        assert_eq!(v.get("neg").unwrap().as_i64(), Some(-7));
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue =").is_err());
        assert!(parse("bad key = 1").is_err());
        assert!(parse("x = \"unterminated").is_err());
    }

    #[test]
    fn error_mentions_line() {
        let e = parse("good = 1\nbad =").unwrap_err();
        assert!(format!("{e}").contains("line 2"));
    }

    #[test]
    fn string_escapes() {
        let v = parse(r#"s = "a\nb\tc""#).unwrap();
        assert_eq!(v.req_str("s").unwrap(), "a\nb\tc");
    }
}
