//! Adam (bias-corrected first/second moments).

use super::Optimizer;

#[derive(Clone, Debug)]
pub struct Adam {
    eta: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(eta: f64, beta1: f64, beta2: f64, eps: f64) -> Adam {
        Adam {
            eta,
            beta1,
            beta2,
            eps,
            m: Vec::new(),
            v: Vec::new(),
            t: 0,
        }
    }

    pub fn default_params(eta: f64) -> Adam {
        Adam::new(eta, 0.9, 0.999, 1e-8)
    }
}

impl Optimizer for Adam {
    fn step(&mut self, theta: &mut [f32], grad: &[f32], _iter: u64) {
        if self.m.len() != theta.len() {
            self.m = vec![0.0; theta.len()];
            self.v = vec![0.0; theta.len()];
            self.t = 0;
        }
        self.t += 1;
        let b1 = self.beta1 as f32;
        let b2 = self.beta2 as f32;
        let bc1 = 1.0 - (self.beta1).powi(self.t as i32);
        let bc2 = 1.0 - (self.beta2).powi(self.t as i32);
        let eta = self.eta as f32;
        let eps = self.eps as f32;
        for i in 0..theta.len() {
            let g = grad[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mhat = self.m[i] / bc1 as f32;
            let vhat = self.v[i] / bc2 as f32;
            theta[i] -= eta * mhat / (vhat.sqrt() + eps);
        }
    }

    fn name(&self) -> &'static str {
        "adam"
    }

    fn reset(&mut self) {
        self.m.clear();
        self.v.clear();
        self.t = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_eta_sized() {
        // With bias correction the first step is ≈ η·sign(g).
        let mut a = Adam::default_params(0.1);
        let mut theta = vec![0.0f32];
        a.step(&mut theta, &[3.0], 0);
        assert!((theta[0] + 0.1).abs() < 1e-4, "{}", theta[0]);
    }

    #[test]
    fn converges_on_quadratic() {
        let mut a = Adam::default_params(0.2);
        let err = crate::optim::test_util::run_quadratic(&mut a, 400);
        assert!(err < 1e-2, "err={err}");
    }

    #[test]
    fn state_resizes_with_dim_change() {
        let mut a = Adam::default_params(0.1);
        let mut t1 = vec![0.0f32; 2];
        a.step(&mut t1, &[1.0, 1.0], 0);
        let mut t2 = vec![0.0f32; 3];
        a.step(&mut t2, &[1.0, 1.0, 1.0], 1); // must not panic
        assert_eq!(t2.len(), 3);
    }
}
