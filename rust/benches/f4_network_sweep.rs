//! F4 — iteration efficiency vs network unreliability (drop rate × γ,
//! plus a stale-admission sweep over slow uplinks).
//!
//! The paper's hybrid barrier tolerates *compute-side* stragglers; this
//! sweep asks how it behaves when the network itself loses messages
//! (arXiv:1810.07766's regime).  For each (drop probability, γ) cell we
//! train to a fixed convergence target — 90% of the initial→optimal loss
//! gap closed — and report iterations- and virtual-time-to-target.
//!
//! **Stale sweep**: the event engine lets a reply out-live its iteration
//! window in virtual time, so F4 now also sweeps per-direction *uplink*
//! latency on the slowest quarter of the cluster: their replies straggle
//! past the barrier and classify as `Admission::Stale`, and the stale
//! columns quantify how much useful work the asymmetric uplinks burn.
//!
//! The cells run concurrently on the sweep engine (`--threads N`
//! overrides the pool size); every cell shares the cached problem, so
//! generation's Cholesky solve happens once.
//!
//! Expected reading: drops act like extra abandonment, so
//! iterations-to-target inflate with the drop rate, and a mid-sized γ
//! (which already plans for missing replies) degrades more gracefully
//! than γ = M (where every lost reply shrinks the barrier below full
//! membership).  Slow uplinks behave like permanent stragglers: their
//! stale replies never contribute, so the effective cluster shrinks by
//! the lagged quarter.  The γ=12 drop-sweep headline and the stale sweep
//! land in `results/BENCH_f4_network.json`.

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, RunReport, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::net::{LinkDir, LinkModel, NetSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;
use hybriditer::trace;

const M: usize = 16;
const ITERS: u64 = 600;
const SEEDS: u64 = 2;
const GAP_FRACTION: f64 = 0.1; // target: 90% of the loss gap closed
/// Workers behind a slow uplink in the stale sweep (the slowest quarter).
const SLOW_UP_WORKERS: usize = M / 4;

fn run_once(
    problem: &KrrProblem,
    gamma: usize,
    drop: f64,
    up_lat: f64,
    block_size: usize,
    seed: u64,
) -> RunReport {
    run_once_traced(problem, gamma, drop, up_lat, block_size, seed, &mut trace::NoopSink)
}

#[allow(clippy::too_many_arguments)]
fn run_once_traced(
    problem: &KrrProblem,
    gamma: usize,
    drop: f64,
    up_lat: f64,
    block_size: usize,
    seed: u64,
    sink: &mut dyn trace::TraceSink,
) -> RunReport {
    let mut net = if drop > 0.0 { NetSpec::lossy(drop) } else { NetSpec::ideal() };
    net.block_size = block_size;
    if up_lat > 0.0 {
        // Per-direction asymmetry: the tail quarter's Grad replies crawl
        // while their Work broadcasts stay instant.
        for w in (M - SLOW_UP_WORKERS)..M {
            net = net.with_override(
                w,
                LinkModel {
                    drop_prob: drop,
                    up: Some(LinkDir {
                        latency: DelayModel::Constant { secs: up_lat },
                        drop_prob: drop,
                    }),
                    ..LinkModel::ideal()
                },
            );
        }
    }
    let cluster = ClusterSpec {
        workers: M,
        base_compute: 0.01,
        delay: DelayModel::LogNormal { mu: -4.0, sigma: 0.5 },
        seed: 70 + seed,
        ..ClusterSpec::default()
    }
    .with_net(net);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma },
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(problem.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(ITERS);
    let mut pool = problem.native_pool();
    sim::run_virtual_traced(&mut pool, &cluster, &cfg, &NoEval, sink).unwrap()
}

struct Cell {
    drop: f64,
    gamma: usize,
    up_lat: f64,
    /// Mean iterations to target (unreached seeds count as `ITERS`).
    iters: f64,
    time: f64,
    reached: u64,
    final_loss: f64,
    dropped: u64,
    duplicated: u64,
    stale: u64,
    abandon_pct: f64,
}

fn sweep_cells(engine: &SweepEngine, points: &[(f64, usize, f64)], target: f64) -> Vec<Cell> {
    let spec = KrrProblemSpec::small().with_machines(M);
    engine.run(points, move |cache, &(drop, gamma, up_lat)| {
        let problem = cache.get(&spec);
        let mut iters_sum = 0.0;
        let mut time_sum = 0.0;
        let mut reached = 0u64;
        let mut final_loss = 0.0;
        let mut dropped = 0u64;
        let mut duplicated = 0u64;
        let mut stale = 0u64;
        let mut abandon = 0.0;
        for seed in 0..SEEDS {
            let rep = run_once(&problem, gamma, drop, up_lat, 0, seed);
            match rep.recorder.iters_to_loss(target) {
                Some(it) => {
                    iters_sum += it as f64;
                    time_sum += rep.recorder.time_to_loss(target).unwrap_or(0.0);
                    reached += 1;
                }
                None => {
                    iters_sum += ITERS as f64;
                    time_sum += rep.total_time();
                }
            }
            final_loss += rep.final_loss();
            dropped += rep.net.dropped;
            duplicated += rep.net.duplicated;
            stale += rep.recorder.rows().iter().map(|r| r.stale as u64).sum::<u64>();
            abandon += rep.abandon_rate();
        }
        let n = SEEDS as f64;
        Cell {
            drop,
            gamma,
            up_lat,
            iters: iters_sum / n,
            time: time_sum / n,
            reached,
            final_loss: final_loss / n,
            dropped,
            duplicated,
            stale,
            abandon_pct: abandon / n * 100.0,
        }
    })
}

fn main() {
    let engine = SweepEngine::from_env();
    println!(
        "F4: drop rate × gamma network sweep — M={M}, {ITERS} iters cap, {SEEDS} seeds, \
         target = {:.0}% of loss gap closed",
        (1.0 - GAP_FRACTION) * 100.0
    );
    println!("sweep pool: {} threads\n", engine.threads());
    let spec = KrrProblemSpec::small().with_machines(M);
    let problem = engine.cache().get(&spec);

    // The clean γ=M reference defines the absolute loss target.
    let reference = run_once(&problem, M, 0.0, 0.0, 0, 0);
    let start_loss = reference
        .recorder
        .rows()
        .first()
        .map(|r| r.loss)
        .expect("reference run recorded no rows");
    let target = problem.loss_star + (start_loss - problem.loss_star) * GAP_FRACTION;
    println!(
        "loss: start {start_loss:.6}, optimum {:.6}, target {target:.6}\n",
        problem.loss_star
    );

    let mut table = Table::new(
        "F4 iterations-to-target vs drop rate",
        &[
            "drop_prob",
            "gamma",
            "up_lat_s",
            "iters_to_target",
            "time_to_target_s",
            "reached",
            "final_loss",
            "net_dropped",
            "net_dup",
            "stale",
            "abandon_pct",
        ],
    );
    let mut points: Vec<(f64, usize, f64)> = Vec::new();
    for &drop in &[0.0, 0.05, 0.1, 0.2, 0.3] {
        for &gamma in &[M / 2, M * 3 / 4, M] {
            points.push((drop, gamma, 0.0));
        }
    }
    // Stale-admission sweep: γ = 3M/4 at a mild drop rate, uplink latency
    // rising until the tail quarter's replies always miss the barrier.
    // (The up_lat = 0 baseline for this γ already sits in the main grid,
    // so the sweep starts at the first nonzero latency.)
    let g_stale = M * 3 / 4;
    let stale_points: Vec<(f64, usize, f64)> = [0.01, 0.02, 0.04]
        .iter()
        .map(|&up| (0.05, g_stale, up))
        .collect();
    let cells = sweep_cells(&engine, &points, target);
    let stale_cells = sweep_cells(&engine, &stale_points, target);

    // Block-admission sweep: block granularity × drop rate at γ = 3M/4.
    // `block_size = 0` is the whole-reply baseline; smaller blocks mean a
    // lossy reply still lands most of its coordinates, so time-to-target
    // should improve monotonically with granularity at a fixed drop rate.
    struct BlockCell {
        drop: f64,
        block_size: usize,
        n_blocks: usize,
        iters: f64,
        time: f64,
        reached: u64,
        blocks_delivered: u64,
        blocks_dropped: u64,
        stale_blocks: u64,
    }
    let g_blk = M * 3 / 4;
    let dim = problem.dim();
    let mut block_points: Vec<(f64, usize)> = Vec::new();
    for &drop in &[0.1, 0.2, 0.3] {
        for &bs in &[0usize, 16, 8, 4, 2] {
            block_points.push((drop, bs));
        }
    }
    let block_spec = KrrProblemSpec::small().with_machines(M);
    let block_cells: Vec<BlockCell> = engine.run(&block_points, |cache, &(drop, bs)| {
        let problem = cache.get(&block_spec);
        let mut iters_sum = 0.0;
        let mut time_sum = 0.0;
        let mut reached = 0u64;
        let mut blocks_delivered = 0u64;
        let mut blocks_dropped = 0u64;
        let mut stale_blocks = 0u64;
        for seed in 0..SEEDS {
            let rep = run_once(&problem, g_blk, drop, 0.0, bs, seed);
            match rep.recorder.iters_to_loss(target) {
                Some(it) => {
                    iters_sum += it as f64;
                    time_sum += rep.recorder.time_to_loss(target).unwrap_or(0.0);
                    reached += 1;
                }
                None => {
                    iters_sum += ITERS as f64;
                    time_sum += rep.total_time();
                }
            }
            blocks_delivered += rep.net.blocks_delivered;
            blocks_dropped += rep.net.blocks_dropped;
            stale_blocks += rep.stale_blocks;
        }
        let n = SEEDS as f64;
        BlockCell {
            drop,
            block_size: bs,
            n_blocks: NetSpec { block_size: bs, ..NetSpec::ideal() }.n_blocks(dim),
            iters: iters_sum / n,
            time: time_sum / n,
            reached,
            blocks_delivered,
            blocks_dropped,
            stale_blocks,
        }
    });
    let mut block_table = Table::new(
        "F4 block admission: time-to-target vs block granularity",
        &[
            "drop_prob",
            "block_size",
            "n_blocks",
            "iters_to_target",
            "time_to_target_s",
            "reached",
            "blocks_delivered",
            "blocks_dropped",
            "stale_blocks",
        ],
    );
    for c in &block_cells {
        block_table.row(vec![
            f(c.drop, 2),
            c.block_size.to_string(),
            c.n_blocks.to_string(),
            f(c.iters, 1),
            f(c.time, 3),
            format!("{}/{}", c.reached, SEEDS),
            c.blocks_delivered.to_string(),
            c.blocks_dropped.to_string(),
            c.stale_blocks.to_string(),
        ]);
    }
    for cell in cells.iter().chain(stale_cells.iter()) {
        table.row(vec![
            f(cell.drop, 2),
            cell.gamma.to_string(),
            f(cell.up_lat, 3),
            f(cell.iters, 1),
            f(cell.time, 3),
            format!("{}/{}", cell.reached, SEEDS),
            format!("{:.6}", cell.final_loss),
            cell.dropped.to_string(),
            cell.duplicated.to_string(),
            cell.stale.to_string(),
            f(cell.abandon_pct, 1),
        ]);
    }
    table.print();
    table.save_csv("f4_network_sweep").unwrap();
    block_table.print();
    block_table.save_csv("f4_block_sweep").unwrap();

    // Headline trajectory point: how much a 10% drop rate inflates
    // iterations-to-target at γ = 3M/4, and how many admissions go stale
    // once the tail quarter sits behind a 40 ms uplink.
    let g_ref = M * 3 / 4;
    let clean = cells
        .iter()
        .find(|c| c.drop == 0.0 && c.gamma == g_ref)
        .expect("clean cell");
    let lossy = cells
        .iter()
        .find(|c| c.drop == 0.1 && c.gamma == g_ref)
        .expect("lossy cell");
    let stale_head = stale_cells.last().expect("stale sweep cell");
    let inflation = if clean.iters > 0.0 { lossy.iters / clean.iters } else { f64::NAN };
    let cell_json = |c: &Cell| {
        format!(
            "    {{\"drop_prob\": {}, \"gamma\": {}, \"up_lat_s\": {}, \
             \"iters_to_target\": {:.1}, \"time_to_target_s\": {:.4}, \"reached\": {}, \
             \"final_loss\": {:.6}, \"stale\": {}, \"dropped\": {}}}",
            c.drop, c.gamma, c.up_lat, c.iters, c.time, c.reached, c.final_loss, c.stale,
            c.dropped
        )
    };
    // Block-sweep headline: whole-reply vs finest-grain admission at the
    // 20% drop rate.
    let blk_whole = block_cells
        .iter()
        .find(|c| c.drop == 0.2 && c.block_size == 0)
        .expect("whole-reply block cell");
    let blk_fine = block_cells
        .iter()
        .find(|c| c.drop == 0.2 && c.block_size == 2)
        .expect("finest block cell");
    let block_speedup =
        if blk_fine.time > 0.0 { blk_whole.time / blk_fine.time } else { f64::NAN };
    let block_json: Vec<String> = block_cells
        .iter()
        .map(|c| {
            format!(
                "    {{\"drop_prob\": {}, \"block_size\": {}, \"n_blocks\": {}, \
                 \"iters_to_target\": {:.1}, \"time_to_target_s\": {:.4}, \"reached\": {}, \
                 \"blocks_delivered\": {}, \"blocks_dropped\": {}, \"stale_blocks\": {}}}",
                c.drop,
                c.block_size,
                c.n_blocks,
                c.iters,
                c.time,
                c.reached,
                c.blocks_delivered,
                c.blocks_dropped,
                c.stale_blocks
            )
        })
        .collect();
    let points_json: Vec<String> = cells.iter().map(&cell_json).collect();
    let stale_json: Vec<String> = stale_cells.iter().map(&cell_json).collect();
    let json = format!(
        "{{\n  \"bench\": \"f4_network\",\n  \"machines\": {M},\n  \"iters_cap\": {ITERS},\n  \
         \"seeds\": {SEEDS},\n  \"target_loss\": {target:.6},\n  \"headline\": {{\n    \
         \"gamma\": {g_ref},\n    \"clean_iters_to_target\": {:.1},\n    \
         \"drop10_iters_to_target\": {:.1},\n    \"iteration_inflation\": {inflation:.3},\n    \
         \"slow_uplink_stale\": {},\n    \"slow_uplink_s\": {},\n    \
         \"block_whole_time_s\": {:.4},\n    \"block_fine_time_s\": {:.4},\n    \
         \"block_speedup\": {block_speedup:.3}\n  }},\n  \"points\": [\n{}\n  ],\n  \
         \"stale_sweep\": [\n{}\n  ],\n  \"block_sweep\": [\n{}\n  ]\n}}\n",
        clean.iters,
        lossy.iters,
        stale_head.stale,
        stale_head.up_lat,
        blk_whole.time,
        blk_fine.time,
        points_json.join(",\n"),
        stale_json.join(",\n"),
        block_json.join(",\n")
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/BENCH_f4_network.json", json).unwrap();

    // Flight-recorder capture of the headline lossy cell (γ = 3M/4 at 10%
    // drop, seed 0): one extra run with the journal attached, exported as
    // JSONL + a Chrome trace for Perfetto (see docs/OBSERVABILITY.md).
    let mut journal = trace::JournalSink::new();
    let traced = run_once_traced(&problem, g_ref, 0.1, 0.0, 0, 0, &mut journal);
    journal
        .write_jsonl(std::path::Path::new("results/f4_headline_trace.jsonl"))
        .unwrap();
    journal
        .write_chrome(std::path::Path::new("results/f4_headline_trace.chrome.json"))
        .unwrap();
    if let Some(ts) = &traced.trace {
        println!(
            "\ntraced headline cell: {} events journaled -> \
             results/f4_headline_trace.jsonl (+ .chrome.json)",
            ts.events
        );
    }
    println!(
        "\nheadline: gamma={g_ref} iters-to-target {:.1} -> {:.1} at 10% drop (x{inflation:.2}); \
         {} stale admissions at a {}s tail uplink; block admission x{block_speedup:.2} \
         time-to-target at 20% drop ({}-wide blocks vs whole replies)",
        clean.iters, lossy.iters, stale_head.stale, stale_head.up_lat, blk_fine.block_size
    );
    println!("trajectory point -> results/BENCH_f4_network.json");

    println!(
        "\nReading: message loss inflates iterations-to-target roughly like\n\
         extra abandonment — γ below M absorbs moderate loss (the barrier\n\
         already plans for missing replies), while γ = M feels every drop.\n\
         Duplicates are absorbed by the barrier's admission dedup at no\n\
         accuracy cost.  Slow uplinks turn the tail quarter into permanent\n\
         stragglers: their replies arrive iterations late, classify Stale,\n\
         and the effective cluster shrinks accordingly."
    );
}
