//! Aggregation-topology invariants (tree / ring overlays vs the star
//! baseline).
//!
//! Two oracle families:
//!
//! * **Ideal-link θ identity** — with no interior loss and γ = M, an
//!   overlay only *reorders* the fold's transport: every delivered leaf
//!   still reaches the root, the coordinator folds the same contribution
//!   set in the same ascending-worker order, and θ must be **bit
//!   identical** to the star run.  With zero hop costs the timing
//!   arithmetic is untouched too, so whole recorded rows match bitwise;
//!   with nonzero costs only the clock moves.
//! * **Lossy-link conservation** — interior-edge fates are pure in
//!   `(seed, node, iter, round)`, so the virtual simulator and the
//!   threaded runtime must realize the *same* overlay: identical
//!   [`hybriditer::agg::AggStats`] (folds, edges, kills, per-node lanes),
//!   per-lane `delivered + dropped == sent`, and matching θ.  Parity
//!   scope: scheduled traces only (no stochastic crashes) and γ = M —
//!   below M the drivers admit subtrees in different orders (documented
//!   in docs/AGGREGATION.md).

use hybriditer::agg::AggSpec;
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{Coordinator, LossForm, RunConfig, RunReport, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::net::{LinkModel, NetSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::worker::NativeKrrFactory;

fn problem(machines: usize) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "topology".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 64,
        seed: 17,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn cfg(m: usize, iters: u64) -> RunConfig {
    RunConfig {
        mode: SyncMode::Hybrid { gamma: m },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(0.01),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters)
}

fn run_virtual(p: &KrrProblem, cluster: &ClusterSpec, cfg: &RunConfig) -> RunReport {
    let mut pool = p.native_pool();
    sim::run_virtual(&mut pool, cluster, cfg, &NoEval).unwrap()
}

fn run_real(p: &KrrProblem, cluster: &ClusterSpec, cfg: &RunConfig) -> RunReport {
    let coord = Coordinator::new(cluster.clone(), cfg.clone()).unwrap();
    let factory = NativeKrrFactory::for_problem(p);
    coord.run_real(&factory, &NoEval).unwrap()
}

fn max_theta_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max)
}

#[test]
fn tree_and_ring_ideal_links_theta_bit_identical_to_star() {
    // Zero hop costs: the overlay is pure transport reshuffling, so every
    // recorded row — loss bits, virtual clock bits, inclusion counts —
    // must reproduce the star run exactly, per fan-in and topology.
    let m = 9;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: (1..m).map(|w| (w, 1.0 + w as f64 * 0.5)).collect(),
        seed: 5,
        ..ClusterSpec::default()
    };
    let cfg = cfg(m, 25);
    let star = run_virtual(&p, &cluster, &cfg);
    assert!(star.status.is_healthy(), "star: {:?}", star.status);
    assert_eq!(star.agg.edge_sent, 0, "star realized interior edges");

    for agg in [AggSpec::tree(2), AggSpec::tree(3), AggSpec::tree(8), AggSpec::ring()] {
        let name = format!("{}/fan_in={}", agg.topology.name(), agg.fan_in);
        let over = run_virtual(&p, &cluster.clone().with_agg(agg), &cfg);
        assert!(over.status.is_healthy(), "{name}: {:?}", over.status);
        assert_eq!(star.theta, over.theta, "{name}: θ bits diverged from star");
        assert_eq!(over.agg.edge_dropped, 0, "{name}: ideal links dropped an edge");
        assert_eq!(over.agg.lost_contributions, 0, "{name}: ideal links killed a leaf");
        assert_eq!(star.recorder.len(), over.recorder.len(), "{name}");
        for (rs, ro) in star.recorder.rows().iter().zip(over.recorder.rows()) {
            assert_eq!(rs.iter, ro.iter, "{name}");
            assert_eq!(rs.included, ro.included, "{name} iter {}", rs.iter);
            assert_eq!(
                rs.loss.to_bits(),
                ro.loss.to_bits(),
                "{name} iter {}: loss bits diverged",
                rs.iter
            );
            assert_eq!(
                rs.time.to_bits(),
                ro.time.to_bits(),
                "{name} iter {}: zero-cost overlay moved the clock",
                rs.iter
            );
        }
    }
}

#[test]
fn tree_and_ring_hop_costs_move_the_clock_but_not_theta() {
    // Nonzero fold/xfer costs dilate iteration latency (interior folds
    // and the root's per-message shadow) without touching which
    // contributions fold or in what order — θ stays bit identical.
    let m = 9;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: (1..m).map(|w| (w, 1.0 + w as f64 * 0.5)).collect(),
        seed: 5,
        ..ClusterSpec::default()
    };
    let cfg = cfg(m, 25);
    let star = run_virtual(&p, &cluster, &cfg);
    let star_t = star.recorder.rows().last().unwrap().time;

    for agg in [
        AggSpec::tree(3).with_costs(2e-4, 1e-4),
        AggSpec::ring().with_costs(2e-4, 1e-4),
    ] {
        let name = agg.topology.name();
        let over = run_virtual(&p, &cluster.clone().with_agg(agg), &cfg);
        assert!(over.status.is_healthy(), "{name}: {:?}", over.status);
        assert_eq!(star.theta, over.theta, "{name}: hop costs moved θ bits");
        let over_t = over.recorder.rows().last().unwrap().time;
        assert!(
            over_t > star_t,
            "{name}: hop costs did not dilate the clock ({over_t} <= {star_t})"
        );
        assert!(over.agg.folds > 0, "{name}: overlay never folded");
    }
}

#[test]
fn lossy_interior_edges_conserve_messages_across_drivers() {
    // Cross-driver conservation: both drivers realize the same pure edge
    // fates, so the whole AggStats rollup — per-node lanes included —
    // must agree, every lane must conserve (delivered + dropped == sent),
    // and the fold must land on the same θ.
    let m = 8;
    let p = problem(m);
    let net = NetSpec {
        default_link: LinkModel {
            drop_prob: 0.2,
            dup_prob: 0.2,
            dup_lag: 0.0005,
            ..LinkModel::ideal()
        },
        ..NetSpec::ideal()
    };
    let mk_cluster = |agg: AggSpec| {
        ClusterSpec {
            workers: m,
            base_compute: 0.005,
            slow_nodes: (1..m).map(|w| (w, 1.0 + w as f64 * 0.5)).collect(),
            seed: 21,
            ..ClusterSpec::default()
        }
        .with_net(net.clone())
        .with_agg(agg)
    };
    let cfg = cfg(m, 30);

    for agg in [AggSpec::tree(2), AggSpec::ring()] {
        let name = agg.topology.name();
        let cluster = mk_cluster(agg);
        let virt = run_virtual(&p, &cluster, &cfg);
        let real = run_real(&p, &cluster, &cfg);
        assert!(virt.status.is_healthy(), "{name} virtual: {:?}", virt.status);
        assert!(real.status.is_healthy(), "{name} real: {:?}", real.status);

        // Leaf roundtrips and interior edges each realize the same pure
        // fates in both drivers.
        assert_eq!(virt.net, real.net, "{name}: leaf accounting diverged");
        assert_eq!(virt.agg, real.agg, "{name}: overlay accounting diverged");
        assert_eq!(virt.agg.topology, name);
        assert!(virt.agg.edge_sent > 0, "{name}: overlay realized no edges");
        assert!(virt.agg.edge_dropped > 0, "{name}: lossy spec dropped no edges");

        // Conservation, in total and per interior node.
        assert_eq!(
            virt.agg.edge_sent,
            virt.agg.edge_delivered + virt.agg.edge_dropped,
            "{name}: edge totals do not conserve"
        );
        for lane in &virt.agg.per_node {
            assert_eq!(
                lane.sent,
                lane.delivered + lane.dropped,
                "{name}: node {} lane does not conserve",
                lane.node
            );
            assert!(lane.node < m, "{name}: lane for out-of-range node {}", lane.node);
        }
        let lane_sent: u64 = virt.agg.per_node.iter().map(|l| l.sent).sum();
        assert_eq!(lane_sent, virt.agg.edge_sent, "{name}: lanes do not tile the total");

        // An interior drop must actually kill contributions (tree) or
        // clear segments; either way both drivers agree on the decisions
        // and the resulting trajectory.
        if name == "tree" {
            assert!(virt.agg.lost_contributions > 0, "tree: drops never killed a leaf");
            assert_eq!(
                virt.total_abandoned, real.total_abandoned,
                "tree: abandonment accounting diverged"
            );
        }
        assert_eq!(virt.recorder.len(), real.recorder.len(), "{name}");
        for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
            assert_eq!(rv.iter, rr.iter, "{name}: row iteration mismatch");
            assert_eq!(rv.included, rr.included, "{name} iter {}", rv.iter);
        }
        let diff = max_theta_diff(&virt.theta, &real.theta);
        assert!(diff < 1e-5, "{name}: θ diverged across drivers: max diff {diff}");
    }
}

#[test]
fn non_hybrid_modes_reject_overlay_topologies() {
    // The overlay is validated up front: BSP and async coordinators must
    // refuse tree/ring rather than silently running star.
    let m = 4;
    let cluster = ClusterSpec { workers: m, ..ClusterSpec::default() }
        .with_agg(AggSpec::tree(2));
    let bsp = RunConfig { mode: SyncMode::Bsp, ..RunConfig::default() }.with_iters(4);
    assert!(Coordinator::new(cluster.clone(), bsp).is_err(), "BSP accepted a tree overlay");
    let asy = RunConfig { mode: SyncMode::Async { damping: 0.0 }, ..RunConfig::default() }
        .with_iters(4);
    assert!(Coordinator::new(cluster, asy).is_err(), "async accepted a tree overlay");
}
