//! Synthetic kernel-ridge-regression problem with a planted optimum.
//!
//! Generation mirrors the paper's setting (eq. 2): draw raw inputs `x`,
//! map them through the RBF random-Fourier feature map `K[x]` (the same
//! `W`, `b` the L1 kernel uses), produce labels `y = K[x]·θ_true + noise`,
//! shard rows across M machines, and solve the normal equations for the
//! exact regularized optimum `θ*` so experiments can report `‖θ_t − θ*‖`.

use crate::data::shard::{split_even, Shard};
use crate::data::solver;
use crate::math::vec_ops;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Specification of a synthetic KRR problem.
#[derive(Clone, Debug)]
pub struct KrrProblemSpec {
    /// Artifact config name ("small" | "default" | "wide") — must match an
    /// AOT artifact when the XLA backend is used.
    pub config: String,
    /// Raw input dimension `d`.
    pub d: usize,
    /// Kernel feature dimension `l`.
    pub l: usize,
    /// Examples per machine `ζ`.
    pub zeta: usize,
    /// Number of machines `M` (total N = M·ζ).
    pub machines: usize,
    /// Label noise std.
    pub noise: f64,
    /// Regularization λ.
    pub lambda: f64,
    /// RBF bandwidth σ (W ~ N(0, 1/σ²)).
    pub bandwidth: f64,
    /// Holdout evaluation rows.
    pub eval_rows: usize,
    pub seed: u64,
}

impl KrrProblemSpec {
    /// The "small" artifact config (fast tests).
    pub fn small() -> KrrProblemSpec {
        KrrProblemSpec {
            config: "small".into(),
            d: 8,
            l: 32,
            zeta: 256,
            machines: 8,
            noise: 0.1,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 512,
            seed: 42,
        }
    }

    /// The "default" artifact config (experiment workhorse).
    pub fn default_config() -> KrrProblemSpec {
        KrrProblemSpec {
            config: "default".into(),
            d: 8,
            l: 64,
            zeta: 2048,
            machines: 16,
            noise: 0.1,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 4096,
            seed: 42,
        }
    }

    /// The "wide" artifact config (perf stress).
    pub fn wide() -> KrrProblemSpec {
        KrrProblemSpec {
            config: "wide".into(),
            d: 16,
            l: 256,
            zeta: 1024,
            machines: 8,
            noise: 0.1,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 2048,
            seed: 42,
        }
    }

    pub fn with_machines(mut self, m: usize) -> Self {
        self.machines = m;
        self
    }

    pub fn with_seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Total examples N = M·ζ.
    pub fn total_examples(&self) -> usize {
        self.machines * self.zeta
    }
}

/// A fully materialized problem instance.
pub struct KrrProblem {
    pub spec: KrrProblemSpec,
    /// Per-machine shards of (Φ, y).
    pub shards: Vec<Shard>,
    /// Holdout shard for unbiased loss evaluation.
    pub eval: Shard,
    /// The planted generating parameters (NOT θ*; noise + reg shift it).
    pub theta_true: Vec<f32>,
    /// Exact solution of eq. 2's normal equations over the training set.
    pub theta_star: Vec<f32>,
    /// Loss (eq. 2 objective over training set) at θ*.
    pub loss_star: f64,
    /// RBF projection (kept for feature-map reuse / artifact cross-checks).
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl KrrProblem {
    /// Generate a problem instance (pure rust; the XLA feature-map path is
    /// exercised separately by `runtime` integration tests).
    pub fn generate(spec: &KrrProblemSpec) -> Result<KrrProblem> {
        if spec.machines == 0 || spec.zeta == 0 || spec.l == 0 {
            return Err(Error::Config("KrrProblemSpec must be non-degenerate".into()));
        }
        let mut rng = Pcg64::new(spec.seed, 0xDA7A);
        let n = spec.total_examples();
        let (d, l) = (spec.d, spec.l);

        // Shared feature map: W ~ N(0, 1/bandwidth²), b ~ U[0, 2π).
        let mut w = vec![0.0f32; d * l];
        rng.fill_normal(&mut w, 0.0, (1.0 / spec.bandwidth) as f32);
        let mut b = vec![0.0f32; l];
        rng.fill_uniform(&mut b, 0.0, (2.0 * std::f64::consts::PI) as f32);

        // Planted parameters.
        let mut theta_true = vec![0.0f32; l];
        rng.fill_normal(&mut theta_true, 0.0, 1.0);

        // Training set.
        let (phi, y) = gen_rows(n, spec, &w, &b, &theta_true, &mut rng);
        let shards = split_even(&phi, &y, l, spec.machines, spec.zeta);

        // Holdout.
        let (phi_e, y_e) = gen_rows(spec.eval_rows.max(1), spec, &w, &b, &theta_true, &mut rng);
        let eval = Shard::new(phi_e, y_e, spec.eval_rows.max(1), l);

        // Exact solution + optimal loss.
        let theta_star = solver::ridge_solve(&phi, &y, l, spec.lambda)?;
        let loss_star = objective(&theta_star, &phi, &y, l, spec.lambda);

        Ok(KrrProblem {
            spec: spec.clone(),
            shards,
            eval,
            theta_true,
            theta_star,
            loss_star,
            w,
            b,
        })
    }

    pub fn dim(&self) -> usize {
        self.spec.l
    }

    /// Objective of eq. 2 over the full training set.
    pub fn train_loss(&self, theta: &[f32]) -> f64 {
        let mut num = 0.0;
        let mut rows = 0usize;
        for s in &self.shards {
            num += sumsq_residual(theta, &s.phi, &s.y, s.l);
            rows += s.rows;
        }
        0.5 * num / rows as f64 + 0.5 * self.spec.lambda * vec_ops::dot(theta, theta)
    }

    /// Objective over the holdout shard.
    pub fn eval_loss(&self, theta: &[f32]) -> f64 {
        let s = &self.eval;
        0.5 * sumsq_residual(theta, &s.phi, &s.y, s.l) / s.rows as f64
            + 0.5 * self.spec.lambda * vec_ops::dot(theta, theta)
    }

    /// `‖θ − θ*‖₂`.
    pub fn theta_err(&self, theta: &[f32]) -> f64 {
        vec_ops::dist2(theta, &self.theta_star)
    }

    /// Pure-rust compute pool over this problem's shards (fused kernel).
    pub fn native_pool(&self) -> crate::data::native::NativeKrrPool {
        crate::data::native::NativeKrrPool::new(
            self.shards.clone(),
            self.spec.lambda as f32,
        )
    }

    /// Pool running the seed's two-pass reference kernel — the golden
    /// baseline for the fused kernel's equivalence tests.
    pub fn reference_pool(&self) -> crate::data::native::NativeKrrPool {
        crate::data::native::NativeKrrPool::reference(
            self.shards.clone(),
            self.spec.lambda as f32,
        )
    }
}

fn gen_rows(
    rows: usize,
    spec: &KrrProblemSpec,
    w: &[f32],
    b: &[f32],
    theta_true: &[f32],
    rng: &mut Pcg64,
) -> (Vec<f32>, Vec<f32>) {
    let (d, l) = (spec.d, spec.l);
    let scale = (2.0f64 / l as f64).sqrt() as f32;
    let mut phi = vec![0.0f32; rows * l];
    let mut y = vec![0.0f32; rows];
    let mut x = vec![0.0f32; d];
    for r in 0..rows {
        rng.fill_uniform(&mut x, -1.0, 1.0);
        let row = &mut phi[r * l..(r + 1) * l];
        // phi_j = cos(x·W[:,j] + b_j) * sqrt(2/l)   (W stored row-major d×l)
        for j in 0..l {
            let mut z = b[j];
            for (k, &xk) in x.iter().enumerate() {
                z += xk * w[k * l + j];
            }
            row[j] = z.cos() * scale;
        }
        y[r] = vec_ops::dot(row, theta_true) as f32 + rng.normal_ms(0.0, spec.noise) as f32;
    }
    (phi, y)
}

/// Sum of squared residuals of a row-major shard.
pub fn sumsq_residual(theta: &[f32], phi: &[f32], y: &[f32], l: usize) -> f64 {
    let mut s = 0.0f64;
    for (row, &yi) in phi.chunks_exact(l).zip(y.iter()) {
        let r = vec_ops::dot(row, theta) - yi as f64;
        s += r * r;
    }
    s
}

/// The eq. 2 objective for an arbitrary (phi, y) matrix.
pub fn objective(theta: &[f32], phi: &[f32], y: &[f32], l: usize, lambda: f64) -> f64 {
    0.5 * sumsq_residual(theta, phi, y, l) / y.len() as f64
        + 0.5 * lambda * vec_ops::dot(theta, theta)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> KrrProblemSpec {
        KrrProblemSpec {
            config: "test".into(),
            d: 4,
            l: 16,
            zeta: 64,
            machines: 4,
            noise: 0.05,
            lambda: 0.01,
            bandwidth: 1.0,
            eval_rows: 128,
            seed: 7,
        }
    }

    #[test]
    fn generates_consistent_shapes() {
        let p = KrrProblem::generate(&tiny_spec()).unwrap();
        assert_eq!(p.shards.len(), 4);
        for s in &p.shards {
            assert_eq!(s.rows, 64);
            assert_eq!(s.l, 16);
        }
        assert_eq!(p.theta_star.len(), 16);
        assert_eq!(p.eval.rows, 128);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = KrrProblem::generate(&tiny_spec()).unwrap();
        let b = KrrProblem::generate(&tiny_spec()).unwrap();
        assert_eq!(a.shards[0].phi, b.shards[0].phi);
        assert_eq!(a.theta_star, b.theta_star);
    }

    #[test]
    fn theta_star_is_a_minimum() {
        let p = KrrProblem::generate(&tiny_spec()).unwrap();
        let base = p.train_loss(&p.theta_star);
        assert!((base - p.loss_star).abs() < 1e-9);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..10 {
            let mut pert = p.theta_star.clone();
            for v in pert.iter_mut() {
                *v += rng.normal_ms(0.0, 0.05) as f32;
            }
            assert!(p.train_loss(&pert) > base);
        }
    }

    #[test]
    fn theta_star_close_to_truth_with_low_noise() {
        let mut spec = tiny_spec();
        spec.noise = 0.01;
        spec.lambda = 1e-4;
        spec.machines = 8; // more data
        let p = KrrProblem::generate(&spec).unwrap();
        let rel = vec_ops::dist2(&p.theta_star, &p.theta_true) / vec_ops::norm2(&p.theta_true);
        assert!(rel < 0.2, "rel={rel}");
    }

    #[test]
    fn features_bounded() {
        let p = KrrProblem::generate(&tiny_spec()).unwrap();
        let bound = (2.0f64 / 16.0).sqrt() as f32 + 1e-6;
        for s in &p.shards {
            assert!(s.phi.iter().all(|v| v.abs() <= bound));
        }
    }

    #[test]
    fn rejects_degenerate_spec() {
        let mut spec = tiny_spec();
        spec.machines = 0;
        assert!(KrrProblem::generate(&spec).is_err());
    }
}
