//! Nonlinear conjugate gradient (Fletcher–Reeves with periodic restart).
//!
//! Same caveat as L-BFGS: a proper line search would add synchronization
//! rounds, so the master takes fixed-η steps along conjugate directions and
//! restarts every `restart` iterations (or on a non-descent direction),
//! which is the standard stochastic compromise.

use super::Optimizer;
use crate::math::vec_ops;

#[derive(Clone, Debug)]
pub struct ConjugateGradient {
    eta: f64,
    restart: usize,
    dir: Vec<f32>,
    prev_gg: f64,
    since_restart: usize,
}

impl ConjugateGradient {
    pub fn new(eta: f64, restart: usize) -> ConjugateGradient {
        ConjugateGradient {
            eta,
            restart: restart.max(1),
            dir: Vec::new(),
            prev_gg: 0.0,
            since_restart: 0,
        }
    }
}

impl Optimizer for ConjugateGradient {
    fn step(&mut self, theta: &mut [f32], grad: &[f32], _iter: u64) {
        let gg = vec_ops::dot(grad, grad);
        let fresh = self.dir.len() != theta.len()
            || self.since_restart >= self.restart
            || self.prev_gg <= 0.0;
        if fresh {
            self.dir = grad.iter().map(|g| -g).collect();
            self.since_restart = 0;
        } else {
            // Fletcher–Reeves: β = g_t·g_t / g_{t-1}·g_{t-1}.
            let beta = (gg / self.prev_gg) as f32;
            for (d, &g) in self.dir.iter_mut().zip(grad.iter()) {
                *d = -g + beta * *d;
            }
            // Restart on non-descent direction.
            if vec_ops::dot(&self.dir, grad) >= 0.0 {
                for (d, &g) in self.dir.iter_mut().zip(grad.iter()) {
                    *d = -g;
                }
                self.since_restart = 0;
            }
        }
        self.prev_gg = gg;
        self.since_restart += 1;
        vec_ops::axpy(self.eta as f32, &self.dir, theta);
    }

    fn name(&self) -> &'static str {
        "cg"
    }

    fn reset(&mut self) {
        self.dir.clear();
        self.prev_gg = 0.0;
        self.since_restart = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_step_is_steepest_descent() {
        let mut o = ConjugateGradient::new(0.1, 10);
        let mut theta = vec![0.0f32, 0.0];
        o.step(&mut theta, &[1.0, -2.0], 0);
        assert!((theta[0] + 0.1).abs() < 1e-6);
        assert!((theta[1] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn directions_become_conjugate_ish() {
        // On a quadratic the second direction must not be parallel to the
        // first (β mixes in history).
        let mut o = ConjugateGradient::new(0.3, 10);
        let curv = [4.0f32, 1.0];
        let mut x = vec![1.0f32, 1.0];
        let g0: Vec<f32> = x.iter().zip(&curv).map(|(xi, c)| c * xi).collect();
        o.step(&mut x, &g0, 0);
        let d0 = o.dir.clone();
        let g1: Vec<f32> = x.iter().zip(&curv).map(|(xi, c)| c * xi).collect();
        o.step(&mut x, &g1, 1);
        let d1 = o.dir.clone();
        let cos = vec_ops::dot(&d0, &d1) / (vec_ops::norm2(&d0) * vec_ops::norm2(&d1));
        assert!(cos.abs() < 0.999, "directions degenerate: cos={cos}");
    }

    #[test]
    fn converges_on_quadratic() {
        let mut o = ConjugateGradient::new(0.3, 6);
        let err = crate::optim::test_util::run_quadratic(&mut o, 300);
        assert!(err < 1e-2, "err={err}");
    }

    #[test]
    fn reset_behaves() {
        let mut o = ConjugateGradient::new(0.1, 5);
        let mut theta = vec![1.0f32];
        o.step(&mut theta, &[1.0], 0);
        o.reset();
        assert!(o.dir.is_empty());
    }
}
