//! Configuration substrate: a shared dynamic [`Value`] tree with TOML-subset
//! and JSON parsers, plus the typed experiment schema.
//!
//! `serde`/`toml`/`serde_json` are not in the offline vendor set, so both
//! parsers are implemented here (DESIGN.md §3).  The TOML subset covers what
//! experiment configs need: comments, `[section]` / `[a.b]` tables, strings,
//! ints, floats, bools, and flat arrays.  The JSON parser is complete
//! (minus `\u` surrogate pairs folding to replacement chars) and is what
//! `runtime::manifest` uses to read `artifacts/manifest.json`.

pub mod json;
pub mod schema;
pub mod toml;
pub mod value;

pub use schema::ExperimentConfig;
pub use value::Value;
