//! Serving-mode properties (docs/SERVING.md).
//!
//! The serve engine's per-window fate sequence — offered / admitted /
//! shed / enqueued / drained — is a pure function of `(serve seed,
//! window index)` and the deterministic queue model; it never reads
//! driver time or driver RNG streams.  So unlike θ parity (which needs
//! deterministic timing), the serve sequence must be **bit-identical
//! across drivers** whenever both complete the same number of
//! iterations, and `ServeStats::seq_digest` is the witness.
//!
//! The [`ThetaCell`] half checks the snapshot contract under real
//! contention: readers are never torn, never lag a completed publish,
//! and a held snapshot survives later publishes untouched.

use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, RunReport, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::prelude::{AdmissionPolicy, Driver, Runner, ServeSpec};
use hybriditer::serve::{Burst, ThetaCell};
use hybriditer::trace::JournalSink;
use hybriditer::worker::NativeKrrFactory;

fn problem(machines: usize) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "serve-prop".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 64,
        seed: 17,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn base_cfg(p: &KrrProblem, mode: SyncMode, iters: u64) -> RunConfig {
    RunConfig {
        mode,
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters)
}

/// A spec that exercises the whole arrival model: diurnal swing, a
/// scripted burst, hot-key skew, and SLO-aware admission.
fn busy_spec(admission: AdmissionPolicy) -> ServeSpec {
    ServeSpec {
        arrival_rate: 2_500.0,
        admission,
        diurnal_amplitude: 0.5,
        diurnal_period_s: 0.2,
        bursts: vec![Burst { start_s: 0.05, end_s: 0.15, factor: 4.0 }],
        ..ServeSpec::default()
    }
}

fn run_serving(
    p: &KrrProblem,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    spec: &ServeSpec,
    driver: Driver,
) -> RunReport {
    match driver {
        Driver::Virtual => {
            let mut pool = p.native_pool();
            Runner::new(cluster, cfg)
                .driver(Driver::Virtual)
                .pool(&mut pool)
                .serve(spec.clone())
                .run()
                .unwrap()
        }
        Driver::Threaded => {
            let factory = NativeKrrFactory::for_problem(p);
            Runner::new(cluster, cfg)
                .driver(Driver::Threaded)
                .factory(&factory)
                .serve(spec.clone())
                .run()
                .unwrap()
        }
    }
}

#[test]
fn serve_sequence_bit_identical_across_drivers_sync() {
    // Hybrid γ = 3 of 4 with a chronic straggler: every iteration closes
    // a barrier in both drivers, so both step the same 40 serve windows
    // — and the entire ServeStats must agree field for field, digest
    // included, even though the two drivers run on different clocks.
    let m = 4;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 12.0)],
        seed: 9,
        ..ClusterSpec::default()
    };
    let cfg = base_cfg(&p, SyncMode::Hybrid { gamma: 3 }, 40);
    let spec = busy_spec(AdmissionPolicy::Shed);

    let virt = run_serving(&p, &cluster, &cfg, &spec, Driver::Virtual);
    let real = run_serving(&p, &cluster, &cfg, &spec, Driver::Threaded);

    let vs = virt.serve.expect("virtual serving run kept no ServeStats");
    let rs = real.serve.expect("threaded serving run kept no ServeStats");
    assert_eq!(vs.windows, 40);
    assert!(vs.offered > 0, "arrival process generated nothing");
    assert!(vs.shed > 0, "burst at 4x base rate never tripped admission");
    assert_eq!(vs, rs, "serve fate sequence diverged across drivers");
}

#[test]
fn serve_sequence_bit_identical_across_drivers_async() {
    // Async mode steps the serve clock every M-th applied update, keyed
    // on the update count — not on which worker's gradient landed — so
    // the sequence survives the threaded driver's arbitrary interleaving.
    let m = 2;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0)],
        seed: 11,
        ..ClusterSpec::default()
    };
    let cfg = base_cfg(&p, SyncMode::Async { damping: 0.5 }, 24);
    let spec = busy_spec(AdmissionPolicy::Queue);

    let virt = run_serving(&p, &cluster, &cfg, &spec, Driver::Virtual);
    let real = run_serving(&p, &cluster, &cfg, &spec, Driver::Threaded);

    let vs = virt.serve.expect("virtual async serving run kept no ServeStats");
    let rs = real.serve.expect("threaded async serving run kept no ServeStats");
    // 24 applied updates over 2 workers = 12 completed serve windows.
    assert_eq!(vs.windows, 12);
    assert!(vs.offered > 0);
    assert_eq!(vs, rs, "async serve fate sequence diverged across drivers");
}

#[test]
fn serve_digest_pure_in_seed_and_schedule() {
    let m = 4;
    let p = problem(m);
    let cluster = ClusterSpec { workers: m, ..ClusterSpec::default() };
    let cfg = base_cfg(&p, SyncMode::Bsp, 30);
    let spec = busy_spec(AdmissionPolicy::Shed);

    // Same (seed, schedule) twice → the same digest, bit for bit.
    let a = run_serving(&p, &cluster, &cfg, &spec, Driver::Virtual).serve.unwrap();
    let b = run_serving(&p, &cluster, &cfg, &spec, Driver::Virtual).serve.unwrap();
    assert_eq!(a, b, "serve engine is not replay-deterministic");

    // A different serve seed → a different arrival realization.
    let reseeded = ServeSpec { seed: spec.seed + 1, ..spec.clone() };
    let c = run_serving(&p, &cluster, &cfg, &reseeded, Driver::Virtual).serve.unwrap();
    assert_ne!(a.seq_digest, c.seq_digest, "digest ignored the serve seed");

    // A different burst schedule → a different offered-load sequence.
    let rescheduled = ServeSpec { bursts: Vec::new(), ..spec };
    let d = run_serving(&p, &cluster, &cfg, &rescheduled, Driver::Virtual).serve.unwrap();
    assert_ne!(a.seq_digest, d.seq_digest, "digest ignored the burst schedule");
    assert!(a.offered > d.offered, "bursts did not raise offered load");
}

#[test]
fn serving_is_inert_when_absent_and_journaled_when_present() {
    // Without a spec, a traced Runner run must write the byte-identical
    // journal (and θ) the legacy traced entry point writes: the serving
    // hook compiles to a skipped `if let` on None.  With a spec, the
    // same run additionally journals serve_window/theta_publish events.
    let m = 4;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 5,
        ..ClusterSpec::default()
    };
    let cfg = base_cfg(&p, SyncMode::Hybrid { gamma: m }, 14);

    let mut pool = p.native_pool();
    let mut legacy_sink = JournalSink::new();
    let legacy = hybriditer::sim::run_virtual_traced(
        &mut pool,
        &cluster,
        &cfg,
        &hybriditer::sim::NoEval,
        &mut legacy_sink,
    )
    .unwrap();

    let mut pool = p.native_pool();
    let mut runner_sink = JournalSink::new();
    let plain = Runner::new(&cluster, &cfg)
        .driver(Driver::Virtual)
        .pool(&mut pool)
        .trace(&mut runner_sink)
        .run()
        .unwrap();
    assert!(plain.serve.is_none());
    assert_eq!(legacy.theta, plain.theta, "Runner wrapper moved θ bits");
    assert_eq!(
        legacy_sink.jsonl_normalized(),
        runner_sink.jsonl_normalized(),
        "Runner wrapper changed the journal"
    );

    let mut pool = p.native_pool();
    let mut serve_sink = JournalSink::new();
    let served = Runner::new(&cluster, &cfg)
        .driver(Driver::Virtual)
        .pool(&mut pool)
        .trace(&mut serve_sink)
        .serve(busy_spec(AdmissionPolicy::Shed))
        .run()
        .unwrap();
    assert_eq!(legacy.theta, served.theta, "serving perturbed training θ");
    let journal = serve_sink.jsonl_normalized();
    assert!(
        journal.contains("\"event\":\"serve_window\""),
        "serving run journaled no serve_window events"
    );
    assert!(
        journal.contains("\"event\":\"theta_publish\""),
        "serving run journaled no theta_publish events"
    );
}

#[test]
fn theta_cell_readers_never_torn_and_never_lag_a_publish() {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::Arc;

    let dim = 256;
    let cell = Arc::new(ThetaCell::new(dim));
    // Epoch floor: stored *after* each publish completes, so any read
    // that starts later must observe at least this epoch.
    let published = Arc::new(AtomicU64::new(0));
    let done = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..4)
        .map(|_| {
            let cell = Arc::clone(&cell);
            let published = Arc::clone(&published);
            let done = Arc::clone(&done);
            std::thread::spawn(move || {
                let mut last = 0u64;
                let mut reads = 0u64;
                while !done.load(Ordering::Acquire) {
                    let floor = published.load(Ordering::Acquire);
                    let (epoch, snap) = cell.read();
                    // Never torn: the writer fills every slot with the
                    // epoch tag, so a mixed-epoch view is a torn read.
                    assert!(
                        snap.iter().all(|&x| x == epoch as f32),
                        "torn read at epoch {epoch}"
                    );
                    assert!(epoch >= last, "epoch went backwards: {last} -> {epoch}");
                    assert!(
                        epoch >= floor,
                        "read returned epoch {epoch} after publish {floor} completed"
                    );
                    last = epoch;
                    reads += 1;
                }
                reads
            })
        })
        .collect();

    // A held snapshot must stay frozen while later publishes land in
    // the other slot (and in a fresh allocation once both are pinned).
    let (held_epoch, held) = cell.read();
    for epoch in 1..=2_000u64 {
        cell.publish(&vec![epoch as f32; dim], epoch);
        published.store(epoch, Ordering::Release);
    }
    assert!(
        held.iter().all(|&x| x == held_epoch as f32),
        "held snapshot mutated under later publishes"
    );

    done.store(true, Ordering::Release);
    for r in readers {
        let reads = r.join().expect("reader panicked — contract violated");
        assert!(reads > 0, "reader never completed a read");
    }
    assert_eq!(cell.epoch(), 2_000);
}
