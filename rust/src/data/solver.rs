//! Exact ridge solver: `θ* = (Φ^T Φ / n + λ I)^{-1} Φ^T y / n`.
//!
//! This is the ground truth for the paper's convergence experiments (the
//! optimum of eq. 2).  `l ≤` a few hundred, so dense Cholesky is instant.

use crate::math::cholesky::cholesky_solve;
use crate::math::vec_ops;
use crate::Result;

/// Solve the regularized normal equations for row-major `phi` (n × l).
pub fn ridge_solve(phi: &[f32], y: &[f32], l: usize, lambda: f64) -> Result<Vec<f32>> {
    let n = y.len();
    assert_eq!(phi.len(), n * l);

    // A = Φ^T Φ / n + λ I  (f64 accumulation).
    let mut a = vec![0.0f64; l * l];
    vec_ops::gram(phi, n, l, &mut a);
    for v in a.iter_mut() {
        *v /= n as f64;
    }
    for i in 0..l {
        a[i * l + i] += lambda;
    }

    // b = Φ^T y / n.
    let mut bt = vec![0.0f32; l];
    vec_ops::matvec_t(phi, n, l, y, &mut bt);
    let b: Vec<f64> = bt.iter().map(|&v| v as f64 / n as f64).collect();

    let x = cholesky_solve(&a, l, &b)?;
    Ok(x.into_iter().map(|v| v as f32).collect())
}

/// Residual of the normal equations at `theta` (diagnostic):
/// `‖(Φ^TΦ/n + λI) θ − Φ^T y/n‖₂`.
pub fn normal_eq_residual(phi: &[f32], y: &[f32], l: usize, lambda: f64, theta: &[f32]) -> f64 {
    let n = y.len();
    let mut tmp = vec![0.0f32; n];
    vec_ops::matvec(phi, n, l, theta, &mut tmp);
    let mut at = vec![0.0f32; l];
    vec_ops::matvec_t(phi, n, l, &tmp, &mut at);
    let mut bt = vec![0.0f32; l];
    vec_ops::matvec_t(phi, n, l, y, &mut bt);
    let mut r2 = 0.0f64;
    for i in 0..l {
        let r = at[i] as f64 / n as f64 + lambda * theta[i] as f64 - bt[i] as f64 / n as f64;
        r2 += r * r;
    }
    r2.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_noiseless_parameters_with_tiny_reg() {
        let mut rng = Pcg64::seeded(1);
        let (n, l) = (400, 12);
        let mut phi = vec![0.0f32; n * l];
        rng.fill_normal(&mut phi, 0.0, 1.0);
        let mut theta = vec![0.0f32; l];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        let mut y = vec![0.0f32; n];
        vec_ops::matvec(&phi, n, l, &theta, &mut y);
        let got = ridge_solve(&phi, &y, l, 1e-9).unwrap();
        for (g, t) in got.iter().zip(&theta) {
            assert!((g - t).abs() < 1e-3, "{g} vs {t}");
        }
    }

    #[test]
    fn solution_satisfies_normal_equations() {
        let mut rng = Pcg64::seeded(2);
        let (n, l) = (300, 8);
        let mut phi = vec![0.0f32; n * l];
        rng.fill_normal(&mut phi, 0.0, 1.0);
        let mut y = vec![0.0f32; n];
        rng.fill_normal(&mut y, 0.0, 1.0);
        let theta = ridge_solve(&phi, &y, l, 0.1).unwrap();
        let res = normal_eq_residual(&phi, &y, l, 0.1, &theta);
        assert!(res < 1e-5, "residual {res}");
    }

    #[test]
    fn larger_lambda_shrinks_solution() {
        let mut rng = Pcg64::seeded(3);
        let (n, l) = (200, 6);
        let mut phi = vec![0.0f32; n * l];
        rng.fill_normal(&mut phi, 0.0, 1.0);
        let mut y = vec![0.0f32; n];
        rng.fill_normal(&mut y, 0.0, 1.0);
        let t1 = ridge_solve(&phi, &y, l, 0.001).unwrap();
        let t2 = ridge_solve(&phi, &y, l, 10.0).unwrap();
        assert!(vec_ops::norm2(&t2) < vec_ops::norm2(&t1));
    }
}
