//! Slave thread body (Algorithm 3 + straggler/fault injection).
//!
//! Elastic clusters: each `Work` message names the shards this worker
//! currently owns (the master re-plans ownership at iteration boundaries),
//! so the slave computes one gradient per assigned shard and reports them
//! in a single `Grad` message.  Injected straggle scales with the number of
//! assigned shards, mirroring the virtual driver's serial-execution model.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::cluster::{MasterMsg, ShardGrad, WorkerMsg};
use crate::straggler::{FailureEvent, FailureState, StragglerProfile};
use crate::util::rng::Pcg64;
use crate::worker::ComputeFactory;

/// Worker thread entry point: build compute locally (PJRT engines are
/// per-thread), then serve Work messages until Shutdown / simulated crash.
///
/// `generation` counts supervisor respawns of this worker slot: the RNG
/// streams are salted with it so a replacement thread draws a fresh
/// failure/delay sequence instead of replaying its predecessor's.
/// Generation 0 leaves both streams bit-identical to the historical ones.
pub fn worker_main(
    w: usize,
    cluster_seed: u64,
    profile: StragglerProfile,
    generation: u64,
    factory: &dyn ComputeFactory,
    rx: mpsc::Receiver<MasterMsg>,
    tx: mpsc::Sender<WorkerMsg>,
) {
    let mut compute = match factory.build(w) {
        Ok(c) => c,
        Err(e) => {
            let _ = tx.send(WorkerMsg::Fatal {
                worker: w,
                error: format!("compute init failed: {e}"),
            });
            return;
        }
    };
    let gen_salt = generation.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut delay_rng = Pcg64::new(cluster_seed ^ 0xBEEF ^ gen_salt, w as u64);
    let mut fail_rng = Pcg64::new(cluster_seed ^ 0xFA11 ^ gen_salt, w as u64);
    let mut fstate = FailureState::new(profile.failure.clone());
    // Recycled gradient buffers from the master's free-list; popped for
    // each reply payload so steady-state replies allocate nothing.
    let mut spares: Vec<Vec<f32>> = Vec::new();

    while let Ok(msg) = rx.recv() {
        let (mut iter, mut theta, mut shards, mut net_delay, mut compute_scale) = match msg {
            MasterMsg::Shutdown => break,
            MasterMsg::Work { iter, theta, shards, net_delay, compute_scale, recycle } => {
                spares.extend(recycle);
                (iter, theta, shards, net_delay, compute_scale)
            }
        };
        // A straggling slave may find newer broadcasts already queued; jump
        // to the freshest θ (Algorithm 3 computes on whatever θ_t it holds —
        // results for superseded iterations would be abandoned anyway).
        let mut shutdown = false;
        while let Ok(next) = rx.try_recv() {
            match next {
                MasterMsg::Shutdown => {
                    shutdown = true;
                    break;
                }
                MasterMsg::Work {
                    iter: i2,
                    theta: t2,
                    shards: s2,
                    net_delay: n2,
                    compute_scale: c2,
                    recycle,
                } => {
                    spares.extend(recycle);
                    iter = i2;
                    theta = t2;
                    shards = s2;
                    net_delay = n2;
                    compute_scale = c2;
                }
            }
        }
        if shutdown {
            break;
        }

        match fstate.step(iter, &mut fail_rng) {
            FailureEvent::Crashed => {
                let _ = tx.send(WorkerMsg::SimulatedCrash { worker: w, iter });
                // A crashed worker stops responding (keep draining so the
                // master's sends don't error, but do no work).
                for m in rx.iter() {
                    if matches!(m, MasterMsg::Shutdown) {
                        break;
                    }
                }
                return;
            }
            FailureEvent::TransientDrop => continue, // result lost
            FailureEvent::Down | FailureEvent::Rejoined | FailureEvent::Healthy => {}
        }

        // Injected straggle: chronic slow factor, capacity dilation, and
        // the master-planned warm-up scale apply to the base compute
        // budget, stochastic delay on top (see DESIGN.md §3).  Both scale
        // with the number of assigned shards (serial execution), matching
        // the virtual driver's `latency × load` model.  The master-planned
        // network delay rides on top, un-scaled: one roundtrip per report.
        // A zero-shard assignment is a control-plane keep-alive: flat base
        // cost, no compute scaling, no delay draw — mirroring the virtual
        // async heartbeat (the sync master never dispatches shard-less
        // workers at all).
        let extra = if shards.is_empty() {
            profile.base_compute + net_delay
        } else {
            (profile.base_compute
                * (profile.slow_factor * compute_scale / profile.capacity - 1.0).max(0.0)
                + profile.delay.sample(&mut delay_rng) * compute_scale)
                * shards.len() as f64
                + net_delay
        };

        compute.retain_shards(&shards);
        let t0 = Instant::now();
        let mut results: Vec<ShardGrad> = Vec::with_capacity(shards.len());
        let mut fatal: Option<String> = None;
        for &s in shards.iter() {
            // Reuse a recycled buffer for the reply payload when one is
            // available (its capacity already fits one gradient).
            let mut res = crate::data::GradResult {
                grad: spares.pop().unwrap_or_default(),
                loss_sum: None,
                examples: 0,
            };
            match compute.grad_shard_into(s, &theta, iter, &mut res) {
                Ok(()) => results.push(ShardGrad {
                    shard: s,
                    grad: res.grad,
                    loss_sum: res.loss_sum,
                    examples: res.examples,
                }),
                Err(e) => {
                    fatal = Some(format!("{e}"));
                    break;
                }
            }
        }
        let compute_secs = t0.elapsed().as_secs_f64();
        if extra > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(extra));
        }

        match fatal {
            None => {
                if tx
                    .send(WorkerMsg::Grad {
                        worker: w,
                        iter,
                        shards: results,
                        compute_secs,
                    })
                    .is_err()
                {
                    break; // master gone
                }
            }
            Some(error) => {
                let _ = tx.send(WorkerMsg::Fatal { worker: w, error });
                return;
            }
        }
    }
}
