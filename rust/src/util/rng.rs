//! Deterministic PRNG: PCG64 (XSL-RR 128/64) plus distribution samplers.
//!
//! `rand` is not in the offline vendor set, so the crate carries its own
//! generator.  PCG64 is small, fast, and has well-understood statistical
//! quality; every experiment seeds its own stream so runs are reproducible
//! bit-for-bit (a requirement for the determinism integration tests).

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Seed with an arbitrary 64-bit seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Single-argument convenience seeding (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child stream (for per-worker RNGs).
    pub fn split(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }

    /// Next uniform u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method, simplified).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        // Rejection sampling on the top bits keeps this unbiased.
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box-Muller (cached second value dropped for
    /// simplicity; the hot paths sample vectors with [`Self::fill_normal`]).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal: `exp(N(mu, sigma))`.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto with scale `x_m > 0` and shape `alpha > 0` (heavy tail).
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        x_m / u.powf(1.0 / alpha)
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.next_f64();
        -u.ln() / lambda
    }

    /// Fill a slice with standard-normal f32s.
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_ms(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with uniform `[lo, hi)` f32s.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo as f64, hi as f64) as f32;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(1234, 7);
        let mut b = Pcg64::new(1234, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(1, 1);
        let mut b = Pcg64::new(1, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::seeded(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Pcg64::seeded(9);
        let n = 40_001;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.lognormal(0.5, 1.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // median of lognormal(mu, sigma) = exp(mu)
        let med = xs[n / 2];
        assert!((med - 0.5f64.exp()).abs() < 0.08, "median={med}");
    }

    #[test]
    fn pareto_is_heavy_tailed_and_bounded_below() {
        let mut rng = Pcg64::seeded(11);
        for _ in 0..10_000 {
            assert!(rng.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg64::seeded(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::seeded(17);
        for _ in 0..50 {
            let mut idx = rng.sample_indices(20, 8);
            idx.sort_unstable();
            idx.dedup();
            assert_eq!(idx.len(), 8);
            assert!(idx.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(19);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
