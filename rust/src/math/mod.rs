//! Numeric substrates: vector ops, statistics, normal quantile, Cholesky.
//!
//! Everything the coordinator needs that would normally come from a
//! linear-algebra or stats crate, implemented from scratch (DESIGN.md §3).

pub mod cholesky;
pub mod kernels;
pub mod quantile;
pub mod stats;
pub mod vec_ops;

pub use cholesky::{cholesky_solve, CholeskyFactor};
pub use quantile::normal_quantile;
pub use stats::{OnlineStats, Summary};
