//! The partial synchronization barrier — the mechanism at the heart of
//! Algorithm 2: "if received γ slave nodes, update".
//!
//! [`PartialBarrier`] tracks one iteration's arrivals for the threaded
//! runtime: it answers "is the barrier closed?" after each arrival and
//! classifies everything after closure as abandoned.  The virtual simulator
//! uses the same type so barrier semantics are tested once.

/// Outcome of offering an arrival to the barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Counted toward γ; barrier still open.
    Included,
    /// Counted toward γ and γ reached: barrier closes now.
    IncludedAndClosed,
    /// Arrived after closure (or duplicate): abandoned.
    Abandoned,
    /// Arrival for a different iteration: abandoned as stale.
    Stale,
}

/// One iteration's barrier state.
#[derive(Clone, Debug)]
pub struct PartialBarrier {
    iter: u64,
    gamma: usize,
    arrived: Vec<bool>,
    included: usize,
    closed: bool,
}

impl PartialBarrier {
    /// Barrier for `iter` over `workers` workers closing after `gamma`
    /// distinct arrivals (BSP: `gamma = alive workers`).
    pub fn new(iter: u64, workers: usize, gamma: usize) -> PartialBarrier {
        assert!(gamma >= 1 && gamma <= workers, "gamma {gamma} of {workers}");
        PartialBarrier {
            iter,
            gamma,
            arrived: vec![false; workers],
            included: 0,
            closed: false,
        }
    }

    pub fn iter(&self) -> u64 {
        self.iter
    }

    pub fn gamma(&self) -> usize {
        self.gamma
    }

    pub fn included(&self) -> usize {
        self.included
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Offer worker `w`'s result for iteration `msg_iter`.
    pub fn offer(&mut self, w: usize, msg_iter: u64) -> Admission {
        if msg_iter != self.iter {
            return Admission::Stale;
        }
        if self.closed || self.arrived[w] {
            return Admission::Abandoned;
        }
        self.arrived[w] = true;
        self.included += 1;
        if self.included >= self.gamma {
            self.closed = true;
            Admission::IncludedAndClosed
        } else {
            Admission::Included
        }
    }

    /// Shrink γ when workers die mid-iteration (barrier can then close on
    /// fewer arrivals).  No-op if already satisfied.
    pub fn shrink_gamma(&mut self, new_gamma: usize) {
        self.gamma = new_gamma.max(1).min(self.gamma);
        if self.included >= self.gamma {
            self.closed = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_exactly_at_gamma() {
        let mut b = PartialBarrier::new(7, 4, 2);
        assert_eq!(b.offer(0, 7), Admission::Included);
        assert!(!b.is_closed());
        assert_eq!(b.offer(2, 7), Admission::IncludedAndClosed);
        assert!(b.is_closed());
        assert_eq!(b.offer(1, 7), Admission::Abandoned);
        assert_eq!(b.included(), 2);
    }

    #[test]
    fn duplicate_arrivals_abandoned() {
        let mut b = PartialBarrier::new(0, 3, 3);
        assert_eq!(b.offer(1, 0), Admission::Included);
        assert_eq!(b.offer(1, 0), Admission::Abandoned);
        assert_eq!(b.included(), 1);
    }

    #[test]
    fn stale_iteration_rejected() {
        let mut b = PartialBarrier::new(5, 2, 1);
        assert_eq!(b.offer(0, 4), Admission::Stale);
        assert_eq!(b.offer(0, 6), Admission::Stale);
        assert_eq!(b.offer(0, 5), Admission::IncludedAndClosed);
    }

    #[test]
    fn shrink_gamma_closes_when_satisfied() {
        let mut b = PartialBarrier::new(0, 4, 3);
        b.offer(0, 0);
        b.offer(1, 0);
        assert!(!b.is_closed());
        b.shrink_gamma(2);
        assert!(b.is_closed());
    }

    #[test]
    #[should_panic]
    fn rejects_gamma_zero() {
        PartialBarrier::new(0, 4, 0);
    }

    #[test]
    #[should_panic]
    fn rejects_gamma_above_workers() {
        PartialBarrier::new(0, 4, 5);
    }
}
