//! Microbenchmarks of the L3 hot path — the profiling substrate for the
//! performance pass (EXPERIMENTS.md §Perf).
//!
//! Times each stage of one coordinator iteration in isolation:
//! native shard gradient (fused kernel *and* the two-pass reference it
//! replaced), XLA shard gradient (PJRT dispatch + pallas kernel),
//! aggregation, optimizer step, barrier bookkeeping, and one whole virtual
//! iteration — so regressions in any stage are visible without a profiler.
//!
//! Emits `results/BENCH_micro_hotpath.json` with per-stage mean/p50/p99 and
//! a `fused_speedup` headline (reference mean / fused mean on the default
//! config), the machine-readable perf-trajectory point this and future PRs
//! compare against.  Runs strictly serially — timing a stage while other
//! sweep points share the cores would corrupt the numbers.

use std::hint::black_box;

use hybriditer::bench_harness::{Bench, BenchResult};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::aggregator::{aggregate, AggregatorKind, Contribution};
use hybriditer::coordinator::barrier::PartialBarrier;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::{ComputePool, GradResult, KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::runtime::{ArtifactSet, Engine};
use hybriditer::sim::{self, NoEval};
use hybriditer::util::rng::Pcg64;
use hybriditer::worker::compute::XlaKrrPool;

fn json_stage(r: &BenchResult) -> String {
    format!(
        "    {{\"name\": \"{}\", \"samples\": {}, \"mean_s\": {:.9e}, \"p50_s\": {:.9e}, \
         \"p99_s\": {:.9e}, \"throughput_hz\": {:.3}}}",
        r.name, r.samples, r.mean, r.p50, r.p99, r.throughput_hz
    )
}

fn main() {
    println!("micro_hotpath: per-stage latencies of one coordinator iteration\n");
    let mut rng = Pcg64::seeded(1);
    let mut stages: Vec<BenchResult> = Vec::new();
    let mut fused_default_mean = f64::NAN;
    let mut reference_default_mean = f64::NAN;
    let mut blocked_wide_mean = f64::NAN;
    let mut reference_wide_mean = f64::NAN;

    // --- shard gradient: fused vs reference vs XLA, three configs --------
    for (cfg_name, spec) in [
        ("small (zeta=256, l=32)", KrrProblemSpec::small().with_machines(2)),
        ("default (zeta=2048, l=64)", KrrProblemSpec::default_config().with_machines(2)),
        ("wide (zeta=1024, l=256)", KrrProblemSpec::wide().with_machines(2)),
    ] {
        let problem = KrrProblem::generate(&spec).unwrap();
        let mut theta = vec![0.0f32; problem.dim()];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        let mut out = GradResult::empty();

        let mut native = problem.native_pool();
        let fused = Bench::new(format!("grad/native/{cfg_name}")).run(|| {
            native.grad_into(0, black_box(&theta), 0, &mut out).unwrap();
            black_box(&out);
        });
        let mut reference = problem.reference_pool();
        let refr = Bench::new(format!("grad/native-reference/{cfg_name}")).run(|| {
            reference.grad_into(0, black_box(&theta), 0, &mut out).unwrap();
            black_box(&out);
        });
        if cfg_name.starts_with("default") {
            fused_default_mean = fused.mean;
            reference_default_mean = refr.mean;
        }
        // l = 256 sits at WIDE_L_THRESHOLD, so the native pool runs the
        // column-blocked kernel here — this cell is the blocked headline.
        if cfg_name.starts_with("wide") {
            blocked_wide_mean = fused.mean;
            reference_wide_mean = refr.mean;
        }
        stages.push(fused);
        stages.push(refr);

        if let Ok(artifacts) = ArtifactSet::discover() {
            let engine = Engine::cpu().unwrap();
            let mut xla_pool = XlaKrrPool::new(
                &artifacts,
                &engine,
                &spec.config,
                &problem.shards,
                spec.lambda as f32,
            )
            .unwrap();
            stages.push(Bench::new(format!("grad/xla/{cfg_name}")).run(|| {
                xla_pool.grad_into(0, black_box(&theta), 0, &mut out).unwrap();
                black_box(&out);
            }));
        }
    }

    // --- aggregation ----------------------------------------------------
    for &(k, dim) in &[(12usize, 64usize), (24, 64), (12, 4096)] {
        let grads: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                let mut g = vec![0.0f32; dim];
                rng.fill_normal(&mut g, 0.0, 1.0);
                g
            })
            .collect();
        let contribs: Vec<Contribution<'_>> = grads
            .iter()
            .map(|g| Contribution::whole(g, 256, 0))
            .collect();
        let mut out = vec![0.0f32; dim];
        stages.push(Bench::new(format!("aggregate/mean/k={k},dim={dim}")).run(|| {
            black_box(aggregate(AggregatorKind::Mean, black_box(&contribs), &mut out));
        }));
    }

    // --- optimizer steps --------------------------------------------------
    let dim = 4096;
    let mut theta = vec![0.0f32; dim];
    let mut grad = vec![0.0f32; dim];
    rng.fill_normal(&mut grad, 0.0, 1.0);
    for kind in [
        OptimizerKind::sgd(0.1),
        OptimizerKind::Adam { eta: 0.1, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        OptimizerKind::Lbfgs { eta: 0.1, history: 10 },
    ] {
        let mut opt = kind.build();
        let mut it = 0u64;
        stages.push(Bench::new(format!("optim/{}/dim={dim}", kind.name())).run(|| {
            opt.step(black_box(&mut theta), black_box(&grad), it);
            it += 1;
        }));
    }

    // --- barrier bookkeeping ---------------------------------------------
    stages.push(Bench::new("barrier/offer x32").run(|| {
        let mut b = PartialBarrier::new(0, 32, 24);
        for w in 0..32 {
            black_box(b.offer(w, 0));
        }
    }));

    // --- one whole virtual iteration (native, M=16) -----------------------
    let spec = KrrProblemSpec::small().with_machines(16);
    let problem = KrrProblem::generate(&spec).unwrap();
    let cluster = ClusterSpec { workers: 16, ..ClusterSpec::default() };
    stages.push(Bench::new("sim/whole-run-100-iters/M=16,small").run(|| {
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma: 12 },
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(100);
        let mut pool = problem.native_pool();
        black_box(sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap());
    }));

    // --- machine-readable trajectory point --------------------------------
    let fused_speedup = reference_default_mean / fused_default_mean;
    let blocked_speedup = reference_wide_mean / blocked_wide_mean;
    let rows: Vec<String> = stages.iter().map(json_stage).collect();
    let json = format!(
        "{{\n  \"bench\": \"micro_hotpath\",\n  \"headline\": {{\n    \
         \"grad_native_default_mean_s\": {fused_default_mean:.9e},\n    \
         \"grad_native_default_reference_mean_s\": {reference_default_mean:.9e},\n    \
         \"fused_speedup\": {fused_speedup:.3},\n    \
         \"grad_native_wide_blocked_mean_s\": {blocked_wide_mean:.9e},\n    \
         \"grad_native_wide_reference_mean_s\": {reference_wide_mean:.9e},\n    \
         \"wide_blocked_speedup\": {blocked_speedup:.3}\n  }},\n  \"stages\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/BENCH_micro_hotpath.json", json).unwrap();
    println!(
        "\nheadline: grad/native default config fused {:.2}us vs reference {:.2}us (x{:.2})",
        fused_default_mean * 1e6,
        reference_default_mean * 1e6,
        fused_speedup
    );
    println!(
        "headline: grad/native wide config blocked {:.2}us vs reference {:.2}us (x{:.2})",
        blocked_wide_mean * 1e6,
        reference_wide_mean * 1e6,
        blocked_speedup
    );
    println!("trajectory point -> results/BENCH_micro_hotpath.json");
}
