"""Build-time compile package: L1 pallas kernels + L2 jax models + AOT."""
