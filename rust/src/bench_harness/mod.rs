//! Benchmark harness (criterion is not in the vendor set).
//!
//! Three layers:
//! * [`Bench`] — microbenchmark timing: warmup, fixed-duration sampling,
//!   mean/p50/p99 reporting (used by `micro_hotpath`);
//! * [`Table`] — aligned experiment-table printing + CSV mirror, used by
//!   every T*/F* bench to emit the rows the paper's tables/figures would
//!   hold;
//! * [`sweep::SweepEngine`] — parallel sweep-point runner with a problem
//!   cache and deterministic result ordering (used by every T*/F* bench's
//!   outer grid).

pub mod sweep;

use std::time::{Duration, Instant};

use crate::math::stats::Summary;

/// Microbenchmark runner.
pub struct Bench {
    name: String,
    warmup: Duration,
    measure: Duration,
    min_samples: usize,
}

/// One microbenchmark's result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub throughput_hz: f64,
}

impl Bench {
    pub fn new(name: impl Into<String>) -> Bench {
        Bench {
            name: name.into(),
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_samples: 10,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    /// Time `f` repeatedly; returns timing summary.
    pub fn run<F: FnMut()>(&self, mut f: F) -> BenchResult {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Measure.
        let mut samples = Vec::new();
        let m0 = Instant::now();
        while m0.elapsed() < self.measure || samples.len() < self.min_samples {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
            if samples.len() > 2_000_000 {
                break;
            }
        }
        let s = Summary::of(&samples);
        let res = BenchResult {
            name: self.name.clone(),
            samples: samples.len(),
            mean: s.mean,
            p50: s.p50,
            p99: s.p99,
            throughput_hz: if s.mean > 0.0 { 1.0 / s.mean } else { f64::INFINITY },
        };
        println!(
            "{:40} {:>8} samples  mean {:>10}  p50 {:>10}  p99 {:>10}  ({:.1}/s)",
            res.name,
            res.samples,
            crate::util::fmt_secs(res.mean),
            crate::util::fmt_secs(res.p50),
            crate::util::fmt_secs(res.p99),
            res.throughput_hz
        );
        res
    }
}

/// Aligned experiment table: collects rows, prints, optionally mirrors to CSV.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Table {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Print with per-column alignment.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("\n=== {} ===", self.title);
        println!("{}", line(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Mirror to `results/<slug>.csv`.
    pub fn save_csv(&self, slug: &str) -> crate::Result<std::path::PathBuf> {
        let path = std::path::Path::new("results").join(format!("{slug}.csv"));
        let header: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        crate::metrics::csv::write_table(&header, &self.rows, &path)?;
        Ok(path)
    }
}

/// Format helper: fixed-precision float cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// Format helper: scientific float cell.
pub fn sci(v: f64) -> String {
    format!("{v:.3e}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_closure() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(5))
            .measure(Duration::from_millis(20))
            .run(|| {
                std::hint::black_box(1 + 1);
            });
        assert!(r.samples >= 10);
        assert!(r.mean >= 0.0);
        assert!(r.p99 >= r.p50);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        t.row(vec!["2".into(), "y".into()]);
        t.print();
        assert_eq!(t.rows().len(), 2);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert!(sci(1234.5).contains('e'));
    }
}
