//! One link's personality and its per-message realization.

use crate::straggler::DelayModel;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// One direction of a link: its own latency distribution and loss rate.
/// Real networks are asymmetric — a worker behind a congested uplink can
/// receive `Work` broadcasts promptly while its `Grad` replies crawl — so
/// each direction of a [`LinkModel`] can carry its own `LinkDir` override.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkDir {
    /// One-way latency distribution (virtual seconds), sampled per message.
    pub latency: DelayModel,
    /// Probability each message in this direction is silently lost.
    pub drop_prob: f64,
}

impl LinkDir {
    pub fn ideal() -> LinkDir {
        LinkDir { latency: DelayModel::None, drop_prob: 0.0 }
    }

    fn validate(&self, name: &str) -> Result<()> {
        if !(0.0..1.0).contains(&self.drop_prob) {
            return Err(Error::Config(format!(
                "link {name} drop_prob must be in [0, 1), got {}",
                self.drop_prob
            )));
        }
        Ok(())
    }
}

/// A coordinator↔worker link's behaviour, split into independent up and
/// down directions.  The symmetric `latency`/`drop_prob` fields apply to
/// *both* directions (each direction still samples its own fate and
/// delay); the optional [`LinkModel::up`]/[`LinkModel::down`] overrides
/// give one direction its own personality — e.g. a slow, lossy uplink
/// under a fast, clean downlink.  Reordering is emergent: latency variance
/// lets a later-sent message overtake an earlier one, and duplication
/// delivers the extra `Grad` copy `dup_lag` seconds behind the primary.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    /// One-way network latency distribution (virtual seconds), sampled per
    /// message — both directions unless overridden.
    pub latency: DelayModel,
    /// Probability each message is silently lost — both directions unless
    /// overridden.
    pub drop_prob: f64,
    /// Probability a delivered `Grad` reply arrives twice.
    pub dup_prob: f64,
    /// How far behind the primary the duplicate copy arrives (seconds).
    pub dup_lag: f64,
    /// Uplink (worker → coordinator, the `Grad` direction) override.
    pub up: Option<LinkDir>,
    /// Downlink (coordinator → worker, the `Work` direction) override.
    pub down: Option<LinkDir>,
}

impl Default for LinkModel {
    fn default() -> Self {
        LinkModel::ideal()
    }
}

impl LinkModel {
    /// Perfect link: zero latency, no loss, no duplication.
    pub fn ideal() -> LinkModel {
        LinkModel {
            latency: DelayModel::None,
            drop_prob: 0.0,
            dup_prob: 0.0,
            dup_lag: 0.0,
            up: None,
            down: None,
        }
    }

    /// Zero-latency link that loses each message with probability `p`.
    pub fn lossy(p: f64) -> LinkModel {
        LinkModel { drop_prob: p, ..LinkModel::ideal() }
    }

    /// Fully asymmetric link from two explicit directions.
    pub fn asymmetric(up: LinkDir, down: LinkDir) -> LinkModel {
        LinkModel {
            up: Some(up),
            down: Some(down),
            ..LinkModel::ideal()
        }
    }

    /// Effective uplink parameters (`Grad` replies).
    pub fn up_dir(&self) -> (&DelayModel, f64) {
        match &self.up {
            Some(d) => (&d.latency, d.drop_prob),
            None => (&self.latency, self.drop_prob),
        }
    }

    /// Effective downlink parameters (`Work` broadcasts).
    pub fn down_dir(&self) -> (&DelayModel, f64) {
        match &self.down {
            Some(d) => (&d.latency, d.drop_prob),
            None => (&self.latency, self.drop_prob),
        }
    }

    /// Does this link perturb traffic at all?
    pub fn is_ideal(&self) -> bool {
        let (up_lat, up_drop) = self.up_dir();
        let (down_lat, down_drop) = self.down_dir();
        self.dup_prob == 0.0
            && up_drop == 0.0
            && down_drop == 0.0
            && *up_lat == DelayModel::None
            && *down_lat == DelayModel::None
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [("drop_prob", self.drop_prob), ("dup_prob", self.dup_prob)] {
            if !(0.0..1.0).contains(&p) {
                return Err(Error::Config(format!(
                    "link {name} must be in [0, 1), got {p}"
                )));
            }
        }
        if let Some(up) = &self.up {
            up.validate("up")?;
        }
        if let Some(down) = &self.down {
            down.validate("down")?;
        }
        if self.dup_lag < 0.0 {
            return Err(Error::Config(format!(
                "link dup_lag must be >= 0, got {}",
                self.dup_lag
            )));
        }
        Ok(())
    }

    /// Realize one roundtrip from a per-message RNG stream.  The sampling
    /// order is fixed (down fate, down delay, up fate, up delay, dup fate)
    /// so a given stream always yields the same realization; a symmetric
    /// link (no direction overrides) consumes the stream exactly as the
    /// pre-asymmetry model did.
    pub fn realize(&self, rng: &mut Pcg64) -> LinkRealization {
        if self.is_ideal() {
            return LinkRealization::ideal();
        }
        let (down_lat, down_drop) = self.down_dir();
        let (up_lat, up_drop) = self.up_dir();
        let down_dropped = rng.next_f64() < down_drop;
        let down_delay = down_lat.sample(rng);
        let up_dropped = rng.next_f64() < up_drop;
        let up_delay = up_lat.sample(rng);
        let up_duplicated = rng.next_f64() < self.dup_prob;
        LinkRealization {
            down_dropped,
            down_delay,
            up_dropped,
            up_delay,
            up_duplicated,
            dup_lag: self.dup_lag,
        }
    }
}

/// One worker-iteration roundtrip, fully realized: both directions' fates
/// and delays.  Produced by [`crate::net::NetSpec::realize`] as a pure
/// function of `(seed, worker, iteration)`, which is what lets the virtual
/// simulator and the threaded runtime agree on every message's fate
/// without sharing any state.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkRealization {
    /// The `Work` broadcast was lost (the worker never computes).
    pub down_dropped: bool,
    /// One-way latency of the `Work` broadcast.
    pub down_delay: f64,
    /// The `Grad` reply was lost in flight.
    pub up_dropped: bool,
    /// One-way latency of the `Grad` reply.
    pub up_delay: f64,
    /// The `Grad` reply arrives twice.
    pub up_duplicated: bool,
    /// Lag of the duplicate copy behind the primary.
    pub dup_lag: f64,
}

impl LinkRealization {
    pub fn ideal() -> LinkRealization {
        LinkRealization {
            down_dropped: false,
            down_delay: 0.0,
            up_dropped: false,
            up_delay: 0.0,
            up_duplicated: false,
            dup_lag: 0.0,
        }
    }

    /// Both directions dead — a scripted partition window.
    pub fn partitioned() -> LinkRealization {
        LinkRealization {
            down_dropped: true,
            up_dropped: true,
            ..LinkRealization::ideal()
        }
    }

    /// Does the roundtrip deliver a usable reply?
    pub fn delivers(&self) -> bool {
        !self.down_dropped && !self.up_dropped
    }

    /// Total injected network latency on a delivered roundtrip.
    pub fn roundtrip_delay(&self) -> f64 {
        self.down_delay + self.up_delay
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_link_never_perturbs() {
        let link = LinkModel::ideal();
        assert!(link.is_ideal());
        let mut rng = Pcg64::seeded(1);
        for _ in 0..100 {
            let r = link.realize(&mut rng);
            assert!(r.delivers());
            assert_eq!(r.roundtrip_delay(), 0.0);
            assert!(!r.up_duplicated);
        }
    }

    #[test]
    fn lossy_link_drops_at_roughly_its_rate() {
        let link = LinkModel::lossy(0.3);
        let mut rng = Pcg64::seeded(2);
        let n = 20_000;
        let dropped = (0..n)
            .filter(|_| link.realize(&mut rng).down_dropped)
            .count();
        let rate = dropped as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn directions_realize_independently() {
        let link = LinkModel::lossy(0.5);
        let mut rng = Pcg64::seeded(3);
        let mut down_only = 0;
        let mut up_only = 0;
        for _ in 0..5000 {
            let r = link.realize(&mut rng);
            if r.down_dropped && !r.up_dropped {
                down_only += 1;
            }
            if r.up_dropped && !r.down_dropped {
                up_only += 1;
            }
        }
        assert!(down_only > 500, "down_only={down_only}");
        assert!(up_only > 500, "up_only={up_only}");
    }

    #[test]
    fn asymmetric_up_direction_only() {
        // Slow, lossy uplink; ideal downlink: Work broadcasts always land
        // with zero delay, Grad replies pay latency and loss.
        let link = LinkModel {
            up: Some(LinkDir {
                latency: DelayModel::Constant { secs: 0.04 },
                drop_prob: 0.5,
            }),
            ..LinkModel::ideal()
        };
        assert!(!link.is_ideal());
        let mut rng = Pcg64::seeded(4);
        let mut up_drops = 0;
        for _ in 0..2000 {
            let r = link.realize(&mut rng);
            assert!(!r.down_dropped, "ideal downlink dropped");
            assert_eq!(r.down_delay, 0.0);
            if r.up_dropped {
                up_drops += 1;
            } else {
                assert!((r.up_delay - 0.04).abs() < 1e-12);
            }
        }
        assert!(up_drops > 500, "up_drops={up_drops}");
    }

    #[test]
    fn asymmetric_builder_and_accessors() {
        let up = LinkDir { latency: DelayModel::Constant { secs: 0.02 }, drop_prob: 0.1 };
        let down = LinkDir::ideal();
        let link = LinkModel::asymmetric(up.clone(), down);
        let (lat, drop) = link.up_dir();
        assert_eq!(*lat, DelayModel::Constant { secs: 0.02 });
        assert_eq!(drop, 0.1);
        let (lat, drop) = link.down_dir();
        assert_eq!(*lat, DelayModel::None);
        assert_eq!(drop, 0.0);
        // Symmetric fields fall through when no override is present.
        let sym = LinkModel::lossy(0.25);
        assert_eq!(sym.up_dir().1, 0.25);
        assert_eq!(sym.down_dir().1, 0.25);
    }

    #[test]
    fn symmetric_link_realizes_identically_to_explicit_dirs() {
        // A link with both directions overridden by copies of the symmetric
        // parameters must consume the RNG stream identically.
        let base = LinkModel {
            latency: DelayModel::Uniform { lo: 0.001, hi: 0.003 },
            drop_prob: 0.2,
            dup_prob: 0.1,
            dup_lag: 0.001,
            ..LinkModel::ideal()
        };
        let explicit = LinkModel {
            up: Some(LinkDir {
                latency: DelayModel::Uniform { lo: 0.001, hi: 0.003 },
                drop_prob: 0.2,
            }),
            down: Some(LinkDir {
                latency: DelayModel::Uniform { lo: 0.001, hi: 0.003 },
                drop_prob: 0.2,
            }),
            ..base.clone()
        };
        let mut r1 = Pcg64::seeded(9);
        let mut r2 = Pcg64::seeded(9);
        for _ in 0..256 {
            assert_eq!(base.realize(&mut r1), explicit.realize(&mut r2));
        }
    }

    #[test]
    fn validate_rejects_bad_probabilities() {
        assert!(LinkModel::lossy(1.0).validate().is_err());
        assert!(LinkModel::lossy(-0.1).validate().is_err());
        assert!(LinkModel { dup_prob: 2.0, ..LinkModel::ideal() }.validate().is_err());
        assert!(LinkModel { dup_lag: -1.0, ..LinkModel::ideal() }.validate().is_err());
        assert!(LinkModel::lossy(0.99).validate().is_ok());
        assert!(LinkModel::ideal().validate().is_ok());
        let bad_up = LinkModel {
            up: Some(LinkDir { latency: DelayModel::None, drop_prob: 1.5 }),
            ..LinkModel::ideal()
        };
        assert!(bad_up.validate().is_err());
        let ok_down = LinkModel {
            down: Some(LinkDir { latency: DelayModel::None, drop_prob: 0.5 }),
            ..LinkModel::ideal()
        };
        assert!(ok_down.validate().is_ok());
    }

    #[test]
    fn partitioned_realization_delivers_nothing() {
        let r = LinkRealization::partitioned();
        assert!(!r.delivers());
        assert!(r.down_dropped && r.up_dropped);
    }
}
