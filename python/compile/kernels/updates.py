"""L1 Pallas kernels: fused master-side parameter updates (Alg. 2 line 3).

The master's update ``theta <- theta - (eta/gamma) sum_j g_j`` and its
momentum/Adam generalizations are pure element-wise streams; each kernel
fuses the whole update into one VMEM pass so the parameter vector makes a
single HBM round-trip per iteration.

These back the ``master_update_*`` HLO artifacts used by the
"update-on-XLA" ablation (DESIGN.md §6); the rust default applies the same
formulas natively (`optim/`), and the python tests pin both paths to
``ref.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _block(l: int, want: int = 4096) -> int:
    bm = min(want, l)
    while l % bm != 0:
        bm -= 1
    return bm


def sgd_update(theta, grad, eta):
    """theta - eta * grad, eta a (1,1)-broadcast scalar."""
    (l,) = theta.shape
    bm = _block(l)

    def kernel(t_ref, g_ref, e_ref, o_ref):
        o_ref[...] = t_ref[...] - e_ref[...] * g_ref[...]

    out = pl.pallas_call(
        kernel,
        grid=(l // bm,),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, l), jnp.float32),
        interpret=True,
    )(
        theta.reshape(1, l),
        grad.reshape(1, l),
        jnp.asarray(eta, jnp.float32).reshape(1, 1),
    )
    return out.reshape(l)


def momentum_update(theta, vel, grad, eta, mu):
    """v <- mu v + g;  theta <- theta - eta v.  Returns (theta', v')."""
    (l,) = theta.shape
    bm = _block(l)

    def kernel(t_ref, v_ref, g_ref, e_ref, m_ref, ot_ref, ov_ref):
        v2 = m_ref[...] * v_ref[...] + g_ref[...]
        ov_ref[...] = v2
        ot_ref[...] = t_ref[...] - e_ref[...] * v2

    out_t, out_v = pl.pallas_call(
        kernel,
        grid=(l // bm,),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, l), jnp.float32),
            jax.ShapeDtypeStruct((1, l), jnp.float32),
        ],
        interpret=True,
    )(
        theta.reshape(1, l),
        vel.reshape(1, l),
        grad.reshape(1, l),
        jnp.asarray(eta, jnp.float32).reshape(1, 1),
        jnp.asarray(mu, jnp.float32).reshape(1, 1),
    )
    return out_t.reshape(l), out_v.reshape(l)


def adam_update(theta, m, v, grad, eta, beta1, beta2, eps, t):
    """Bias-corrected Adam step, fully fused.  Returns (theta', m', v')."""
    (l,) = theta.shape
    bm = _block(l)

    def kernel(t_ref, m_ref, v_ref, g_ref, s_ref, ot_ref, om_ref, ov_ref):
        # s_ref packs the five scalars [eta, beta1, beta2, eps, t].
        eta_ = s_ref[0, 0]
        b1 = s_ref[0, 1]
        b2 = s_ref[0, 2]
        eps_ = s_ref[0, 3]
        tt = s_ref[0, 4]
        g = g_ref[...]
        m2 = b1 * m_ref[...] + (1.0 - b1) * g
        v2 = b2 * v_ref[...] + (1.0 - b2) * g * g
        om_ref[...] = m2
        ov_ref[...] = v2
        mhat = m2 / (1.0 - b1**tt)
        vhat = v2 / (1.0 - b2**tt)
        ot_ref[...] = t_ref[...] - eta_ * mhat / (jnp.sqrt(vhat) + eps_)

    scalars = jnp.stack(
        [
            jnp.asarray(eta, jnp.float32),
            jnp.asarray(beta1, jnp.float32),
            jnp.asarray(beta2, jnp.float32),
            jnp.asarray(eps, jnp.float32),
            jnp.asarray(t, jnp.float32),
        ]
    ).reshape(1, 5)

    out_t, out_m, out_v = pl.pallas_call(
        kernel,
        grid=(l // bm,),
        in_specs=[
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, 5), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
            pl.BlockSpec((1, bm), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, l), jnp.float32),
            jax.ShapeDtypeStruct((1, l), jnp.float32),
            jax.ShapeDtypeStruct((1, l), jnp.float32),
        ],
        interpret=True,
    )(
        theta.reshape(1, l),
        m.reshape(1, l),
        v.reshape(1, l),
        grad.reshape(1, l),
        scalars,
    )
    return out_t.reshape(l), out_m.reshape(l), out_v.reshape(l)
