//! End-to-end training integration: the full three-layer stack (pallas
//! kernel → jax lowering → PJRT runtime → hybrid coordinator) trains real
//! problems.  Requires `make artifacts` (skips otherwise).

use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::{ComputePool, KrrProblem, KrrProblemSpec};
use hybriditer::lm::{init::init_params, LmPool};
use hybriditer::optim::OptimizerKind;
use hybriditer::runtime::{ArtifactSet, Engine};
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;
use hybriditer::worker::compute::XlaKrrPool;
use hybriditer::cluster::ClusterSpec;

fn artifacts_or_skip() -> Option<ArtifactSet> {
    match ArtifactSet::discover() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e}");
            None
        }
    }
}

fn krr_cfg(problem: &KrrProblem) -> RunConfig {
    RunConfig {
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(problem.spec.lambda),
        eval_every: 50,
        ..RunConfig::default()
    }
}

#[test]
fn hybrid_training_on_xla_backend_converges() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let spec = KrrProblemSpec::small().with_machines(6);
    let problem = KrrProblem::generate(&spec).unwrap();
    let engine = Engine::cpu().unwrap();
    let mut pool = XlaKrrPool::new(
        &artifacts,
        &engine,
        "small",
        &problem.shards,
        spec.lambda as f32,
    )
    .unwrap();

    let cluster = ClusterSpec {
        workers: 6,
        delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
        ..ClusterSpec::default()
    };
    let cfg = krr_cfg(&problem)
        .with_mode(SyncMode::Hybrid { gamma: 4 })
        .with_iters(250);
    let report = sim::run_virtual(&mut pool, &cluster, &cfg, &problem).unwrap();

    assert!(report.status.is_healthy(), "{:?}", report.status);
    assert!(report.total_abandoned > 0);
    let err = problem.theta_err(&report.theta);
    assert!(err < 0.1, "theta_err={err}");
    // The gap to the exact optimum must close substantially from θ=0.
    let first = report.recorder.rows().first().unwrap().loss;
    let last = report.final_loss();
    let gap0 = first - problem.loss_star;
    let gap1 = last - problem.loss_star;
    assert!(gap1 < gap0 * 0.1, "loss gap {gap0} -> {gap1}");
}

#[test]
fn xla_and_native_backends_agree_iteration_by_iteration() {
    // Same problem, same cluster randomness: both backends must produce the
    // same θ trajectory up to f32 kernel round-off.
    let Some(artifacts) = artifacts_or_skip() else { return };
    let spec = KrrProblemSpec::small().with_machines(4);
    let problem = KrrProblem::generate(&spec).unwrap();
    let cluster = ClusterSpec {
        workers: 4,
        delay: DelayModel::LogNormal { mu: -5.0, sigma: 0.8 },
        ..ClusterSpec::default()
    };
    let cfg = krr_cfg(&problem)
        .with_mode(SyncMode::Hybrid { gamma: 3 })
        .with_iters(40);

    let mut native = problem.native_pool();
    let rep_native = sim::run_virtual(&mut native, &cluster, &cfg, &NoEval).unwrap();

    let engine = Engine::cpu().unwrap();
    let mut xla_pool = XlaKrrPool::new(
        &artifacts,
        &engine,
        "small",
        &problem.shards,
        spec.lambda as f32,
    )
    .unwrap();
    let rep_xla = sim::run_virtual(&mut xla_pool, &cluster, &cfg, &NoEval).unwrap();

    // Same barrier decisions (same virtual clock) …
    assert_eq!(rep_native.total_abandoned, rep_xla.total_abandoned);
    assert_eq!(rep_native.total_time(), rep_xla.total_time());
    // … and numerically close parameters.
    let max_diff = rep_native
        .theta
        .iter()
        .zip(&rep_xla.theta)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-2, "theta diff {max_diff}");
}

#[test]
fn lm_pool_gradients_reduce_loss() {
    // Four data-parallel workers, hybrid γ=3, adam master: loss on the
    // synthetic bigram corpus must fall from ~ln(vocab) toward the floor.
    let Some(artifacts) = artifacts_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let mut pool = match LmPool::new(&artifacts, &engine, "lm_tiny", 4, 4, 99) {
        Ok(p) => p,
        Err(e) => panic!("lm_tiny artifact unusable: {e}"),
    };
    let init = init_params(pool.task(), 99);
    let uniform_loss = (pool.task().vocab as f64).ln();

    let cluster = ClusterSpec { workers: 4, ..ClusterSpec::default() };
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 3 },
        optimizer: OptimizerKind::Adam { eta: 3e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        loss_form: LossForm::plain(),
        eval_every: 0,
        init_theta: Some(init),
        ..RunConfig::default()
    }
    .with_iters(30);
    let report = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();

    assert!(report.status.is_healthy());
    let first = report.recorder.rows().first().unwrap().loss;
    let last = report.final_loss();
    assert!(
        (first - uniform_loss).abs() < 0.7,
        "init loss {first} should be near ln(V)={uniform_loss}"
    );
    assert!(last < first - 0.3, "LM loss {first} -> {last} did not drop");
    assert!(last > pool.loss_floor() - 0.05, "below entropy floor?!");
}

#[test]
fn lm_grad_shapes_roundtrip() {
    let Some(artifacts) = artifacts_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let mut pool = LmPool::new(&artifacts, &engine, "lm_tiny", 2, 4, 1).unwrap();
    let dim = pool.dim();
    let theta = init_params(pool.task(), 1);
    assert_eq!(theta.len(), dim);
    let g = pool.grad(0, &theta, 0).unwrap();
    assert_eq!(g.grad.len(), dim);
    assert!(g.loss_sum.unwrap() > 0.0);
    assert_eq!(g.examples, pool.task().tokens_per_batch());
    // Different workers draw different batches → different grads.
    let g2 = pool.grad(1, &theta, 0).unwrap();
    assert_ne!(g.grad, g2.grad);
}
