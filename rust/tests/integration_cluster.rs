//! Threaded ("real" timing) runtime integration: worker threads, channels,
//! wall-clock barriers, fault injection.  Native backend keeps these fast;
//! the XLA-threaded path is covered separately (spawns M PJRT clients).

use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{Coordinator, LossForm, RunConfig, RunStatus, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::NoEval;
use hybriditer::straggler::{DelayModel, FailureModel};
use hybriditer::worker::NativeKrrFactory;

fn problem(machines: usize) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "test".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 64,
        seed: 5,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn cfg(p: &KrrProblem) -> RunConfig {
    RunConfig {
        optimizer: OptimizerKind::sgd(1.0),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        ..RunConfig::default()
    }
}

#[test]
fn real_bsp_trains() {
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0, // no injected sleeps: fast test
        ..ClusterSpec::default()
    };
    let run_cfg = cfg(&p).with_mode(SyncMode::Bsp).with_iters(150);
    let coord = Coordinator::new(cluster, run_cfg).unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert!(p.theta_err(&rep.theta) < 0.1);
}

#[test]
fn real_hybrid_abandons_stragglers_and_wins_wallclock() {
    let p = problem(6);
    // Everyone sleeps ~1ms; one chronically slow node sleeps ~10ms.  The
    // hybrid run must outlast the slow node's first few results so the
    // stale-arrival accounting is exercised.
    let make_cluster = || {
        ClusterSpec {
            workers: 6,
            base_compute: 0.001,
            delay: DelayModel::Constant { secs: 0.001 },
            ..ClusterSpec::default()
        }
        .with_slow_tail(1, 10.0)
    };
    let iters = 60;

    let factory = NativeKrrFactory::for_problem(&p);
    let bsp = Coordinator::new(make_cluster(), cfg(&p).with_mode(SyncMode::Bsp).with_iters(iters))
        .unwrap()
        .run_real(&factory, &NoEval)
        .unwrap();
    let hyb = Coordinator::new(
        make_cluster(),
        cfg(&p).with_mode(SyncMode::Hybrid { gamma: 5 }).with_iters(iters),
    )
    .unwrap()
    .run_real(&factory, &NoEval)
    .unwrap();

    assert!(hyb.status.is_healthy());
    assert!(hyb.total_abandoned > 0, "slow node never abandoned");
    assert!(
        hyb.driver_secs < bsp.driver_secs * 0.6,
        "hybrid {:.3}s vs bsp {:.3}s wall-clock",
        hyb.driver_secs,
        bsp.driver_secs
    );
}

#[test]
fn real_hybrid_survives_worker_crash() {
    let p = problem(6);
    // Only workers 4 and 5 are crash-prone: they die early with near
    // certainty, the other four keep the γ=3 barrier satisfiable forever.
    let cluster = ClusterSpec {
        workers: 6,
        base_compute: 0.0,
        failure: FailureModel {
            crash_prob: 0.1,
            transient_prob: 0.0,
            rejoin_after: None,
        },
        failure_only: vec![4, 5],
        seed: 21,
        ..ClusterSpec::default()
    };
    let coord = Coordinator::new(
        cluster,
        cfg(&p).with_mode(SyncMode::Hybrid { gamma: 3 }).with_iters(200),
    )
    .unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert!(rep.crashes > 0, "no crash injected");
}

#[test]
fn real_scheduled_join_respawns_crashed_thread() {
    // Supervisor-style respawn: a thread that simulated a stochastic crash
    // stops serving, so a later *scheduled* join spawns a replacement slave
    // on a fresh channel and re-admits the worker — the historical behavior
    // was to veto the join.  Worker 3 crashes with certainty at iteration 0;
    // the schedule joins it at 6, where the respawned thread (crash_prob
    // still 1.0) promptly crashes again on its first Work.
    use hybriditer::cluster::ElasticSchedule;
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0,
        failure: FailureModel {
            crash_prob: 1.0,
            transient_prob: 0.0,
            rejoin_after: None,
        },
        failure_only: vec![3],
        ..ClusterSpec::default()
    }
    .with_elastic(ElasticSchedule::parse("3:join@6").unwrap(), 1);
    let coord = Coordinator::new(
        cluster,
        cfg(&p).with_mode(SyncMode::Hybrid { gamma: 2 }).with_iters(12),
    )
    .unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert_eq!(rep.rejoins, 1, "scheduled join did not respawn the thread");
    assert_eq!(rep.crashes, 2, "replacement thread should crash again");
    // Default policy is abandon: the respawn is pure supervision, no
    // recovery action fires.
    assert_eq!(rep.recoveries, 0);
    for row in rep.recorder.rows() {
        if row.iter >= 7 {
            assert_eq!(row.alive, 3, "iter {}: dead worker counted alive", row.iter);
        }
    }
}

#[test]
fn real_lossy_net_keeps_training() {
    // 15% message loss + duplication on real threads: the run must stay
    // healthy, report network accounting, and still learn.
    use hybriditer::net::{LinkModel, NetSpec};
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0,
        delay: DelayModel::Constant { secs: 0.001 },
        ..ClusterSpec::default()
    }
    .with_net(NetSpec {
        default_link: LinkModel {
            drop_prob: 0.15,
            dup_prob: 0.15,
            dup_lag: 0.0002,
            ..LinkModel::ideal()
        },
        ..NetSpec::ideal()
    });
    let coord = Coordinator::new(
        cluster,
        cfg(&p).with_mode(SyncMode::Hybrid { gamma: 2 }).with_iters(150),
    )
    .unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert!(rep.net.dropped > 0, "{:?}", rep.net);
    assert_eq!(rep.net.sent, rep.net.delivered + rep.net.dropped);
    assert!(p.theta_err(&rep.theta) < 0.2, "err={}", p.theta_err(&rep.theta));
}

#[test]
fn real_bsp_stall_detection_on_crash() {
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0,
        failure: FailureModel {
            crash_prob: 0.05,
            transient_prob: 0.0,
            rejoin_after: None,
        },
        seed: 3,
        ..ClusterSpec::default()
    };
    let mut c = cfg(&p).with_mode(SyncMode::Bsp).with_iters(500);
    c.bsp_recovery = hybriditer::coordinator::BspRecovery::Stall;
    let coord = Coordinator::new(cluster, c).unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(
        matches!(rep.status, RunStatus::Stalled { .. }),
        "{:?}",
        rep.status
    );
}

#[test]
fn real_async_trains() {
    let p = problem(4);
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.0,
        delay: DelayModel::Uniform { lo: 0.0, hi: 0.001 },
        ..ClusterSpec::default()
    };
    let mut c = cfg(&p).with_mode(SyncMode::Async { damping: 0.0 });
    c.optimizer = OptimizerKind::sgd(0.3);
    c = c.with_iters(600); // updates
    let coord = Coordinator::new(cluster, c).unwrap();
    let factory = NativeKrrFactory::for_problem(&p);
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert!(rep.mean_staleness.is_some());
    assert!(p.theta_err(&rep.theta) < 0.2, "err={}", p.theta_err(&rep.theta));
}

#[test]
fn real_xla_threaded_smoke() {
    // Each worker thread builds its own PJRT client; 3 workers, few iters.
    let Some(artifacts) = hybriditer::runtime::ArtifactSet::discover().ok() else {
        eprintln!("SKIP (no artifacts)");
        return;
    };
    let spec = KrrProblemSpec::small().with_machines(3);
    let p = KrrProblem::generate(&spec).unwrap();
    let cluster = ClusterSpec {
        workers: 3,
        base_compute: 0.0,
        ..ClusterSpec::default()
    };
    let coord = Coordinator::new(
        cluster,
        cfg(&p).with_mode(SyncMode::Hybrid { gamma: 2 }).with_iters(10),
    )
    .unwrap();
    let factory = hybriditer::worker::XlaKrrFactory::new(
        &artifacts,
        "small",
        p.shards.clone(),
        p.spec.lambda as f32,
    )
    .unwrap();
    let rep = coord.run_real(&factory, &NoEval).unwrap();
    assert!(rep.status.is_healthy(), "{:?}", rep.status);
    assert_eq!(rep.recorder.len(), 10);
}
