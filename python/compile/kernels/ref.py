"""Pure-jnp oracles for every L1 pallas kernel.

These are the correctness ground truth: no pallas, no tiling, just the
textbook formulas.  ``python/tests`` asserts each kernel against its oracle
across shapes/dtypes/seeds (hypothesis sweeps), and the oracles are also
lowered to HLO as ``*_ref`` artifacts so the rust integration tests can
cross-check the kernel artifacts end to end.
"""

from __future__ import annotations

import jax.numpy as jnp


def krr_grad(theta, phi, y, lam):
    """(1/zeta) phi^T (phi theta - y) + lam theta  — Alg. 3 body."""
    zeta = phi.shape[0]
    r = phi @ theta - y
    return phi.T @ r / zeta + lam * theta


def krr_loss(theta, phi, y, lam):
    """(1/(2 zeta)) sum (phi theta - y)^2 + (lam/2) ||theta||^2."""
    zeta = phi.shape[0]
    r = phi @ theta - y
    return 0.5 * jnp.sum(r * r) / zeta + 0.5 * lam * jnp.sum(theta * theta)


def krr_sumsq(theta, phi, y):
    """sum (phi theta - y)^2 (the kernel's raw accumulator)."""
    r = phi @ theta - y
    return jnp.sum(r * r)


def rbf_features(x, w, b):
    """Random Fourier features: cos(x @ w + b) * sqrt(2/l)."""
    l = w.shape[1]
    return jnp.cos(x @ w + b) * jnp.sqrt(2.0 / l)


def sgd_update(theta, grad, eta):
    return theta - eta * grad


def momentum_update(theta, vel, grad, eta, mu):
    v = mu * vel + grad
    return theta - eta * v, v


def adam_update(theta, m, v, grad, eta, beta1, beta2, eps, t):
    m2 = beta1 * m + (1.0 - beta1) * grad
    v2 = beta2 * v + (1.0 - beta2) * grad * grad
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    return theta - eta * mhat / (jnp.sqrt(vhat) + eps), m2, v2
