//! Virtual-time delivery for the discrete-event simulator.
//!
//! Message fates realized here are pure in `(seed, worker, iter)`, which is
//! what lets the flight recorder ([`crate::trace`]) re-realize them at
//! dispatch time without consuming any RNG state: the journaled fate
//! sequence is identical to what the transport actually delivers
//! (`trace::tests::roundtrip_fates_match_transport` pins this down).

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use super::block::BlockSet;
use super::link::LinkRealization;
use super::spec::NetSpec;
use super::NetStats;

/// One message popping out of a [`Transport`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Delivery {
    /// Virtual arrival time, relative to the same origin the sends used
    /// (the lockstep sync driver measures from the iteration start).
    pub at: f64,
    pub worker: usize,
    pub iter: u64,
    /// True for the extra copy of a duplicated reply.
    pub duplicate: bool,
    /// Which gradient blocks this reply carries.  `BlockSet::full(1)`
    /// whenever block admission is off — the legacy whole-reply model.
    pub blocks: BlockSet,
}

/// Virtual-time message routing: sends schedule delivery events, polls pop
/// them in arrival order.  The lockstep sync driver routes one roundtrip
/// per responder per iteration: the `Work` broadcast at relative time 0,
/// `compute` seconds of worker time, and the `Grad` reply back; the
/// network realization decides what survives and when it lands.
pub trait Transport {
    /// Route one coordinator→worker→coordinator roundtrip for `iter`.
    /// Surviving deliveries become [`Transport::poll`]-able events.
    fn send_roundtrip(&mut self, worker: usize, iter: u64, compute: f64);
    /// Pop the next delivery in ascending `(time, worker, duplicate)`
    /// order, or `None` when everything in flight has been delivered.
    fn poll(&mut self) -> Option<Delivery>;
    /// Distinct workers with a pending primary (non-duplicate) delivery.
    fn deliverable(&self) -> usize;
    /// Message-level accounting so far.
    fn stats(&self) -> NetStats;
}

/// Heap key ordered by `(time, worker, duplicate)`.  Latencies are finite
/// (the spec validates its distributions produce non-NaN samples), so the
/// `partial_cmp` fallback to `Equal` is never load-bearing.
#[derive(PartialEq)]
struct Key {
    at: f64,
    worker: usize,
    duplicate: bool,
    iter: u64,
}

impl Eq for Key {}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.at
            .partial_cmp(&other.at)
            .unwrap_or(Ordering::Equal)
            .then(self.worker.cmp(&other.worker))
            .then(self.duplicate.cmp(&other.duplicate))
    }
}

/// The simulator's [`Transport`]: realizes every message's fate from the
/// pure [`NetSpec::realize`] function and keeps surviving deliveries on a
/// min-heap.  With an ideal spec the fast path schedules delivery exactly
/// at `compute` with no sampling — the pre-transport timing model, bit for
/// bit.
pub struct VirtualTransport {
    spec: NetSpec,
    seed: u64,
    ideal: bool,
    n_blocks: usize,
    heap: BinaryHeap<Reverse<Key>>,
    primaries: usize,
    stats: NetStats,
}

impl VirtualTransport {
    pub fn new(spec: NetSpec, seed: u64) -> VirtualTransport {
        let ideal = spec.is_ideal();
        VirtualTransport {
            spec,
            seed,
            ideal,
            n_blocks: 1,
            heap: BinaryHeap::new(),
            primaries: 0,
            stats: NetStats::default(),
        }
    }

    /// Activate block admission: chunk every reply into `n` blocks (the
    /// driver computes `n` from the gradient dimension via
    /// [`NetSpec::n_blocks`]).  `n <= 1` keeps the legacy whole-reply
    /// model.
    pub fn set_block_count(&mut self, n: usize) {
        self.n_blocks = n.max(1);
    }

    pub fn is_ideal(&self) -> bool {
        self.ideal
    }

    /// The spec this transport realizes from — aggregation topologies
    /// ([`crate::agg`]) re-realize interior-edge fates purely from it.
    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The delivered block set of `(worker, iter, duplicate)`'s reply —
    /// pure re-realization, so drivers that queue deliveries as bare
    /// events can recover the mask at admission time.
    pub fn blocks_for(&self, worker: usize, iter: u64, duplicate: bool) -> BlockSet {
        if self.ideal || self.n_blocks <= 1 {
            return BlockSet::full(self.n_blocks);
        }
        let r = self.spec.realize(self.seed, worker, iter);
        self.spec
            .realize_blocks(self.seed, worker, iter, self.n_blocks, r.up_dropped, duplicate)
    }

    /// Realize (and account) BSP retry attempt `attempt` for worker
    /// `worker`'s iteration-`iter` recovery — the satellite fix that
    /// routes retransmissions through the link model instead of assuming
    /// a clean path.  Duplicates are not materialized for retries.
    pub fn realize_retry(&mut self, worker: usize, iter: u64, attempt: u64) -> LinkRealization {
        let r = self.spec.realize_attempt(self.seed, worker, iter, attempt);
        self.stats.count_roundtrip(&r, false);
        r
    }
}

impl Transport for VirtualTransport {
    fn send_roundtrip(&mut self, worker: usize, iter: u64, compute: f64) {
        if self.ideal {
            self.stats.sent += 2;
            self.stats.delivered += 2;
            if self.n_blocks > 1 {
                self.stats.count_blocks_ideal(self.n_blocks);
            }
            self.heap.push(Reverse(Key { at: compute, worker, duplicate: false, iter }));
            self.primaries += 1;
            return;
        }
        let r = self.spec.realize(self.seed, worker, iter);
        let surfaced = if self.n_blocks <= 1 {
            self.stats.count_roundtrip(&r, true)
        } else {
            let blocks = self.spec.realize_blocks(
                self.seed,
                worker,
                iter,
                self.n_blocks,
                r.up_dropped,
                false,
            );
            self.stats
                .count_roundtrip_blocks(&r, blocks, self.spec.admits(blocks), true)
        };
        if !surfaced {
            return;
        }
        let at = r.down_delay + compute + r.up_delay;
        self.heap.push(Reverse(Key { at, worker, duplicate: false, iter }));
        self.primaries += 1;
        if r.up_duplicated {
            self.heap.push(Reverse(Key { at: at + r.dup_lag, worker, duplicate: true, iter }));
        }
    }

    fn poll(&mut self) -> Option<Delivery> {
        match self.heap.pop() {
            None => None,
            Some(Reverse(k)) => {
                if !k.duplicate {
                    self.primaries -= 1;
                }
                let blocks = self.blocks_for(k.worker, k.iter, k.duplicate);
                Some(Delivery {
                    at: k.at,
                    worker: k.worker,
                    iter: k.iter,
                    duplicate: k.duplicate,
                    blocks,
                })
            }
        }
    }

    fn deliverable(&self) -> usize {
        self.primaries
    }

    fn stats(&self) -> NetStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::link::LinkModel;
    use crate::straggler::DelayModel;

    #[test]
    fn ideal_delivers_in_compute_order() {
        let mut t = VirtualTransport::new(NetSpec::ideal(), 1);
        t.send_roundtrip(0, 5, 0.03);
        t.send_roundtrip(1, 5, 0.01);
        t.send_roundtrip(2, 5, 0.02);
        assert_eq!(t.deliverable(), 3);
        let order: Vec<(usize, f64)> = std::iter::from_fn(|| t.poll())
            .map(|d| (d.worker, d.at))
            .collect();
        assert_eq!(order, vec![(1, 0.01), (2, 0.02), (0, 0.03)]);
        assert_eq!(t.deliverable(), 0);
        assert_eq!(t.stats().sent, 6);
        assert_eq!(t.stats().delivered, 6);
    }

    #[test]
    fn ties_break_by_worker_then_duplicate() {
        // dup_prob 1.0 would fail validate(), but realize() is the unit
        // under test here and `next_f64 < 1.0` always holds — so every
        // reply duplicates, deterministically.
        let spec = NetSpec {
            default_link: LinkModel { dup_prob: 1.0, dup_lag: 0.0, ..LinkModel::ideal() },
            ..NetSpec::ideal()
        };
        let mut t = VirtualTransport::new(spec, 3);
        t.send_roundtrip(1, 0, 0.01);
        t.send_roundtrip(0, 0, 0.01);
        let ds: Vec<Delivery> = std::iter::from_fn(|| t.poll()).collect();
        // Every primary precedes its own duplicate, and equal times order
        // by worker index.
        assert_eq!(ds.len(), 4);
        assert_eq!((ds[0].worker, ds[0].duplicate), (0, false));
        assert_eq!((ds[1].worker, ds[1].duplicate), (0, true));
        assert_eq!((ds[2].worker, ds[2].duplicate), (1, false));
        assert_eq!((ds[3].worker, ds[3].duplicate), (1, true));
        assert_eq!(t.stats().duplicated, 2);
    }

    #[test]
    fn drops_never_surface() {
        let mut t = VirtualTransport::new(NetSpec::lossy(0.5), 7);
        let n = 200u64;
        for iter in 0..n {
            t.send_roundtrip(0, iter, 0.01);
        }
        let popped = std::iter::from_fn(|| t.poll()).count() as u64;
        let s = t.stats();
        assert_eq!(s.sent, s.delivered + s.dropped);
        assert!(s.dropped > 0, "nothing dropped at 50%");
        assert!(popped < n, "popped {popped} of {n} at 50% loss");
        // Each popped event is a delivered Grad whose Work also got
        // through; Works may outnumber Grads (up-direction drops).
        assert!(s.delivered >= 2 * popped, "{s:?} vs {popped} pops");
    }

    #[test]
    fn net_delays_shift_arrivals() {
        let spec = NetSpec {
            default_link: LinkModel {
                latency: DelayModel::Constant { secs: 0.005 },
                ..LinkModel::ideal()
            },
            ..NetSpec::ideal()
        };
        let mut t = VirtualTransport::new(spec, 1);
        t.send_roundtrip(0, 0, 0.02);
        let d = t.poll().unwrap();
        assert!((d.at - 0.03).abs() < 1e-12, "at={}", d.at);
        assert!(t.poll().is_none());
    }

    #[test]
    fn single_block_count_reproduces_legacy_schedule() {
        // block_size large enough that the gradient is one block: the
        // transport must schedule, count, and deliver exactly as the
        // pre-block model — under a lossy spec, not just an ideal one.
        let spec = NetSpec { block_size: 1024, ..NetSpec::lossy(0.3) };
        let run = |blocked: bool| {
            let mut t = VirtualTransport::new(spec.clone(), 11);
            if blocked {
                t.set_block_count(spec.n_blocks(16)); // 16 ≤ 1024 → 1 block
            }
            for iter in 0..50 {
                for w in 0..4 {
                    t.send_roundtrip(w, iter, 0.01 * (w + 1) as f64);
                }
            }
            let ds: Vec<(f64, usize, u64, bool)> = std::iter::from_fn(|| t.poll())
                .map(|d| (d.at, d.worker, d.iter, d.duplicate))
                .collect();
            (ds, t.stats())
        };
        let (d1, s1) = run(false);
        let (d2, s2) = run(true);
        assert_eq!(d1, d2);
        assert_eq!(s1, s2);
        assert_eq!(s2.blocks_sent, 0, "single-block replies must not count block stats");
    }

    #[test]
    fn blocked_replies_surface_partial_sets() {
        let spec = NetSpec { block_size: 2, ..NetSpec::lossy(0.3) };
        let n = spec.n_blocks(16);
        assert_eq!(n, 8);
        let mut t = VirtualTransport::new(spec.clone(), 19);
        for iter in 0..200u64 {
            for w in 0..4 {
                t.send_roundtrip(w, iter, 0.01);
            }
        }
        let mut partial = 0usize;
        let mut rescued = 0usize;
        let mut popped = 0usize;
        while let Some(d) = t.poll() {
            popped += 1;
            assert_eq!(d.blocks.len(), n);
            assert!(!d.blocks.is_empty(), "empty replies must never surface");
            // The mask is recoverable purely.
            assert_eq!(d.blocks, t.blocks_for(d.worker, d.iter, d.duplicate));
            if !d.blocks.is_full() {
                partial += 1;
            }
            if !d.duplicate && !d.blocks.contains(0) {
                rescued += 1; // legacy model would have dropped this reply
            }
        }
        assert!(popped > 0);
        assert!(partial > 0, "30% loss never produced a partial reply");
        assert!(rescued > 0, "no reply survived on tail blocks alone");
        let s = t.stats();
        assert_eq!(s.sent, s.delivered + s.dropped);
        assert_eq!(s.blocks_sent, s.blocks_delivered + s.blocks_dropped);
        assert!(s.blocks_dropped > 0);
    }

    #[test]
    fn min_block_frac_suppresses_thin_replies() {
        let strict = NetSpec { block_size: 2, min_block_frac: 0.99, ..NetSpec::lossy(0.4) };
        let loose = NetSpec { min_block_frac: 0.0, ..strict.clone() };
        let run = |spec: &NetSpec| {
            let mut t = VirtualTransport::new(spec.clone(), 7);
            t.set_block_count(spec.n_blocks(16));
            for iter in 0..300u64 {
                t.send_roundtrip(0, iter, 0.01);
            }
            let popped = std::iter::from_fn(|| t.poll())
                .inspect(|d| assert!(spec.admits(d.blocks) || d.duplicate))
                .count();
            (popped, t.stats())
        };
        let (p_strict, s_strict) = run(&strict);
        let (p_loose, s_loose) = run(&loose);
        assert!(p_strict < p_loose, "threshold suppressed nothing: {p_strict} vs {p_loose}");
        // The physical block realization is policy-independent.
        assert_eq!(s_strict.blocks_delivered, s_loose.blocks_delivered);
        assert!(s_strict.dropped > s_loose.dropped);
    }

    #[test]
    fn retry_realizations_are_counted_and_pure() {
        let mut t = VirtualTransport::new(NetSpec::lossy(0.4), 5);
        let before = t.stats();
        let a = t.realize_retry(1, 10, 0);
        let b = t.realize_retry(1, 10, 0);
        assert_eq!(a, b);
        let s = t.stats();
        assert_eq!(s.sent - before.sent, if a.down_dropped { 2 } else { 4 });
        assert_eq!(s.sent, s.delivered + s.dropped);
    }

    #[test]
    fn deterministic_across_instances() {
        let mk = || {
            let mut t = VirtualTransport::new(NetSpec::lossy(0.3), 11);
            for iter in 0..50 {
                for w in 0..4 {
                    t.send_roundtrip(w, iter, 0.01 * (w + 1) as f64);
                }
            }
            let ds: Vec<Delivery> = std::iter::from_fn(|| t.poll()).collect();
            (ds, t.stats())
        };
        let (d1, s1) = mk();
        let (d2, s2) = mk();
        assert_eq!(d1, d2);
        assert_eq!(s1, s2);
    }
}
