//! Property tests for the unreliable-network transport layer:
//! realization determinism, transport-schedule determinism, delivery
//! accounting invariants, and [`PartialBarrier`] invariants under the
//! duplication/reordering a lossy [`LinkModel`] injects.

use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::barrier::{Admission, PartialBarrier};
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::net::{LinkModel, NetSpec, Transport, VirtualTransport};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;
use hybriditer::util::proptest::check;
use hybriditer::util::rng::Pcg64;

/// Draw a random (but valid) lossy spec from the case RNG.
fn draw_spec(rng: &mut Pcg64, workers: usize) -> NetSpec {
    let link = LinkModel {
        latency: if rng.next_f64() < 0.5 {
            DelayModel::None
        } else {
            DelayModel::Uniform { lo: 0.0, hi: 0.01 }
        },
        drop_prob: rng.uniform(0.0, 0.5),
        dup_prob: rng.uniform(0.0, 0.5),
        dup_lag: rng.uniform(0.0, 0.002),
        ..LinkModel::ideal()
    };
    let mut spec = NetSpec { default_link: link, ..NetSpec::ideal() };
    if rng.next_f64() < 0.3 {
        let w = rng.below(workers as u64) as usize;
        let from = rng.below(20);
        spec = spec.with_partition(&[w], from, from + 1 + rng.below(20));
    }
    spec
}

#[test]
fn prop_realize_is_a_pure_function() {
    check("realize_pure", 50, |rng| {
        let workers = 2 + rng.below(8) as usize;
        let spec = draw_spec(rng, workers);
        let seed = rng.next_u64();
        for w in 0..workers {
            for iter in 0..32u64 {
                let a = spec.realize(seed, w, iter);
                let b = spec.realize(seed, w, iter);
                if a != b {
                    return Err(format!("realize({seed}, {w}, {iter}) not pure: {a:?} vs {b:?}"));
                }
                if a.dup_lag < 0.0 || a.down_delay < 0.0 || a.up_delay < 0.0 {
                    return Err(format!("negative delay realized: {a:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transport_schedule_deterministic() {
    // Same seed + NetSpec ⇒ identical delivery order, times, and stats.
    check("transport_deterministic", 30, |rng| {
        let workers = 2 + rng.below(8) as usize;
        let spec = draw_spec(rng, workers);
        let seed = rng.next_u64();
        let computes: Vec<f64> = (0..workers).map(|_| rng.uniform(0.001, 0.05)).collect();
        let run = || {
            let mut t = VirtualTransport::new(spec.clone(), seed);
            let mut log = Vec::new();
            for iter in 0..40u64 {
                for w in 0..workers {
                    t.send_roundtrip(w, iter, computes[w]);
                }
                while let Some(d) = t.poll() {
                    log.push((d.at, d.worker, d.iter, d.duplicate));
                }
            }
            (log, t.stats())
        };
        let (l1, s1) = run();
        let (l2, s2) = run();
        if l1 != l2 {
            return Err("delivery schedules diverged for identical inputs".into());
        }
        if s1 != s2 {
            return Err(format!("stats diverged: {s1:?} vs {s2:?}"));
        }
        if s1.sent != s1.delivered + s1.dropped {
            return Err(format!("accounting invariant broken: {s1:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_deliveries_arrive_in_time_order_and_dups_follow_primaries() {
    check("poll_order", 30, |rng| {
        let workers = 2 + rng.below(8) as usize;
        let spec = draw_spec(rng, workers);
        let seed = rng.next_u64();
        let mut t = VirtualTransport::new(spec, seed);
        for iter in 0..40u64 {
            for w in 0..workers {
                t.send_roundtrip(w, iter, rng.uniform(0.001, 0.05));
            }
            let mut last = f64::NEG_INFINITY;
            let mut primary_seen = vec![false; workers];
            while let Some(d) = t.poll() {
                if d.at < last {
                    return Err(format!("arrival at {} after {}", d.at, last));
                }
                last = d.at;
                if d.duplicate {
                    if !primary_seen[d.worker] {
                        return Err(format!("dup for worker {} before its primary", d.worker));
                    }
                } else {
                    if primary_seen[d.worker] {
                        return Err(format!("two primaries for worker {}", d.worker));
                    }
                    primary_seen[d.worker] = true;
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_barrier_invariants_under_lossy_link() {
    // Feed the barrier exactly what a lossy, duplicating, reordering link
    // delivers; its invariants must hold regardless of the spec drawn.
    check("barrier_under_loss", 50, |rng| {
        let workers = 2 + rng.below(10) as usize;
        let gamma = 1 + rng.below(workers as u64) as usize;
        let spec = draw_spec(rng, workers);
        let seed = rng.next_u64();
        let mut t = VirtualTransport::new(spec, seed);
        for iter in 0..25u64 {
            for w in 0..workers {
                t.send_roundtrip(w, iter, rng.uniform(0.001, 0.05));
            }
            let deliverable = t.deliverable();
            if deliverable == 0 {
                continue;
            }
            let g_eff = gamma.min(deliverable);
            let mut barrier = PartialBarrier::new(iter, workers, g_eff);
            let mut included = vec![false; workers];
            let mut n_included = 0usize;
            while let Some(d) = t.poll() {
                match barrier.offer(d.worker, d.iter) {
                    Admission::Included | Admission::IncludedAndClosed => {
                        if d.duplicate {
                            return Err("duplicate copy admitted".into());
                        }
                        if included[d.worker] {
                            return Err(format!("worker {} admitted twice", d.worker));
                        }
                        if barrier.is_closed() && barrier.included() > g_eff {
                            return Err("barrier overfilled".into());
                        }
                        included[d.worker] = true;
                        n_included += 1;
                    }
                    Admission::Abandoned => {
                        if !barrier.is_closed() && !included[d.worker] && !d.duplicate {
                            return Err(format!(
                                "fresh primary from worker {} abandoned pre-close",
                                d.worker
                            ));
                        }
                    }
                    Admission::Stale => {
                        return Err("sync transport delivered a stale iteration".into());
                    }
                }
            }
            if n_included != g_eff {
                return Err(format!("included {n_included}, γ_eff {g_eff}"));
            }
            if !barrier.is_closed() {
                return Err("barrier never closed despite γ_eff deliveries".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sim_driver_deterministic_under_lossy_net() {
    // Same seed + NetSpec ⇒ bit-identical trajectory, counts, and stats
    // from the virtual driver.
    let spec = KrrProblemSpec {
        config: "propnet".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines: 6,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 64,
        seed: 23,
    };
    let p = KrrProblem::generate(&spec).unwrap();
    check("sim_lossy_deterministic", 6, |rng| {
        let net = draw_spec(rng, 6);
        let cluster = ClusterSpec {
            workers: 6,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        }
        .with_net(net);
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma: 4 },
            optimizer: OptimizerKind::sgd(0.8),
            loss_form: LossForm::krr(p.spec.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(60);
        let mut pool1 = p.native_pool();
        let r1 = sim::run_virtual(&mut pool1, &cluster, &cfg, &NoEval).unwrap();
        let mut pool2 = p.native_pool();
        let r2 = sim::run_virtual(&mut pool2, &cluster, &cfg, &NoEval).unwrap();
        if r1.theta != r2.theta {
            return Err("theta diverged across identical runs".into());
        }
        if r1.net != r2.net {
            return Err(format!("net stats diverged: {:?} vs {:?}", r1.net, r2.net));
        }
        if r1.total_abandoned != r2.total_abandoned
            || r1.total_contributions != r2.total_contributions
        {
            return Err("admission totals diverged".into());
        }
        Ok(())
    });
}

#[test]
fn prop_duplicate_block_sets_fold_each_block_at_most_once() {
    // Orphaned-block dedup guard: a duplicated reply is an independent
    // retransmission whose block set may overlap the primary's.  Folding
    // both through the ledger must claim each (worker, iter, block) at
    // most once — the dup only contributes blocks the primary lost, and
    // replaying either copy claims nothing further.
    use hybriditer::net::BlockLedger;
    check("block_dedup", 50, |rng| {
        let workers = 2 + rng.below(6) as usize;
        let link = LinkModel {
            drop_prob: rng.uniform(0.1, 0.6),
            dup_prob: 1.0,
            ..LinkModel::ideal()
        };
        let spec = NetSpec { default_link: link, ..NetSpec::ideal() };
        let seed = rng.next_u64();
        let n = 2 + rng.below(7) as usize;
        let mut ledger = BlockLedger::default();
        for iter in 0..20u64 {
            for w in 0..workers {
                let r = spec.realize(seed, w, iter);
                let primary = spec.realize_blocks(seed, w, iter, n, r.up_dropped, false);
                let dup = spec.realize_blocks(seed, w, iter, n, r.up_dropped, true);
                let got_primary = ledger.claim(w, iter, primary);
                let got_dup = ledger.claim(w, iter, dup);
                if got_primary.mask() != primary.mask() {
                    return Err(format!(
                        "w{w} iter {iter}: first claim mutated the primary set \
                         ({:#x} vs {:#x})",
                        got_primary.mask(),
                        primary.mask()
                    ));
                }
                if got_primary.mask() & got_dup.mask() != 0 {
                    return Err(format!(
                        "w{w} iter {iter}: block double-counted across copies \
                         (overlap {:#x})",
                        got_primary.mask() & got_dup.mask()
                    ));
                }
                if got_dup.mask() & !dup.mask() != 0 {
                    return Err(format!(
                        "w{w} iter {iter}: dup claim invented blocks it never \
                         delivered ({:#x} vs {:#x})",
                        got_dup.mask(),
                        dup.mask()
                    ));
                }
                if got_primary.mask() | got_dup.mask() != primary.mask() | dup.mask() {
                    return Err(format!("w{w} iter {iter}: delivered coverage lost"));
                }
                // Replays — a re-queued copy of either message — are inert.
                if !ledger.claim(w, iter, primary).is_empty()
                    || !ledger.claim(w, iter, dup).is_empty()
                {
                    return Err(format!("w{w} iter {iter}: replay claimed fresh blocks"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_empirical_drop_rate_tracks_spec() {
    // Over many roundtrips the observed message drop rate must track the
    // configured probability (loose 3σ-ish tolerance).
    for &p in &[0.05, 0.2, 0.4] {
        let mut t = VirtualTransport::new(NetSpec::lossy(p), 0xD0_5EED);
        for iter in 0..2000u64 {
            for w in 0..4 {
                t.send_roundtrip(w, iter, 0.01);
            }
            while t.poll().is_some() {}
        }
        let s = t.stats();
        let rate = s.drop_rate();
        assert!(
            (rate - p).abs() < 0.02,
            "configured {p}, observed {rate} ({s:?})"
        );
    }
}
