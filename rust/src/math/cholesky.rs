//! Dense Cholesky factorization/solve for the exact ridge solution.
//!
//! The T1/T2 experiments report `‖θ_t − θ*‖`; `θ*` solves the l×l system
//! `(Φ^T Φ / m + λ I) θ = Φ^T y / m` (eq. 2's normal equations).  `l` is at
//! most a few hundred, so an O(l³) dense factorization is instant.

use crate::{Error, Result};

/// Lower-triangular Cholesky factor of an SPD matrix (row-major, n×n, f64).
pub struct CholeskyFactor {
    l: Vec<f64>,
    n: usize,
}

impl CholeskyFactor {
    /// Factor `a` (row-major n×n, symmetric positive definite).
    pub fn new(a: &[f64], n: usize) -> Result<CholeskyFactor> {
        if a.len() != n * n {
            return Err(Error::Shape(format!(
                "cholesky: expected {}x{} = {} elements, got {}",
                n,
                n,
                n * n,
                a.len()
            )));
        }
        let mut l = vec![0.0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[i * n + j];
                for k in 0..j {
                    s -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if s <= 0.0 {
                        return Err(Error::other(format!(
                            "cholesky: matrix not positive definite at pivot {i} (s={s})"
                        )));
                    }
                    l[i * n + j] = s.sqrt();
                } else {
                    l[i * n + j] = s / l[j * n + j];
                }
            }
        }
        Ok(CholeskyFactor { l, n })
    }

    /// Solve `A x = b` via forward+back substitution.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(Error::Shape(format!(
                "cholesky solve: rhs has {} elements, want {}",
                b.len(),
                self.n
            )));
        }
        let n = self.n;
        // L z = b
        let mut z = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[i * n + k] * z[k];
            }
            z[i] = s / self.l[i * n + i];
        }
        // L^T x = z
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = z[i];
            for k in (i + 1)..n {
                s -= self.l[k * n + i] * x[k];
            }
            x[i] = s / self.l[i * n + i];
        }
        Ok(x)
    }
}

/// One-shot solve of an SPD system.
pub fn cholesky_solve(a: &[f64], n: usize, b: &[f64]) -> Result<Vec<f64>> {
    CholeskyFactor::new(a, n)?.solve(b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn solves_small_system() {
        // A = [[4,2],[2,3]], b = [2,1] -> x = [0.5, 0]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let x = cholesky_solve(&a, 2, &[2.0, 1.0]).unwrap();
        assert!((x[0] - 0.5).abs() < 1e-12);
        assert!(x[1].abs() < 1e-12);
    }

    #[test]
    fn identity_solve_is_identity() {
        let n = 5;
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            a[i * n + i] = 1.0;
        }
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let x = cholesky_solve(&a, n, &b).unwrap();
        for (xi, bi) in x.iter().zip(&b) {
            assert!((xi - bi).abs() < 1e-14);
        }
    }

    #[test]
    fn random_spd_roundtrip() {
        let mut rng = Pcg64::seeded(3);
        let n = 32;
        // A = B B^T + n*I is SPD.
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[i * n + k] * b[j * n + k];
                }
                a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut rhs = vec![0.0; n];
        for i in 0..n {
            rhs[i] = (0..n).map(|j| a[i * n + j] * x_true[j]).sum();
        }
        let x = cholesky_solve(&a, n, &rhs).unwrap();
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-8, "{got} vs {want}");
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(CholeskyFactor::new(&a, 2).is_err());
    }

    #[test]
    fn rejects_bad_shape() {
        assert!(CholeskyFactor::new(&[1.0, 2.0, 3.0], 2).is_err());
    }
}
