//! Convergence detection (the master's `IsConvergence` of Algorithm 2).

/// Stop rules checked after every iteration.
#[derive(Clone, Debug)]
pub struct StopRule {
    /// Hard iteration cap.
    pub max_iters: u64,
    /// Stop when the best observed loss improves less than this over
    /// `patience` consecutive iterations (0 disables).
    pub loss_tol: f64,
    pub patience: u64,
    /// Stop when the aggregated gradient norm falls below this (0 disables).
    pub grad_tol: f64,
}

impl Default for StopRule {
    fn default() -> Self {
        StopRule {
            max_iters: 1000,
            loss_tol: 0.0,
            patience: 20,
            grad_tol: 0.0,
        }
    }
}

/// Why a run ended.
#[derive(Clone, Debug, PartialEq)]
pub enum RunStatus {
    /// Hit max_iters.
    Completed,
    /// A stop rule fired at `iter`.
    Converged { iter: u64, reason: String },
    /// BSP waiting on a dead worker with no recovery (fault-tolerance demo).
    Stalled { iter: u64 },
    /// Every worker is down.
    ClusterDead { iter: u64 },
}

impl RunStatus {
    pub fn is_healthy(&self) -> bool {
        matches!(self, RunStatus::Completed | RunStatus::Converged { .. })
    }
}

/// Stateful convergence tracker.
#[derive(Clone, Debug)]
pub struct ConvergenceTracker {
    rule: StopRule,
    best_loss: f64,
    since_improvement: u64,
}

impl ConvergenceTracker {
    pub fn new(rule: StopRule) -> ConvergenceTracker {
        ConvergenceTracker {
            rule,
            best_loss: f64::INFINITY,
            since_improvement: 0,
        }
    }

    pub fn rule(&self) -> &StopRule {
        &self.rule
    }

    /// Observe one iteration. Returns `Some(status)` when the run should stop.
    pub fn observe(&mut self, iter: u64, loss: f64, grad_norm: f64) -> Option<RunStatus> {
        if self.rule.grad_tol > 0.0 && grad_norm < self.rule.grad_tol {
            return Some(RunStatus::Converged {
                iter,
                reason: format!("grad_norm {grad_norm:.3e} < {:.3e}", self.rule.grad_tol),
            });
        }
        if self.rule.loss_tol > 0.0 {
            if loss < self.best_loss - self.rule.loss_tol {
                self.best_loss = loss;
                self.since_improvement = 0;
            } else {
                self.best_loss = self.best_loss.min(loss);
                self.since_improvement += 1;
                if self.since_improvement >= self.rule.patience {
                    return Some(RunStatus::Converged {
                        iter,
                        reason: format!(
                            "loss plateau: < {:.1e} improvement for {} iters",
                            self.rule.loss_tol, self.rule.patience
                        ),
                    });
                }
            }
        }
        if iter + 1 >= self.rule.max_iters {
            return Some(RunStatus::Completed);
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn max_iters_completes() {
        let mut t = ConvergenceTracker::new(StopRule {
            max_iters: 3,
            ..StopRule::default()
        });
        assert!(t.observe(0, 1.0, 1.0).is_none());
        assert!(t.observe(1, 0.9, 1.0).is_none());
        assert_eq!(t.observe(2, 0.8, 1.0), Some(RunStatus::Completed));
    }

    #[test]
    fn grad_tol_fires() {
        let mut t = ConvergenceTracker::new(StopRule {
            max_iters: 100,
            grad_tol: 1e-3,
            ..StopRule::default()
        });
        assert!(t.observe(0, 1.0, 0.1).is_none());
        match t.observe(1, 1.0, 1e-4) {
            Some(RunStatus::Converged { iter: 1, .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn plateau_fires_after_patience() {
        let mut t = ConvergenceTracker::new(StopRule {
            max_iters: 1000,
            loss_tol: 1e-6,
            patience: 3,
            grad_tol: 0.0,
        });
        assert!(t.observe(0, 1.0, 1.0).is_none());
        assert!(t.observe(1, 1.0, 1.0).is_none());
        assert!(t.observe(2, 1.0, 1.0).is_none());
        assert!(matches!(
            t.observe(3, 1.0, 1.0),
            Some(RunStatus::Converged { .. })
        ));
    }

    #[test]
    fn improvement_resets_patience() {
        let mut t = ConvergenceTracker::new(StopRule {
            max_iters: 1000,
            loss_tol: 1e-6,
            patience: 2,
            grad_tol: 0.0,
        });
        assert!(t.observe(0, 1.0, 1.0).is_none());
        assert!(t.observe(1, 1.0, 1.0).is_none());
        assert!(t.observe(2, 0.5, 1.0).is_none()); // improved, reset
        assert!(t.observe(3, 0.5, 1.0).is_none());
        assert!(t.observe(4, 0.5, 1.0).is_some());
    }

    #[test]
    fn status_health() {
        assert!(RunStatus::Completed.is_healthy());
        assert!(!RunStatus::Stalled { iter: 5 }.is_healthy());
        assert!(!RunStatus::ClusterDead { iter: 5 }.is_healthy());
    }
}
