"""L2 correctness: the decoder-only LM used by the end-to-end example."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import transformer as tf
from compile.shapes import LM_CONFIGS

CFG = LM_CONFIGS["lm_tiny"]


def _params(seed=0):
    return [jnp.asarray(p) for p in tf.init_params(CFG, seed)]


def _tokens(seed=0, batch=None):
    rng = np.random.default_rng(seed)
    b = batch or CFG.batch
    return jnp.asarray(rng.integers(0, CFG.vocab, (b, CFG.seq + 1)), jnp.int32)


class TestParams:
    def test_spec_count_matches_meta(self):
        specs = tf.param_specs(CFG)
        n = sum(int(np.prod(s)) for _, s in specs)
        assert n == CFG.n_params()

    def test_init_shapes(self):
        ps = tf.init_params(CFG, 0)
        for p, (name, shape) in zip(ps, tf.param_specs(CFG)):
            assert p.shape == shape, name
            assert p.dtype == np.float32

    def test_init_deterministic(self):
        a = tf.init_params(CFG, 42)
        b = tf.init_params(CFG, 42)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_ln_scales_ones(self):
        for p, (name, _) in zip(tf.init_params(CFG, 0), tf.param_specs(CFG)):
            if name.endswith("_scale"):
                assert np.all(p == 1.0)


class TestForward:
    def test_loss_near_uniform_at_init(self):
        """At init the model is near-uniform: loss ~ log(vocab)."""
        loss = float(tf.loss_fn(CFG, _tokens(), _params()))
        assert abs(loss - np.log(CFG.vocab)) < 0.5

    def test_causality(self):
        """Changing a future token must not change past logits."""
        ps = _params()
        toks = np.asarray(_tokens(1))
        logits1 = np.asarray(tf._forward(CFG, jnp.asarray(toks[:, :-1]), ps))
        toks2 = toks.copy()
        toks2[:, CFG.seq // 2 :] = (toks2[:, CFG.seq // 2 :] + 1) % CFG.vocab
        logits2 = np.asarray(tf._forward(CFG, jnp.asarray(toks2[:, :-1]), ps))
        cut = CFG.seq // 2
        np.testing.assert_allclose(
            logits1[:, :cut, :], logits2[:, :cut, :], rtol=1e-4, atol=1e-4
        )

    def test_grads_shapes_match_params(self):
        step = tf.lm_step(CFG)
        out = step(_tokens(), *_params())
        loss, grads = out[0], out[1:]
        assert loss.shape == ()
        specs = tf.param_specs(CFG)
        assert len(grads) == len(specs)
        for g, (name, shape) in zip(grads, specs):
            assert g.shape == shape, name

    def test_lm_loss_equals_lm_step_loss(self):
        step = tf.lm_step(CFG)
        ev = tf.lm_loss(CFG)
        toks, ps = _tokens(2), _params()
        l1 = float(step(toks, *ps)[0])
        l2 = float(ev(toks, *ps)[0])
        assert abs(l1 - l2) < 1e-5


class TestTraining:
    def test_sgd_steps_reduce_loss(self):
        """A few full-batch SGD steps on one repeated batch must fit it."""
        step = jax.jit(tf.lm_step(CFG))
        ps = _params()
        toks = _tokens(3)
        losses = []
        for _ in range(8):
            out = step(toks, *ps)
            losses.append(float(out[0]))
            ps = [p - 0.5 * g for p, g in zip(ps, out[1:])]
        assert losses[-1] < losses[0] - 0.1, losses

    def test_grad_matches_finite_difference(self):
        """Spot-check autodiff on a handful of coordinates."""
        ps = _params()
        toks = _tokens(4)
        step = tf.lm_step(CFG)
        out = step(toks, *ps)
        g_lnf = np.asarray(out[-2])  # lnf_scale gradient
        idx = len(ps) - 2
        eps = 1e-2
        for coord in (0, CFG.d_model // 2):
            plus = [p for p in ps]
            plus[idx] = ps[idx].at[coord].add(eps)
            minus = [p for p in ps]
            minus[idx] = ps[idx].at[coord].add(-eps)
            fd = (
                float(tf.loss_fn(CFG, toks, plus)) - float(tf.loss_fn(CFG, toks, minus))
            ) / (2 * eps)
            assert abs(fd - g_lnf[coord]) < 5e-3, (coord, fd, g_lnf[coord])
