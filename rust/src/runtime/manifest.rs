//! `artifacts/manifest.json` parsing and shape metadata.

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::json;
use crate::config::Value;
use crate::{Error, Result};

/// Element dtype of a tensor crossing the PJRT boundary.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    U32,
}

impl Dtype {
    pub fn parse(tag: &str) -> Result<Dtype> {
        match tag {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            "u32" => Ok(Dtype::U32),
            other => Err(Error::Manifest(format!("unknown dtype '{other}'"))),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// Shape + dtype of one input/output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    /// Input name from the python signature (outputs have "").
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (KRR config dims, LM hyperparameters, ...).
    pub meta: Value,
}

impl ArtifactInfo {
    /// Look up an input position by name.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|t| t.name == name)
            .ok_or_else(|| {
                Error::Manifest(format!("artifact '{}' has no input '{name}'", self.name))
            })
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(Value::as_usize)
            .ok_or_else(|| {
                Error::Manifest(format!("artifact '{}' missing meta '{key}'", self.name))
            })
    }
}

/// The whole parsed manifest.
pub struct Manifest {
    pub format_version: u64,
    pub jax_version: String,
    artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        Manifest::parse(&text).map_err(|e| Error::Manifest(format!("{}: {e}", path.display())))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = json::parse(text)?;
        let format_version = root.opt_u64("format_version", 0);
        if format_version != 1 {
            return Err(Error::Manifest(format!(
                "unsupported manifest format_version {format_version}"
            )));
        }
        let jax_version = root.opt_str("jax_version", "?").to_string();
        let table = root
            .get("artifacts")
            .and_then(Value::as_table)
            .ok_or_else(|| Error::Manifest("missing 'artifacts' table".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in table {
            artifacts.insert(name.clone(), parse_entry(name, entry)?);
        }
        Ok(Manifest {
            format_version,
            jax_version,
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(name).ok_or_else(|| {
            Error::Manifest(format!(
                "artifact '{name}' not in manifest (have: {})",
                self.names().join(", ")
            ))
        })
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &ArtifactInfo)> {
        self.artifacts.iter()
    }
}

fn parse_entry(name: &str, entry: &Value) -> Result<ArtifactInfo> {
    let file = entry.req_str("file")?.to_string();
    let inputs = parse_tensors(name, entry, "inputs")?;
    let outputs = parse_tensors(name, entry, "outputs")?;
    Ok(ArtifactInfo {
        name: name.to_string(),
        file,
        inputs,
        outputs,
        meta: entry.get("meta").cloned().unwrap_or_else(Value::empty_table),
    })
}

fn parse_tensors(name: &str, entry: &Value, key: &str) -> Result<Vec<TensorSpec>> {
    let arr = entry
        .get(key)
        .and_then(Value::as_array)
        .ok_or_else(|| Error::Manifest(format!("artifact '{name}' missing '{key}'")))?;
    arr.iter()
        .map(|t| {
            let shape = t
                .get("shape")
                .and_then(Value::as_array)
                .ok_or_else(|| Error::Manifest(format!("artifact '{name}': tensor missing shape")))?
                .iter()
                .map(|v| {
                    v.as_usize().ok_or_else(|| {
                        Error::Manifest(format!("artifact '{name}': bad shape element"))
                    })
                })
                .collect::<Result<Vec<usize>>>()?;
            let dtype = Dtype::parse(t.opt_str("dtype", "f32"))?;
            Ok(TensorSpec {
                name: t.opt_str("name", "").to_string(),
                shape,
                dtype,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "format_version": 1,
      "jax_version": "0.8.2",
      "artifacts": {
        "krr_worker_grad_small": {
          "file": "krr_worker_grad_small.hlo.txt",
          "inputs": [
            {"name": "theta", "shape": [32], "dtype": "f32"},
            {"name": "phi", "shape": [256, 32], "dtype": "f32"},
            {"name": "y", "shape": [256], "dtype": "f32"},
            {"name": "lam", "shape": [], "dtype": "f32"}
          ],
          "outputs": [{"shape": [32], "dtype": "f32"}],
          "meta": {"config": "small", "l": 32, "zeta": 256}
        }
      }
    }"#;

    #[test]
    fn parses_entry() {
        let m = Manifest::parse(DOC).unwrap();
        assert_eq!(m.len(), 1);
        let a = m.get("krr_worker_grad_small").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].shape, vec![256, 32]);
        assert_eq!(a.inputs[3].shape, Vec::<usize>::new());
        assert_eq!(a.inputs[3].elements(), 1);
        assert_eq!(a.outputs[0].dtype, Dtype::F32);
        assert_eq!(a.meta_usize("zeta").unwrap(), 256);
        assert_eq!(a.input_index("y").unwrap(), 2);
        assert!(a.input_index("nope").is_err());
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(DOC).unwrap();
        let e = m.get("nope").unwrap_err();
        assert!(format!("{e}").contains("krr_worker_grad_small"));
    }

    #[test]
    fn rejects_wrong_version() {
        let doc = r#"{"format_version": 2, "artifacts": {}}"#;
        assert!(Manifest::parse(doc).is_err());
    }

    #[test]
    fn rejects_bad_dtype() {
        let doc = DOC.replace("\"f32\"", "\"f64\"");
        assert!(Manifest::parse(&doc).is_err());
    }
}
