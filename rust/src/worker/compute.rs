//! Worker-side compute implementations: native mirror and the PJRT path.
//!
//! [`XlaKrrPool`] (virtual mode, one engine on the driver thread) and
//! [`XlaKrrFactory`] (real mode, one engine per worker thread) both execute
//! the `krr_worker_grad_loss_<config>` artifact — the L1 pallas kernel
//! lowered through the L2 jax entry point — so the *entire* gradient math
//! on the hot path runs inside XLA, exactly as Algorithm 3 prescribes.
//!
//! Elastic rebalancing means a worker may be handed any shard, not just
//! its original one, so the per-worker [`WorkerCompute`] is
//! **shard-addressable**: the native path computes straight from the shared
//! shard table, and the XLA path uploads a shard's device buffers the first
//! time it is assigned and keeps them resident after that (migrating a
//! shard costs one host→device copy, then it's as fast as home data).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use crate::data::native::krr_shard_grad_into;
use crate::data::shard::Shard;
use crate::data::{ComputePool, GradResult};
use crate::runtime::{literal, ArtifactSet, Engine, Executable};
use crate::worker::{ComputeFactory, WorkerCompute};
use crate::{Error, Result};

/// Per-shard *device buffers* a worker uploads once (Φ and y never change).
/// Keeping them device-resident skips the per-call host→device copy the
/// literal path pays — 512 KiB/call for the default shard (§Perf L3).
struct ShardBuffers {
    phi: xla::PjRtBuffer,
    y: xla::PjRtBuffer,
    /// Device-resident λ scalar (also constant per run).
    lam: xla::PjRtBuffer,
    rows: usize,
}

fn shard_buffers(engine: &Engine, shard: &Shard, lam: f32) -> Result<ShardBuffers> {
    Ok(ShardBuffers {
        phi: engine.buffer_f32(&shard.phi, &[shard.rows, shard.l])?,
        y: engine.buffer_f32(&shard.y, &[shard.rows])?,
        lam: engine.buffer_f32(&[lam], &[])?,
        rows: shard.rows,
    })
}

/// Run one gradient+loss step through the artifact (device-buffer path),
/// writing into a caller-owned [`GradResult`] — the drivers' scratch
/// arenas reuse `out` across calls, so the host side of the PJRT boundary
/// stops allocating a fresh gradient `Vec` per dispatch.
fn xla_grad_into(
    engine: &Engine,
    exe: &Executable,
    bufs: &ShardBuffers,
    theta: &[f32],
    out: &mut GradResult,
) -> Result<()> {
    // θ changes every iteration → uploaded per call; Φ/y/λ stay resident.
    let theta_buf = engine.buffer_f32(theta, &[theta.len()])?;
    let outs = exe.run_b(&[&theta_buf, &bufs.phi, &bufs.y, &bufs.lam])?;
    literal::read_f32_into(&outs[0], &mut out.grad)?;
    out.loss_sum = Some(literal::to_scalar_f32(&outs[1])? as f64);
    out.examples = bufs.rows;
    Ok(())
}

// ---------------------------------------------------------------------
// Virtual-mode pool: single engine, all shards resident
// ---------------------------------------------------------------------

/// XLA-backed [`ComputePool`] for the virtual simulator.
pub struct XlaKrrPool {
    engine: Engine,
    exe: Executable,
    shards: Vec<ShardBuffers>,
    dim: usize,
}

impl XlaKrrPool {
    /// Load `krr_worker_grad_loss_<config>` and upload every shard.
    pub fn new(
        artifacts: &ArtifactSet,
        engine: &Engine,
        config: &str,
        shards: &[Shard],
        lam: f32,
    ) -> Result<XlaKrrPool> {
        let name = format!("krr_worker_grad_loss_{config}");
        let exe = artifacts.load(engine, &name)?;
        let info = exe.info();
        let l = info.meta_usize("l")?;
        let zeta = info.meta_usize("zeta")?;
        for s in shards {
            if s.l != l || s.rows != zeta {
                return Err(Error::Shape(format!(
                    "shard is {}x{}, artifact '{name}' wants {zeta}x{l}",
                    s.rows, s.l
                )));
            }
        }
        let bufs = shards
            .iter()
            .map(|s| shard_buffers(engine, s, lam))
            .collect::<Result<Vec<_>>>()?;
        Ok(XlaKrrPool {
            engine: engine.clone(),
            exe,
            shards: bufs,
            dim: l,
        })
    }
}

impl ComputePool for XlaKrrPool {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_workers(&self) -> usize {
        self.shards.len()
    }

    fn shard_examples(&self, w: usize) -> usize {
        self.shards[w].rows
    }

    fn grad_into(
        &mut self,
        w: usize,
        theta: &[f32],
        _iter: u64,
        out: &mut GradResult,
    ) -> Result<()> {
        xla_grad_into(&self.engine, &self.exe, &self.shards[w], theta, out)
    }
}

// ---------------------------------------------------------------------
// Real-mode factories (one engine per worker thread)
// ---------------------------------------------------------------------

/// Pure-rust factory (no PJRT) — fast-path for tests/benches of the
/// threaded runtime itself.  Workers share the shard table (Arc), so any
/// worker can compute any shard the rebalancer assigns it.
pub struct NativeKrrFactory {
    shards: Arc<Vec<Shard>>,
    lam: f32,
}

impl NativeKrrFactory {
    pub fn new(shards: Vec<Shard>, lam: f32) -> NativeKrrFactory {
        NativeKrrFactory {
            shards: Arc::new(shards),
            lam,
        }
    }

    pub fn for_problem(problem: &crate::data::KrrProblem) -> NativeKrrFactory {
        NativeKrrFactory::new(problem.shards.clone(), problem.spec.lambda as f32)
    }
}

struct NativeWorker {
    shards: Arc<Vec<Shard>>,
    lam: f32,
    /// Residual scratch for the column-blocked wide kernel, grown once.
    resid: Vec<f32>,
}

impl WorkerCompute for NativeWorker {
    fn dim(&self) -> usize {
        self.shards.first().map(|s| s.l).unwrap_or(0)
    }

    fn grad_shard_into(
        &mut self,
        shard: usize,
        theta: &[f32],
        _iter: u64,
        out: &mut GradResult,
    ) -> Result<()> {
        let s = self.shards.get(shard).ok_or_else(|| {
            Error::Cluster(format!("assigned unknown shard {shard}"))
        })?;
        krr_shard_grad_into(s, self.lam, theta, &mut self.resid, out);
        Ok(())
    }
}

impl ComputeFactory for NativeKrrFactory {
    fn dim(&self) -> usize {
        self.shards.first().map(|s| s.l).unwrap_or(0)
    }

    fn workers(&self) -> usize {
        self.shards.len()
    }

    fn shard_examples(&self, w: usize) -> usize {
        self.shards[w].rows
    }

    fn build(&self, _w: usize) -> Result<Box<dyn WorkerCompute>> {
        Ok(Box::new(NativeWorker {
            shards: Arc::clone(&self.shards),
            lam: self.lam,
            resid: Vec::new(),
        }))
    }
}

/// PJRT factory: each worker thread compiles its own copy of the artifact.
pub struct XlaKrrFactory {
    artifact_dir: PathBuf,
    config: String,
    shards: Arc<Vec<Shard>>,
    lam: f32,
    dim: usize,
}

impl XlaKrrFactory {
    pub fn new(
        artifacts: &ArtifactSet,
        config: &str,
        shards: Vec<Shard>,
        lam: f32,
    ) -> Result<XlaKrrFactory> {
        // Validate shapes against the manifest up front (fail fast on the
        // driver thread, not inside M worker threads).
        let info = artifacts.info(&format!("krr_worker_grad_loss_{config}"))?;
        let l = info.meta_usize("l")?;
        let zeta = info.meta_usize("zeta")?;
        for s in &shards {
            if s.l != l || s.rows != zeta {
                return Err(Error::Shape(format!(
                    "shard is {}x{}, artifact wants {zeta}x{l}",
                    s.rows, s.l
                )));
            }
        }
        Ok(XlaKrrFactory {
            artifact_dir: artifacts.dir().to_path_buf(),
            config: config.to_string(),
            shards: Arc::new(shards),
            lam,
            dim: l,
        })
    }
}

struct XlaWorker {
    engine: Engine,
    exe: Executable,
    /// Shared host-side shard table; device buffers upload on first
    /// assignment and stay resident (keyed by shard index).
    shards: Arc<Vec<Shard>>,
    bufs: BTreeMap<usize, ShardBuffers>,
    lam: f32,
    dim: usize,
}

impl WorkerCompute for XlaWorker {
    fn dim(&self) -> usize {
        self.dim
    }

    fn retain_shards(&mut self, shards: &[usize]) {
        // Drop device buffers for shards rebalanced away, so the cache is
        // bounded by the current assignment instead of every shard ever
        // assigned (re-adoption re-pays exactly one host→device upload).
        self.bufs.retain(|s, _| shards.contains(s));
    }

    fn grad_shard_into(
        &mut self,
        shard: usize,
        theta: &[f32],
        _iter: u64,
        out: &mut GradResult,
    ) -> Result<()> {
        if !self.bufs.contains_key(&shard) {
            let s = self.shards.get(shard).ok_or_else(|| {
                Error::Cluster(format!("assigned unknown shard {shard}"))
            })?;
            let b = shard_buffers(&self.engine, s, self.lam)?;
            self.bufs.insert(shard, b);
        }
        let bufs = self.bufs.get(&shard).expect("just inserted");
        xla_grad_into(&self.engine, &self.exe, bufs, theta, out)
    }
}

impl ComputeFactory for XlaKrrFactory {
    fn dim(&self) -> usize {
        self.dim
    }

    fn workers(&self) -> usize {
        self.shards.len()
    }

    fn shard_examples(&self, w: usize) -> usize {
        self.shards[w].rows
    }

    fn build(&self, w: usize) -> Result<Box<dyn WorkerCompute>> {
        let artifacts = ArtifactSet::open(&self.artifact_dir)?;
        let engine = Engine::cpu()?;
        let exe = artifacts.load(&engine, &format!("krr_worker_grad_loss_{}", self.config))?;
        // Pre-upload the worker's home shard; others upload on demand.
        let mut bufs = BTreeMap::new();
        bufs.insert(w, shard_buffers(&engine, &self.shards[w], self.lam)?);
        Ok(Box::new(XlaWorker {
            engine,
            exe,
            shards: Arc::clone(&self.shards),
            bufs,
            lam: self.lam,
            dim: self.dim,
        }))
    }
}
