//! The partial synchronization barrier — the mechanism at the heart of
//! Algorithm 2: "if received γ slave nodes, update".
//!
//! [`PartialBarrier`] tracks one iteration's arrivals for the threaded
//! runtime: it answers "is the barrier closed?" after each arrival and
//! classifies everything after closure as abandoned.  The virtual simulator
//! uses the same type so barrier semantics are tested once.

/// Outcome of offering an arrival to the barrier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Counted toward γ; barrier still open.
    Included,
    /// Counted toward γ and γ reached: barrier closes now.
    IncludedAndClosed,
    /// Arrived after closure (or duplicate): abandoned.
    Abandoned,
    /// Arrival for a different iteration: abandoned as stale.
    Stale,
}

/// One iteration's barrier state.
#[derive(Clone, Debug)]
pub struct PartialBarrier {
    iter: u64,
    gamma: usize,
    arrived: Vec<bool>,
    included: usize,
    closed: bool,
}

impl PartialBarrier {
    /// Barrier for `iter` over `workers` workers closing after `gamma`
    /// distinct arrivals (BSP: `gamma = alive workers`).
    pub fn new(iter: u64, workers: usize, gamma: usize) -> PartialBarrier {
        assert!(gamma >= 1 && gamma <= workers, "gamma {gamma} of {workers}");
        PartialBarrier {
            iter,
            gamma,
            arrived: vec![false; workers],
            included: 0,
            closed: false,
        }
    }

    /// Reuse this barrier for a new iteration without reallocating the
    /// arrival mask (the virtual driver's zero-alloc steady state keeps
    /// one barrier in its scratch arena).  The worker count is fixed at
    /// construction.
    pub fn reset(&mut self, iter: u64, gamma: usize) {
        assert!(
            gamma >= 1 && gamma <= self.arrived.len(),
            "gamma {gamma} of {}",
            self.arrived.len()
        );
        self.iter = iter;
        self.gamma = gamma;
        self.arrived.fill(false);
        self.included = 0;
        self.closed = false;
    }

    pub fn iter(&self) -> u64 {
        self.iter
    }

    pub fn gamma(&self) -> usize {
        self.gamma
    }

    pub fn included(&self) -> usize {
        self.included
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Offer worker `w`'s result for iteration `msg_iter`.
    pub fn offer(&mut self, w: usize, msg_iter: u64) -> Admission {
        if msg_iter != self.iter {
            return Admission::Stale;
        }
        if self.closed || self.arrived[w] {
            return Admission::Abandoned;
        }
        self.arrived[w] = true;
        self.included += 1;
        if self.included >= self.gamma {
            self.closed = true;
            Admission::IncludedAndClosed
        } else {
            Admission::Included
        }
    }

    /// Shrink γ when workers die mid-iteration (barrier can then close on
    /// fewer arrivals).  No-op if already satisfied.
    pub fn shrink_gamma(&mut self, new_gamma: usize) {
        self.gamma = new_gamma.max(1).min(self.gamma);
        if self.included >= self.gamma {
            self.closed = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn closes_exactly_at_gamma() {
        let mut b = PartialBarrier::new(7, 4, 2);
        assert_eq!(b.offer(0, 7), Admission::Included);
        assert!(!b.is_closed());
        assert_eq!(b.offer(2, 7), Admission::IncludedAndClosed);
        assert!(b.is_closed());
        assert_eq!(b.offer(1, 7), Admission::Abandoned);
        assert_eq!(b.included(), 2);
    }

    #[test]
    fn duplicate_arrivals_abandoned() {
        let mut b = PartialBarrier::new(0, 3, 3);
        assert_eq!(b.offer(1, 0), Admission::Included);
        assert_eq!(b.offer(1, 0), Admission::Abandoned);
        assert_eq!(b.included(), 1);
    }

    #[test]
    fn stale_iteration_rejected() {
        let mut b = PartialBarrier::new(5, 2, 1);
        assert_eq!(b.offer(0, 4), Admission::Stale);
        assert_eq!(b.offer(0, 6), Admission::Stale);
        assert_eq!(b.offer(0, 5), Admission::IncludedAndClosed);
    }

    #[test]
    fn shrink_gamma_closes_when_satisfied() {
        let mut b = PartialBarrier::new(0, 4, 3);
        b.offer(0, 0);
        b.offer(1, 0);
        assert!(!b.is_closed());
        b.shrink_gamma(2);
        assert!(b.is_closed());
    }

    #[test]
    fn all_workers_abandoned_in_one_iteration() {
        // Every arrival carries a superseded iteration number (all workers
        // straggled past the barrier): nothing is included, the barrier
        // stays open, and the inclusion count is 0 — the coordinator's
        // "skip the update, keep the clock moving" case.
        let mut b = PartialBarrier::new(3, 4, 2);
        for w in 0..4 {
            assert_eq!(b.offer(w, 2), Admission::Stale);
        }
        assert_eq!(b.included(), 0);
        assert!(!b.is_closed());
        // Same outcome when the barrier closed before anyone else arrived:
        // every later arrival is abandoned.
        let mut b = PartialBarrier::new(0, 4, 1);
        assert_eq!(b.offer(2, 0), Admission::IncludedAndClosed);
        for w in [0, 1, 3] {
            assert_eq!(b.offer(w, 0), Admission::Abandoned);
        }
        assert_eq!(b.included(), 1);
    }

    #[test]
    fn single_worker_cluster() {
        // m = 1 degenerates to BSP on one node: γ must be 1, the first
        // offer closes the barrier, duplicates are abandoned.
        let mut b = PartialBarrier::new(0, 1, 1);
        assert_eq!(b.gamma(), 1);
        assert!(!b.is_closed());
        assert_eq!(b.offer(0, 0), Admission::IncludedAndClosed);
        assert!(b.is_closed());
        assert_eq!(b.offer(0, 0), Admission::Abandoned);
        assert_eq!(b.included(), 1);
        // Shrinking a single-worker barrier is a no-op lower bound: γ ≥ 1.
        let mut b = PartialBarrier::new(1, 1, 1);
        b.shrink_gamma(0);
        assert_eq!(b.gamma(), 1);
        assert!(!b.is_closed());
    }

    #[test]
    fn worker_rejoining_same_iteration_it_was_declared_dead() {
        // γ=3 of 4; worker 2 is declared dead mid-iteration, so the master
        // shrinks γ to the remaining alive count — but the worker rejoins
        // (supervisor respawn) within the same iteration and its result
        // still arrives.  The barrier must accept that result toward γ
        // rather than double-counting or rejecting it.
        let mut b = PartialBarrier::new(5, 4, 3);
        assert_eq!(b.offer(0, 5), Admission::Included);
        // Worker 2 declared dead: alive = 3, γ clamps to 3 (no-op here).
        b.shrink_gamma(3);
        assert!(!b.is_closed());
        // Worker 2 rejoins within the iteration and reports.
        assert_eq!(b.offer(2, 5), Admission::Included);
        assert_eq!(b.offer(1, 5), Admission::IncludedAndClosed);
        assert!(b.is_closed());
        assert_eq!(b.included(), 3);
        // Its re-sent duplicate (rejoin then retransmit) is abandoned.
        assert_eq!(b.offer(2, 5), Admission::Abandoned);
    }

    #[test]
    fn shrink_gamma_never_reopens_or_grows() {
        let mut b = PartialBarrier::new(0, 4, 2);
        b.offer(0, 0);
        b.offer(1, 0);
        assert!(b.is_closed());
        // Shrinking after closure keeps it closed.
        b.shrink_gamma(1);
        assert!(b.is_closed());
        // "Shrinking" upward is clamped to the current γ.
        let mut b = PartialBarrier::new(0, 4, 2);
        b.shrink_gamma(4);
        assert_eq!(b.gamma(), 2);
    }

    #[test]
    fn reset_reuses_barrier_like_new() {
        let mut reused = PartialBarrier::new(0, 4, 2);
        reused.offer(0, 0);
        reused.offer(1, 0);
        assert!(reused.is_closed());
        reused.reset(7, 3);
        let fresh = PartialBarrier::new(7, 4, 3);
        assert_eq!(reused.iter(), fresh.iter());
        assert_eq!(reused.gamma(), fresh.gamma());
        assert_eq!(reused.included(), 0);
        assert!(!reused.is_closed());
        // Previously-arrived workers count again after a reset.
        assert_eq!(reused.offer(0, 7), Admission::Included);
    }

    #[test]
    #[should_panic]
    fn reset_rejects_gamma_above_workers() {
        let mut b = PartialBarrier::new(0, 4, 2);
        b.reset(1, 5);
    }

    #[test]
    #[should_panic]
    fn rejects_gamma_zero() {
        PartialBarrier::new(0, 4, 0);
    }

    #[test]
    #[should_panic]
    fn rejects_gamma_above_workers() {
        PartialBarrier::new(0, 4, 5);
    }
}
