//! Minimal `log` backend (no `env_logger` in the vendor set).
//!
//! Levels come from `HYBRIDITER_LOG` (error|warn|info|debug|trace),
//! defaulting to `info`.  Output goes to stderr with elapsed-time stamps so
//! coordinator traces line up with metric timestamps.

use std::io::Write;
use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:10.4}s {:5} {}] {}",
            record.level(),
            record.target().split("::").last().unwrap_or(""),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Call once from binaries/examples.
pub fn init() {
    init_with_level(default_level());
}

/// Install with an explicit level filter (idempotent).
pub fn init_with_level(level: log::LevelFilter) {
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        level,
    });
    // set_logger fails if already set (e.g. tests calling init twice) — fine.
    let _ = log::set_logger(logger);
    log::set_max_level(logger.level);
}

fn default_level() -> log::LevelFilter {
    match std::env::var("HYBRIDITER_LOG").as_deref() {
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logger smoke");
    }
}
