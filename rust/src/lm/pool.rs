//! XLA-backed LM compute pool: each simulated worker runs `lm_step` on its
//! own corpus shard's microbatches.

use crate::data::corpus::BigramCorpus;
use crate::data::{ComputePool, GradResult};
use crate::lm::LmTask;
use crate::runtime::{literal, ArtifactSet, Engine, Executable};
use crate::util::rng::Pcg64;
use crate::Result;

/// Data-parallel LM pool for the virtual simulator (single engine).
pub struct LmPool {
    task: LmTask,
    engine: Engine,
    exe: Executable,
    corpus: BigramCorpus,
    /// Per-worker batch RNGs (disjoint streams = disjoint data shards).
    rngs: Vec<Pcg64>,
    offsets: Vec<(usize, usize)>,
}

impl LmPool {
    pub fn new(
        artifacts: &ArtifactSet,
        engine: &Engine,
        config: &str,
        workers: usize,
        corpus_branching: usize,
        seed: u64,
    ) -> Result<LmPool> {
        let task = LmTask::from_manifest(artifacts, config)?;
        let exe = artifacts.load(engine, &format!("lm_step_{config}"))?;
        let corpus = BigramCorpus::new(task.vocab, corpus_branching, seed);
        let mut root = Pcg64::new(seed, 0x70_01);
        let rngs = (0..workers).map(|w| root.split(w as u64)).collect();
        let offsets = task.offsets();
        Ok(LmPool {
            task,
            engine: engine.clone(),
            exe,
            corpus,
            rngs,
            offsets,
        })
    }

    pub fn task(&self) -> &LmTask {
        &self.task
    }

    /// The corpus' exact conditional entropy: the achievable loss floor.
    pub fn loss_floor(&self) -> f64 {
        self.corpus.conditional_entropy()
    }

    /// Evaluate mean NLL on a fresh batch (eval hook helper).
    pub fn eval_loss(&mut self, theta: &[f32], seed: u64) -> Result<f64> {
        let mut rng = Pcg64::new(seed, 0xE7A1);
        let mut res = GradResult::empty();
        self.step_into(theta, &mut rng, &mut res)?;
        Ok(res.loss_sum.unwrap() / res.examples as f64)
    }

    fn step_into(&mut self, theta: &[f32], rng: &mut Pcg64, out: &mut GradResult) -> Result<()> {
        let t = &self.task;
        debug_assert_eq!(theta.len(), t.n_params);
        let tokens = self.corpus.sample_batch(t.batch, t.seq, rng);

        // Pack inputs: tokens + every parameter tensor sliced from flat θ.
        // Device buffers are built straight from the host slices (single
        // copy; the literal path would copy twice — §Perf L3).
        let mut inputs: Vec<xla::PjRtBuffer> = Vec::with_capacity(1 + t.params.len());
        inputs.push(self.engine.buffer_i32(&tokens, &[t.batch, t.seq + 1])?);
        for (spec, &(off, n)) in t.params.iter().zip(&self.offsets) {
            inputs.push(self.engine.buffer_f32(&theta[off..off + n], &spec.shape)?);
        }

        let outs = self.exe.run_b(&inputs)?;
        let loss = literal::to_scalar_f32(&outs[0])? as f64;

        // Flatten grads back into the caller's buffer (outs[1..] in param
        // order); resize is a no-op on a reused slot.
        out.grad.resize(t.n_params, 0.0);
        for (o, &(off, n)) in outs[1..].iter().zip(&self.offsets) {
            let v = literal::to_vec_f32(o)?;
            debug_assert_eq!(v.len(), n);
            out.grad[off..off + n].copy_from_slice(&v);
        }

        let examples = t.tokens_per_batch();
        // lm_step returns *mean* NLL; convert to a sum so the shared loss
        // assembly (Σ/Σ) recovers the mean across workers.
        out.loss_sum = Some(loss * examples as f64);
        out.examples = examples;
        Ok(())
    }
}

impl ComputePool for LmPool {
    fn dim(&self) -> usize {
        self.task.n_params
    }

    fn n_workers(&self) -> usize {
        self.rngs.len()
    }

    fn shard_examples(&self, _w: usize) -> usize {
        self.task.tokens_per_batch()
    }

    fn grad_into(
        &mut self,
        w: usize,
        theta: &[f32],
        _iter: u64,
        out: &mut GradResult,
    ) -> Result<()> {
        let mut rng = self.rngs[w].clone();
        self.step_into(theta, &mut rng, out)?;
        self.rngs[w] = rng;
        Ok(())
    }
}
