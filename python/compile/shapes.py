"""Static shape configurations for every AOT artifact.

The rust runtime executes fixed-shape PJRT executables, so every entry
point is lowered at the concrete shapes listed here.  ``aot.py`` iterates
these configs; ``manifest.json`` records them for the rust side
(`runtime/manifest.rs`), and the rust config files refer to configs by name.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class KrrConfig:
    """One KRR problem size: d raw input dims -> l kernel features,
    zeta examples per machine (the paper's notation)."""

    name: str
    d: int  # raw input dimension
    l: int  # kernel feature dimension (paper's l)
    zeta: int  # examples per machine (paper's zeta)


@dataclass(frozen=True)
class LmConfig:
    """One decoder-only LM size for the end-to-end training example."""

    name: str
    vocab: int
    d_model: int
    n_head: int
    n_layer: int
    seq: int  # tokens per example fed to the model
    batch: int  # per-worker microbatch
    d_ff: int = 0  # 0 -> 4 * d_model

    @property
    def ff(self) -> int:
        return self.d_ff or 4 * self.d_model

    def n_params(self) -> int:
        D, F, V, T = self.d_model, self.ff, self.vocab, self.seq
        per_layer = 2 * D + 4 * D * D + 2 * D + D * F + F + F * D + D
        return V * D + T * D + self.n_layer * per_layer + 2 * D


# --- KRR problem sizes -------------------------------------------------
# "small" keeps python tests and rust unit tests fast; "default" is the
# experiment workhorse (T1..T4, F1..F3); "wide" stresses the kernel tiling
# and is the perf-pass target.
KRR_CONFIGS: dict[str, KrrConfig] = {
    c.name: c
    for c in [
        KrrConfig("small", d=8, l=32, zeta=256),
        KrrConfig("default", d=8, l=64, zeta=2048),
        KrrConfig("wide", d=16, l=256, zeta=1024),
    ]
}

# --- LM sizes ----------------------------------------------------------
# "lm_tiny" is for tests; "lm_small" (~1.6M params) is the end-to-end
# example's default; "lm_medium" (~19M params) is the larger e2e target
# (lowered only with --lm-medium: compile time on the CPU PJRT client is
# noticeable).  The paper's setting is a 2014 CPU cluster; DESIGN.md §3
# documents scaling the mandated ~100M e2e transformer down to what the
# CPU-interpret testbed trains in minutes.
LM_CONFIGS: dict[str, LmConfig] = {
    c.name: c
    for c in [
        LmConfig("lm_tiny", vocab=256, d_model=64, n_head=4, n_layer=2, seq=64, batch=4),
        LmConfig("lm_small", vocab=512, d_model=128, n_head=4, n_layer=4, seq=128, batch=8),
        LmConfig("lm_medium", vocab=4096, d_model=384, n_head=6, n_layer=8, seq=256, batch=8),
    ]
}

# KRR configs whose artifacts are always built.
DEFAULT_KRR = ["small", "default", "wide"]
# LM configs whose artifacts are always built.
DEFAULT_LM = ["lm_tiny", "lm_small"]
