//! PJRT engine: compile HLO text, execute with validation.

use std::path::Path;
use std::time::Instant;

use crate::runtime::manifest::ArtifactInfo;
#[cfg(debug_assertions)]
use crate::runtime::manifest::Dtype;
use crate::{Error, Result};

/// A PJRT client bound to one device (CPU here).  **Not `Send`** — build
/// one per thread (see module docs on [`crate::runtime`]).
pub struct Engine {
    client: xla::PjRtClient,
}

impl Clone for Engine {
    fn clone(&self) -> Self {
        // PjRtClient is an Rc handle; cloning shares the underlying client.
        Engine {
            client: self.client.clone(),
        }
    }
}

impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine {
            client: xla::PjRtClient::cpu()?,
        })
    }

    /// Upload an f32 tensor to a device buffer (perf path: static inputs
    /// like a worker's Φ shard upload once, skipping the per-call
    /// host→device copy that `execute` on literals performs).
    pub fn buffer_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 tensor to a device buffer.
    pub fn buffer_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO text file into an [`Executable`].
    pub fn compile_hlo_file(&self, path: &Path, info: ArtifactInfo) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::other("non-UTF-8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::debug!(
            "compiled {} in {:.1}ms",
            info.name,
            t0.elapsed().as_secs_f64() * 1e3
        );
        Ok(Executable { exe, info })
    }

    /// Compile HLO text from a string (used by tests).
    pub fn compile_hlo_text(&self, text: &str, info: ArtifactInfo) -> Result<Executable> {
        let dir = std::env::temp_dir().join("hybriditer_hlo");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{}_{}.hlo.txt", info.name, std::process::id()));
        std::fs::write(&path, text)?;
        let out = self.compile_hlo_file(&path, info);
        let _ = std::fs::remove_file(&path);
        out
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    info: ArtifactInfo,
}

impl Executable {
    pub fn info(&self) -> &ArtifactInfo {
        &self.info
    }

    /// Execute with the given input literals (order = manifest order).
    /// Accepts owned literals or references (`Borrow<Literal>`), so static
    /// inputs like a worker's Φ shard upload once and are passed by ref.
    /// Returns the flattened output tuple as individual literals.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.inputs.len() {
            return Err(Error::Shape(format!(
                "artifact '{}': {} inputs given, manifest wants {}",
                self.info.name,
                inputs.len(),
                self.info.inputs.len()
            )));
        }
        #[cfg(debug_assertions)]
        self.validate_inputs(inputs)?;

        let result = self.exe.execute::<L>(inputs)?;
        let tuple = result
            .first()
            .and_then(|bufs| bufs.first())
            .ok_or_else(|| Error::other("PJRT returned no output buffers"))?
            .to_literal_sync()?;
        // Lowered with return_tuple=True: a single tuple of outputs.
        let outs = tuple.to_tuple()?;
        if outs.len() != self.info.outputs.len() {
            return Err(Error::Shape(format!(
                "artifact '{}': {} outputs returned, manifest says {}",
                self.info.name,
                outs.len(),
                self.info.outputs.len()
            )));
        }
        Ok(outs)
    }

    /// Execute with device-resident input buffers (see [`Engine::buffer_f32`]).
    /// Skips the host→device transfer `run` performs on every literal input.
    pub fn run_b<B: std::borrow::Borrow<xla::PjRtBuffer>>(
        &self,
        inputs: &[B],
    ) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.info.inputs.len() {
            return Err(Error::Shape(format!(
                "artifact '{}': {} inputs given, manifest wants {}",
                self.info.name,
                inputs.len(),
                self.info.inputs.len()
            )));
        }
        let result = self.exe.execute_b::<B>(inputs)?;
        let tuple = result
            .first()
            .and_then(|bufs| bufs.first())
            .ok_or_else(|| Error::other("PJRT returned no output buffers"))?
            .to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.info.outputs.len() {
            return Err(Error::Shape(format!(
                "artifact '{}': {} outputs returned, manifest says {}",
                self.info.name,
                outs.len(),
                self.info.outputs.len()
            )));
        }
        Ok(outs)
    }

    #[cfg(debug_assertions)]
    fn validate_inputs<L: std::borrow::Borrow<xla::Literal>>(&self, inputs: &[L]) -> Result<()> {
        for (lit, spec) in inputs.iter().map(|l| l.borrow()).zip(&self.info.inputs) {
            let n = lit.element_count();
            if n != spec.elements() {
                return Err(Error::Shape(format!(
                    "artifact '{}': input '{}' has {} elements, want {} ({:?})",
                    self.info.name,
                    spec.name,
                    n,
                    spec.elements(),
                    spec.shape
                )));
            }
            let ty = lit.ty()?;
            let ok = matches!(
                (spec.dtype, ty),
                (Dtype::F32, xla::ElementType::F32)
                    | (Dtype::I32, xla::ElementType::S32)
                    | (Dtype::U32, xla::ElementType::U32)
            );
            if !ok {
                return Err(Error::Shape(format!(
                    "artifact '{}': input '{}' dtype mismatch (manifest {:?}, literal {:?})",
                    self.info.name, spec.name, spec.dtype, ty
                )));
            }
        }
        Ok(())
    }
}
