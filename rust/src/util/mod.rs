//! Small from-scratch substrates: RNG, logger, property-test helper.
//!
//! The offline vendor set has neither `rand` nor `proptest` nor a logger
//! backend, so the pieces the rest of the crate needs are implemented here
//! (DESIGN.md §3).

pub mod logger;
pub mod pool;
pub mod proptest;
pub mod rng;

/// Format a `std::time::Duration` in adaptive human units.
pub fn fmt_duration(d: std::time::Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format seconds (f64) in adaptive human units.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_formats() {
        assert_eq!(fmt_secs(2.5), "2.50s");
        assert_eq!(fmt_secs(0.0025), "2.50ms");
        assert_eq!(fmt_secs(0.0000025), "2.5us");
    }
}
