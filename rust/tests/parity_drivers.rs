//! Cross-driver parity: given the same deterministic straggler/failure/join
//! trace, the virtual simulator (`sim::run_virtual`) and the threaded
//! runtime (`Coordinator::run_real`) must make identical inclusion /
//! abandonment decisions and produce matching θ trajectories.
//!
//! Stochastic delay models cannot be compared across drivers (each driver
//! owns its RNG streams), so parity traces use *deterministic* timing:
//! per-worker chronic slow factors spaced far enough apart (≥ 5 ms) that
//! wall-clock arrival order in the threaded runtime equals the virtual
//! latency order.  Gradient math is shared (`krr_shard_grad_into`) and
//! both drivers fold contributions in ascending shard order, so θ agrees
//! to f32 round-off.
//!
//! The perf pass added golden equivalence tests at the bottom: the fused
//! single-pass kernel must match the seed's two-pass reference bit for
//! bit, and `run_virtual` θ trajectories must be identical before/after
//! the scratch-arena + `grad_into` refactor (the reference pool *is* the
//! "before": allocate-per-call, two-pass kernel).

use hybriditer::cluster::{ClusterSpec, ElasticSchedule};
use hybriditer::coordinator::{Coordinator, LossForm, RunConfig, RunReport, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::net::{LinkModel, NetSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::trace::JournalSink;
use hybriditer::worker::NativeKrrFactory;

fn problem(machines: usize) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "parity".into(),
        d: 4,
        l: 16,
        zeta: 64,
        machines,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 64,
        seed: 17,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn run_both(p: &KrrProblem, cluster: &ClusterSpec, cfg: &RunConfig) -> (RunReport, RunReport) {
    let mut pool = p.native_pool();
    let virt = sim::run_virtual(&mut pool, cluster, cfg, &NoEval).unwrap();
    let coord = Coordinator::new(cluster.clone(), cfg.clone()).unwrap();
    let factory = NativeKrrFactory::for_problem(p);
    let real = coord.run_real(&factory, &NoEval).unwrap();
    (virt, real)
}

fn max_theta_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn parity_elastic_join_trace_same_decisions_and_theta() {
    // 2 of 4 workers leave at iteration 4 and rejoin at 8; rebalancing on;
    // γ = M so every responder is included and neither driver can drift.
    let m = 4;
    let p = problem(m);
    let iters = 14;
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        // Deterministic, well-separated per-worker latencies.
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 5,
        ..ClusterSpec::default()
    }
    .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 3], 4, 8), 1);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: m },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters);

    let (virt, real) = run_both(&p, &cluster, &cfg);

    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    // Same membership history…
    assert_eq!(virt.crashes, 2);
    assert_eq!(real.crashes, 2);
    assert_eq!(virt.rejoins, 2);
    assert_eq!(real.rejoins, 2);
    assert_eq!(virt.rebalances, real.rebalances);

    // …identical per-iteration inclusion decisions…
    assert_eq!(virt.recorder.len(), real.recorder.len());
    for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
        assert_eq!(rv.iter, rr.iter);
        assert_eq!(
            rv.included, rr.included,
            "iter {}: virtual included {} shards, real {}",
            rv.iter, rv.included, rr.included
        );
        assert_eq!(rv.alive, rr.alive, "iter {}", rv.iter);
    }
    assert_eq!(virt.total_contributions, real.total_contributions);
    assert_eq!(virt.total_abandoned, 0);
    assert_eq!(real.total_abandoned, 0);

    // …and matching θ (same shared gradient kernel, same fold order).
    let diff = max_theta_diff(&virt.theta, &real.theta);
    assert!(diff < 1e-5, "theta diverged: max diff {diff}");
}

#[test]
fn parity_straggler_trace_same_abandonment_decisions() {
    // One chronically 12× slow worker under γ = 3 of 4: both drivers must
    // abandon exactly that worker's shard every iteration (it never lands
    // inside the barrier), and agree on θ.
    let m = 4;
    let p = problem(m);
    let iters = 20;
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 12.0)],
        seed: 9,
        ..ClusterSpec::default()
    };
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 3 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters);

    let (virt, real) = run_both(&p, &cluster, &cfg);

    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    // Both drivers include exactly workers {0,1,2} every iteration: the
    // slow worker's shard never contributes.
    for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
        assert_eq!(rv.included, 3, "virtual iter {}", rv.iter);
        assert_eq!(rr.included, 3, "real iter {}", rr.iter);
    }
    assert_eq!(virt.total_contributions, 3 * iters);
    assert_eq!(real.total_contributions, 3 * iters);
    // The virtual driver abandons the straggler once per iteration; the
    // threaded runtime abandons each of its (less frequent, because it
    // skips to the freshest broadcast) stale arrivals — both must abandon
    // *something*, and only worker 3's results.
    assert_eq!(virt.total_abandoned, iters);
    assert!(real.total_abandoned > 0, "straggler never went stale");

    let diff = max_theta_diff(&virt.theta, &real.theta);
    assert!(diff < 1e-5, "theta diverged: max diff {diff}");
}

#[test]
fn parity_mixed_capacity_join_same_ownership_timeline_and_counts() {
    // A 0.25× worker leaves at iteration 4 and rejoins at 8 with a
    // 3-boundary warm-up ramp, capacity-weighted rebalancing on.  Both
    // drivers must realize the *same ownership timeline* — the shard moves
    // through the same owners at the same boundaries, driven by the shared
    // weighted planner and warm-up state — and agree on every admission
    // count and on θ.  The timeline is sampled at two cuts: mid-ramp
    // (iters = 10, the rejoiner still shard-less) and after the ramp
    // (iters = 16, the shard handed back).
    let m = 4;
    let p = problem(m);
    let mk_cluster = || {
        ClusterSpec {
            workers: m,
            base_compute: 0.005,
            // Deterministic, well-separated per-worker latencies; worker
            // 3's 0.25× capacity gives it a 4×-base service time.
            slow_nodes: vec![(1, 2.0), (2, 3.0)],
            capacities: vec![(3, 0.25)],
            rebalance_every: 1,
            seed: 35,
            ..ClusterSpec::default()
        }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&[3], 4, 8), 1)
        .with_warmup(3)
    };
    let mk_cfg = |iters: u64| {
        RunConfig {
            mode: SyncMode::Hybrid { gamma: m },
            optimizer: OptimizerKind::sgd(0.8),
            loss_form: LossForm::krr(p.spec.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(iters)
    };

    // Mid-ramp cut: the rejoiner's warm-up weight is still too small for
    // the apportionment to hand its shard back, so shard 3 sits on the
    // adopter (worker 0) in both drivers.
    let (virt_mid, real_mid) = run_both(&p, &mk_cluster(), &mk_cfg(10));
    assert_eq!(virt_mid.shard_owners, vec![0, 1, 2, 0]);
    assert_eq!(real_mid.shard_owners, vec![0, 1, 2, 0]);
    assert_eq!(virt_mid.rebalances, 1);
    assert_eq!(real_mid.rebalances, 1);

    // Full run: the ramp saturates at boundary 11 and the weighted planner
    // hands shard 3 back to its warmed owner.
    let (virt, real) = run_both(&p, &mk_cluster(), &mk_cfg(16));
    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);
    assert_eq!(virt.shard_owners, vec![0, 1, 2, 3]);
    assert_eq!(real.shard_owners, vec![0, 1, 2, 3]);
    assert_eq!(virt.rebalances, 2);
    assert_eq!(real.rebalances, 2);
    assert_eq!(virt.crashes, 1);
    assert_eq!(real.crashes, 1);
    assert_eq!(virt.rejoins, 1);
    assert_eq!(real.rejoins, 1);

    // γ = M with every responder included: no abandons, no stales — and
    // the drivers agree on every per-iteration decision.
    assert_eq!(virt.total_abandoned, 0);
    assert_eq!(real.total_abandoned, 0);
    let virt_stale: usize = virt.recorder.rows().iter().map(|r| r.stale).sum();
    let real_stale: usize = real.recorder.rows().iter().map(|r| r.stale).sum();
    assert_eq!(virt_stale, real_stale);
    assert_eq!(virt.total_contributions, real.total_contributions);
    assert_eq!(virt.recorder.len(), real.recorder.len());
    for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
        assert_eq!(rv.iter, rr.iter);
        assert_eq!(
            rv.included, rr.included,
            "iter {}: virtual included {} shards, real {}",
            rv.iter, rv.included, rr.included
        );
        assert_eq!(rv.alive, rr.alive, "iter {}", rv.iter);
        // Every shard keeps contributing through the whole churn cycle:
        // the whole point of adopting + ramped give-back.
        assert_eq!(rv.included, m, "iter {}", rv.iter);
    }

    let diff = max_theta_diff(&virt.theta, &real.theta);
    assert!(diff < 1e-5, "theta diverged: max diff {diff}");
}

#[test]
fn parity_ideal_net_reports_zero_perturbation() {
    // The default NetSpec is ideal: both drivers must report clean message
    // accounting (nothing dropped or duplicated) and identical send counts
    // on a crash-free trace.
    let m = 4;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 3,
        ..ClusterSpec::default()
    };
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: m },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(10);
    let (virt, real) = run_both(&p, &cluster, &cfg);
    assert_eq!(virt.net.dropped, 0);
    assert_eq!(virt.net.duplicated, 0);
    assert_eq!(virt.net, real.net, "ideal-net accounting diverged");
    // 2 messages per worker per iteration.
    assert_eq!(virt.net.sent, 2 * m as u64 * 10);
}

#[test]
fn parity_lossy_net_same_counts_decisions_and_theta() {
    // Acceptance: with a lossy + duplicating NetSpec, both drivers realize
    // the *same* per-message fates (delivered / dropped / duplicated per
    // seed), make the same inclusion decisions, and land on the same θ.
    // Timing is deterministic (well-separated chronic slow factors, zero
    // net latency) so wall-clock arrival order equals virtual order.
    let m = 4;
    let p = problem(m);
    let iters = 30;
    let net = NetSpec {
        default_link: LinkModel {
            drop_prob: 0.25,
            dup_prob: 0.25,
            dup_lag: 0.0005,
            ..LinkModel::ideal()
        },
        ..NetSpec::ideal()
    };
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 21,
        ..ClusterSpec::default()
    }
    .with_net(net);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 2 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters);

    let (virt, real) = run_both(&p, &cluster, &cfg);

    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    // Identical message-level accounting — the same pure realization
    // function drives both drivers.
    assert_eq!(virt.net, real.net, "net accounting diverged");
    assert!(virt.net.dropped > 0, "test spec produced no drops: {:?}", virt.net);
    assert!(virt.net.duplicated > 0, "test spec produced no dups: {:?}", virt.net);
    assert_eq!(virt.net.sent, virt.net.delivered + virt.net.dropped);

    // Identical per-iteration inclusion decisions (rows align because both
    // drivers skip exactly the all-dropped iterations).
    assert_eq!(virt.recorder.len(), real.recorder.len());
    for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
        assert_eq!(rv.iter, rr.iter, "row iteration mismatch");
        assert_eq!(
            rv.included, rr.included,
            "iter {}: virtual included {}, real {}",
            rv.iter, rv.included, rr.included
        );
        assert_eq!(rv.dropped, rr.dropped, "iter {} dropped", rv.iter);
        assert_eq!(rv.duplicated, rr.duplicated, "iter {} duplicated", rv.iter);
    }
    assert_eq!(virt.total_contributions, real.total_contributions);

    // Same included shard sets + same fold order ⇒ matching θ.
    let diff = max_theta_diff(&virt.theta, &real.theta);
    assert!(diff < 1e-5, "theta diverged: max diff {diff}");
}

#[test]
fn parity_stale_admissions_virtual_matches_threaded() {
    // Acceptance (event-engine refactor): the virtual driver now produces
    // nonzero `Admission::Stale` counts — a reply out-living its iteration
    // window — and they must equal the threaded driver's under the same
    // lossy spec.
    //
    // Trace design: workers 2 and 3 sit behind chronically slow, lossy
    // *uplinks* (40/60 ms on a ~5–10 ms barrier — per-direction asymmetry,
    // the Work broadcast down is instant), so each reply they send lands
    // several iterations late.  They participate only in one-iteration
    // bursts (join@k, leave@k+1) with idle gaps long enough that each
    // burst puts exactly one reply per slow worker in flight in *both*
    // drivers — the threaded slave is guaranteed idle again before the
    // next burst — and every delivered one classifies Stale.  Workers 0
    // and 1 keep clean links so the barrier always closes on them (no
    // skipped iterations, and the slow replies are never admitted); the
    // per-message fates are the same pure function of (seed, worker, iter)
    // in both drivers, so delivered/dropped — and hence the stale totals —
    // agree exactly.
    use hybriditer::cluster::{ElasticEvent, ElasticKind};
    use hybriditer::net::LinkDir;
    use hybriditer::straggler::DelayModel;

    let m = 4;
    let p = problem(m);
    let iters = 90;
    let slow_up = |secs: f64| LinkModel {
        drop_prob: 0.25,
        up: Some(LinkDir {
            latency: DelayModel::Constant { secs },
            drop_prob: 0.25,
        }),
        ..LinkModel::ideal()
    };
    let net = NetSpec::ideal()
        .with_override(2, slow_up(0.04))
        .with_override(3, slow_up(0.06));
    let mut events = Vec::new();
    for burst in [0u64, 15, 30, 45, 60, 75] {
        for w in [2usize, 3] {
            if burst > 0 {
                events.push(ElasticEvent { iter: burst, worker: w, kind: ElasticKind::Join });
            }
            events.push(ElasticEvent { iter: burst + 1, worker: w, kind: ElasticKind::Leave });
        }
    }
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        // Deterministic, well-separated fast-worker latencies: worker 1's
        // 2× slow factor is what the threaded barrier waits on, keeping
        // wall-clock windows ≈ 5 ms so the slow uplink replies land
        // iterations later in both drivers.
        slow_nodes: vec![(1, 2.0)],
        seed: 27,
        ..ClusterSpec::default()
    }
    .with_net(net)
    .with_elastic(hybriditer::cluster::ElasticSchedule::new(events), 0);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 2 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters);

    let (virt, real) = run_both(&p, &cluster, &cfg);

    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    // Same pure per-message realizations → identical message accounting.
    assert_eq!(virt.net, real.net, "net accounting diverged");
    assert!(virt.net.dropped > 0, "lossy spec produced no drops");

    // The headline: the virtual driver produces stale admissions in
    // virtual time, and exactly as many as the threaded driver.
    let virt_stale: usize = virt.recorder.rows().iter().map(|r| r.stale).sum();
    let real_stale: usize = real.recorder.rows().iter().map(|r| r.stale).sum();
    assert!(virt_stale > 0, "virtual driver produced no stale admissions");
    assert_eq!(
        virt_stale, real_stale,
        "stale counts diverged: virtual {virt_stale}, real {real_stale}"
    );
    assert_eq!(virt.total_abandoned, real.total_abandoned);
    assert_eq!(virt.total_contributions, real.total_contributions);

    // Same inclusion decisions per recorded iteration, same θ.
    assert_eq!(virt.recorder.len(), real.recorder.len());
    for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
        assert_eq!(rv.iter, rr.iter, "row iteration mismatch");
        assert_eq!(
            rv.included, rr.included,
            "iter {}: virtual included {}, real {}",
            rv.iter, rv.included, rr.included
        );
        assert_eq!(rv.dropped, rr.dropped, "iter {} dropped", rv.iter);
    }
    let diff = max_theta_diff(&virt.theta, &real.theta);
    assert!(diff < 1e-5, "theta diverged: max diff {diff}");
}

#[test]
fn parity_async_lost_roundtrip_retransmits_held_theta() {
    // Regression (retransmit parity): when the network loses an async
    // roundtrip, the threaded master must resend the *held* θ snapshot and
    // keep `version_given` — the virtual driver's worker retries from the
    // θ it already has.  The old behaviour (fresh snapshot + refreshed
    // version) silently reset the eventual reply's staleness.
    //
    // Trace design: two workers; worker 1 sits behind a scripted partition
    // covering its first three attempt tags, so attempts 0–2 are lost
    // *deterministically* (no RNG involved) and attempt 3 delivers.  Worker
    // 0 keeps a clean link and a 20 ms cadence; worker 1's 66 ms cadence
    // means ~3 master updates elapse per lost attempt.  With the held-θ
    // retransmit, worker 1's first applied reply carries staleness ≈ 12
    // (every update since its *initial* dispatch); with the fresh-θ bug it
    // would only count the updates of the final roundtrip (≈ 3).  The mean
    // staleness over 14 updates separates the two regimes by ~4×, far
    // beyond wall-clock ordering jitter.
    let m = 2;
    let p = problem(m);
    let net = NetSpec {
        partitions: NetSpec::parse_partitions("1@0..3").unwrap(),
        ..NetSpec::ideal()
    };
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.02,
        slow_nodes: vec![(1, 3.3)],
        seed: 11,
        ..ClusterSpec::default()
    }
    .with_net(net);
    let cfg = RunConfig {
        mode: SyncMode::Async { damping: 0.5 },
        optimizer: OptimizerKind::sgd(0.5),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(14);

    let (virt, real) = run_both(&p, &cluster, &cfg);
    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);
    assert!(virt.net.dropped > 0, "partition produced no drops: {:?}", virt.net);
    assert!(real.net.dropped > 0, "partition produced no drops: {:?}", real.net);

    // The held-θ retransmit keeps staleness accruing across lost attempts
    // in *both* drivers; the fresh-θ bug pins the threaded mean near 0.2.
    let vs = virt.mean_staleness.expect("virtual made updates");
    let rs = real.mean_staleness.expect("real made updates");
    assert!(vs > 0.6, "virtual mean staleness collapsed: {vs}");
    assert!(rs > 0.6, "threaded retransmit reset staleness: {rs}");
    assert!(
        (vs - rs).abs() < 0.3,
        "staleness accounting diverged: virtual {vs}, real {rs}"
    );
}

#[test]
fn parity_blocked_lossy_net_same_block_fates_and_theta() {
    // Tentpole acceptance: with block admission active (dim 16 chunked
    // into 4 blocks) over a lossy + duplicating net, both drivers realize
    // the *same per-block fates* — identical delivered/dropped block
    // counts and stale-block totals — make the same admission decisions,
    // and land on the same θ through the shared fraction-weighted fold.
    let m = 4;
    let p = problem(m);
    let iters = 30;
    let net = NetSpec {
        default_link: LinkModel {
            drop_prob: 0.25,
            dup_prob: 0.25,
            dup_lag: 0.0005,
            ..LinkModel::ideal()
        },
        block_size: 4,
        min_block_frac: 0.0,
        ..NetSpec::ideal()
    };
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 21,
        ..ClusterSpec::default()
    }
    .with_net(net);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 2 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(iters);

    let (virt, real) = run_both(&p, &cluster, &cfg);

    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    // Identical message- and block-level accounting.
    assert_eq!(virt.net, real.net, "net/block accounting diverged");
    assert!(virt.net.blocks_sent > 0, "blocking never engaged: {:?}", virt.net);
    assert!(virt.net.blocks_dropped > 0, "no block was ever lost: {:?}", virt.net);
    assert_eq!(
        virt.net.blocks_sent,
        virt.net.blocks_delivered + virt.net.blocks_dropped
    );
    assert_eq!(
        virt.stale_blocks, real.stale_blocks,
        "stale-block admission diverged"
    );

    // Same admission decisions and delivered-block rows per iteration.
    assert_eq!(virt.recorder.len(), real.recorder.len());
    for (rv, rr) in virt.recorder.rows().iter().zip(real.recorder.rows()) {
        assert_eq!(rv.iter, rr.iter, "row iteration mismatch");
        assert_eq!(rv.included, rr.included, "iter {} included", rv.iter);
        assert_eq!(rv.dropped, rr.dropped, "iter {} dropped", rv.iter);
        assert_eq!(rv.blocks, rr.blocks, "iter {} delivered blocks", rv.iter);
    }
    assert_eq!(virt.total_contributions, real.total_contributions);

    // Same masks through the shared fraction-weighted fold ⇒ matching θ.
    let diff = max_theta_diff(&virt.theta, &real.theta);
    assert!(diff < 1e-5, "theta diverged: max diff {diff}");
}

#[test]
fn blocked_single_block_reproduces_unblocked_run_bitwise() {
    // Acceptance: `block_size = 0` (blocking off) and `block_size = ∞`
    // (one block spanning the reply) must reproduce the pre-block
    // admission decisions and θ bit for bit, under both ideal and lossy
    // nets — the single-block fate *is* the legacy binary delivery
    // decision.  An ideal net with real chunking (4 blocks) is also inert:
    // every block of every reply delivers, so only the accounting grows.
    let m = 4;
    let p = problem(m);
    let lossy_link = LinkModel {
        drop_prob: 0.25,
        dup_prob: 0.25,
        dup_lag: 0.0005,
        ..LinkModel::ideal()
    };
    let mk_cluster = |block_size: usize, lossy: bool| {
        let net = NetSpec {
            default_link: if lossy { lossy_link.clone() } else { LinkModel::ideal() },
            block_size,
            ..NetSpec::ideal()
        };
        ClusterSpec {
            workers: m,
            base_compute: 0.005,
            slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
            seed: 21,
            ..ClusterSpec::default()
        }
        .with_net(net)
    };
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 2 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(30);
    let run = |cluster: &ClusterSpec| {
        let mut pool = p.native_pool();
        sim::run_virtual(&mut pool, cluster, &cfg, &NoEval).unwrap()
    };

    for lossy in [false, true] {
        let off = run(&mk_cluster(0, lossy));
        // dim = 16, so a 1 MiB block size collapses to a single block.
        let one = run(&mk_cluster(1 << 20, lossy));
        assert_eq!(off.theta, one.theta, "lossy={lossy}: theta bits diverged");
        assert_eq!(off.net, one.net, "lossy={lossy}: accounting diverged");
        assert_eq!(off.net.blocks_sent, 0, "single-block runs must not count blocks");
        assert_eq!(off.stale_blocks, one.stale_blocks);
        assert_eq!(off.recorder.len(), one.recorder.len());
        for (ra, rb) in off.recorder.rows().iter().zip(one.recorder.rows()) {
            assert_eq!(ra.iter, rb.iter);
            assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "lossy={lossy} iter {}", ra.iter);
            assert_eq!(ra.time.to_bits(), rb.time.to_bits(), "lossy={lossy} iter {}", ra.iter);
            assert_eq!(ra.included, rb.included);
            assert_eq!(ra.blocks, rb.blocks);
        }
    }

    // Ideal net + real chunking: θ identical to the unblocked ideal run;
    // the block counters fill in (every block delivered, none dropped).
    let ideal_off = run(&mk_cluster(0, false));
    let ideal_blocked = run(&mk_cluster(4, false));
    assert_eq!(ideal_off.theta, ideal_blocked.theta, "ideal blocking perturbed θ");
    assert!(ideal_blocked.net.blocks_sent > 0);
    assert_eq!(ideal_blocked.net.blocks_dropped, 0);
    assert_eq!(
        ideal_blocked.net.blocks_sent,
        ideal_blocked.net.blocks_delivered
    );
}

// ---------------------------------------------------------------------
// Golden equivalence: fused kernel & scratch-arena refactor (perf pass)
// ---------------------------------------------------------------------

/// The fused single-pass kernel must match the two-pass reference on every
/// artifact config the bench suite uses — within 1e-5 by the acceptance
/// criterion, and in fact bit for bit (the fused kernel preserves the
/// reference's per-row and per-column fold orders exactly).
#[test]
fn golden_fused_kernel_matches_reference_on_all_configs() {
    use hybriditer::data::ComputePool;
    use hybriditer::util::rng::Pcg64;

    for spec in [
        KrrProblemSpec::small().with_machines(2),
        KrrProblemSpec::default_config().with_machines(2),
        KrrProblemSpec::wide().with_machines(2),
    ] {
        let p = KrrProblem::generate(&spec).unwrap();
        let mut fused = p.native_pool();
        let mut reference = p.reference_pool();
        let mut rng = Pcg64::seeded(spec.l as u64);
        let mut theta = vec![0.0f32; p.dim()];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        for w in 0..fused.n_workers() {
            let gf = fused.grad(w, &theta, 0).unwrap();
            let gr = reference.grad(w, &theta, 0).unwrap();
            let max_diff = max_theta_diff(&gf.grad, &gr.grad);
            assert!(
                max_diff <= 1e-5,
                "config {}: worker {w} fused vs reference diff {max_diff}",
                spec.config
            );
            // Stronger than the acceptance bound: exact bit equality.
            assert_eq!(gf.grad, gr.grad, "config {}: grad bits diverged", spec.config);
            assert_eq!(
                gf.loss_sum.unwrap().to_bits(),
                gr.loss_sum.unwrap().to_bits(),
                "config {}: loss bits diverged",
                spec.config
            );
            assert_eq!(gf.examples, gr.examples);
        }
    }
}

/// `run_virtual` trajectories must be *bit-identical* before/after the
/// perf pass: the reference pool reproduces the seed's behaviour (two-pass
/// kernel, fresh allocation per call), the native pool runs the fused
/// kernel through the scratch arena — θ and every recorded row must agree
/// exactly, across straggler abandonment, elastic churn, and the
/// staleness-damped reuse ablation.
#[test]
fn golden_theta_trajectory_bit_identical_reference_vs_fused() {
    let m = 6;
    let p = problem(m);
    let base = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        delay: hybriditer::straggler::DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
        seed: 31,
        ..ClusterSpec::default()
    };
    let scenarios: Vec<(ClusterSpec, RunConfig)> = vec![
        (
            base.clone(),
            RunConfig {
                mode: SyncMode::Hybrid { gamma: 4 },
                optimizer: OptimizerKind::sgd(0.8),
                loss_form: LossForm::krr(p.spec.lambda),
                eval_every: 0,
                record_every: 1,
                ..RunConfig::default()
            }
            .with_iters(60),
        ),
        (
            base.clone()
                .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 4], 10, 25), 1),
            RunConfig {
                mode: SyncMode::Hybrid { gamma: 4 },
                optimizer: OptimizerKind::sgd(0.8),
                loss_form: LossForm::krr(p.spec.lambda),
                eval_every: 0,
                record_every: 1,
                ..RunConfig::default()
            }
            .with_iters(60),
        ),
        (
            base.clone(),
            RunConfig {
                mode: SyncMode::Hybrid { gamma: 3 },
                optimizer: OptimizerKind::sgd(0.8),
                aggregator: hybriditer::coordinator::AggregatorKind::StalenessDamped {
                    rho: 0.5,
                },
                loss_form: LossForm::krr(p.spec.lambda),
                eval_every: 0,
                record_every: 1,
                ..RunConfig::default()
            }
            .with_iters(60),
        ),
        (
            base,
            RunConfig {
                mode: SyncMode::Bsp,
                optimizer: OptimizerKind::sgd(0.8),
                loss_form: LossForm::krr(p.spec.lambda),
                eval_every: 0,
                record_every: 1,
                ..RunConfig::default()
            }
            .with_iters(40),
        ),
    ];
    for (i, (cluster, cfg)) in scenarios.iter().enumerate() {
        let mut fused_pool = p.native_pool();
        let fused = sim::run_virtual(&mut fused_pool, cluster, cfg, &NoEval).unwrap();
        let mut ref_pool = p.reference_pool();
        let reference = sim::run_virtual(&mut ref_pool, cluster, cfg, &NoEval).unwrap();
        assert_eq!(
            fused.theta, reference.theta,
            "scenario {i}: theta bits diverged"
        );
        assert_eq!(fused.recorder.len(), reference.recorder.len(), "scenario {i}");
        for (rf, rr) in fused.recorder.rows().iter().zip(reference.recorder.rows()) {
            assert_eq!(rf.iter, rr.iter, "scenario {i}");
            assert_eq!(rf.loss.to_bits(), rr.loss.to_bits(), "scenario {i} iter {}", rf.iter);
            assert_eq!(
                rf.grad_norm.to_bits(),
                rr.grad_norm.to_bits(),
                "scenario {i} iter {}",
                rf.iter
            );
            assert_eq!(rf.included, rr.included, "scenario {i} iter {}", rf.iter);
            assert_eq!(rf.time.to_bits(), rr.time.to_bits(), "scenario {i} iter {}", rf.iter);
        }
        assert_eq!(fused.total_abandoned, reference.total_abandoned, "scenario {i}");
    }
}

// ---------------------------------------------------------------------
// Trace-parity oracles: the flight recorder as a cross-driver invariant
// ---------------------------------------------------------------------

/// Run both drivers with a [`JournalSink`] attached and hand back the
/// journals alongside the reports.
fn run_both_traced(
    p: &KrrProblem,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
) -> (RunReport, JournalSink, RunReport, JournalSink) {
    let mut pool = p.native_pool();
    let mut vsink = JournalSink::new();
    let virt = sim::run_virtual_traced(&mut pool, cluster, cfg, &NoEval, &mut vsink).unwrap();
    let coord = Coordinator::new(cluster.clone(), cfg.clone()).unwrap();
    let factory = NativeKrrFactory::for_problem(p);
    let mut rsink = JournalSink::new();
    let real = coord.run_real_traced(&factory, &NoEval, &mut rsink).unwrap();
    (virt, vsink, real, rsink)
}

/// Byte-identity with a readable failure: report the first diverging line
/// instead of dumping two whole journals into the assertion message.
fn assert_journals_identical(tag: &str, virt: &str, real: &str) {
    for (i, (lv, lr)) in virt.lines().zip(real.lines()).enumerate() {
        assert_eq!(lv, lr, "{tag}: journals diverge at line {i}");
    }
    assert_eq!(
        virt.lines().count(),
        real.lines().count(),
        "{tag}: journal lengths differ"
    );
    assert_eq!(virt, real, "{tag}: journals not byte-identical");
}

#[test]
fn trace_parity_ideal_elastic_byte_identical_journals() {
    // Tentpole oracle: on an ideal network both drivers must write the
    // *byte-identical* event journal once timestamps are normalized away —
    // same events, same (iter, worker) stamps, same order.  The elastic
    // trace exercises every taxonomy branch reachable without loss:
    // dispatches, deliveries, leave/join boundaries, rebalance cuts, and
    // barrier closes.  γ = M keeps every delivery inside its barrier, so
    // wall-clock jitter cannot reorder events across iterations.
    let m = 4;
    let p = problem(m);
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 5,
        ..ClusterSpec::default()
    }
    .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 3], 4, 8), 1);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: m },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(14);

    let (virt, vsink, real, rsink) = run_both_traced(&p, &cluster, &cfg);
    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    let vj = vsink.jsonl_normalized();
    let rj = rsink.jsonl_normalized();
    assert!(!vj.is_empty(), "virtual journal is empty");
    for ev in ["dispatch", "delivery", "join", "leave", "rebalance_cut", "barrier_close"] {
        assert!(vj.contains(ev), "virtual journal never recorded a {ev:?} event");
    }
    assert_journals_identical("ideal-elastic", &vj, &rj);

    // The run-level rollups agree too (they fold over the same records).
    let vt = virt.trace.expect("virtual run kept no trace summary");
    let rt = real.trace.expect("real run kept no trace summary");
    assert_eq!(vt.events, rt.events, "summary event counts diverged");
    assert_eq!(vt.barriers, rt.barriers, "summary barrier counts diverged");
    for (lv, lr) in vt.per_worker.iter().zip(&rt.per_worker) {
        assert_eq!(lv.worker, lr.worker);
        assert_eq!(lv.dispatches, lr.dispatches, "worker {}", lv.worker);
        assert_eq!(lv.deliveries, lr.deliveries, "worker {}", lv.worker);
    }

    // Tracing is purely observational: attaching a sink cannot move θ.
    let mut pool = p.native_pool();
    let untraced = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
    assert_eq!(virt.theta, untraced.theta, "attaching a sink perturbed θ bits");
}

#[test]
fn trace_parity_lossy_net_identical_fate_sequences() {
    // Tentpole oracle, lossy half: wall-clock arrival order differs across
    // drivers once the network drops and duplicates messages, but the
    // per-message *fates* (dispatch / drop / duplicate per (worker, iter))
    // are a pure function of the spec — both journals must agree on the
    // fate subsequence exactly, event for event.
    let m = 4;
    let p = problem(m);
    let net = NetSpec {
        default_link: LinkModel {
            drop_prob: 0.25,
            dup_prob: 0.25,
            dup_lag: 0.0005,
            ..LinkModel::ideal()
        },
        ..NetSpec::ideal()
    };
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 21,
        ..ClusterSpec::default()
    }
    .with_net(net);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 2 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(30);

    let (virt, vsink, real, rsink) = run_both_traced(&p, &cluster, &cfg);
    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    let vf = vsink.fate_jsonl();
    let rf = rsink.fate_jsonl();
    assert!(vf.contains("\"event\":\"drop\""), "lossy spec journaled no drops");
    assert!(vf.contains("\"event\":\"duplicate\""), "lossy spec journaled no dups");
    assert_journals_identical("lossy-fates", &vf, &rf);

    // Fate events cross-check the run-level accounting: every dispatch
    // sends its Work message, and each roundtrip surviving the down link
    // sends a Grad reply too.
    let dispatches = vf.matches("\"event\":\"dispatch\"").count() as u64;
    let down_drops = vf.matches("\"down\":true").count() as u64;
    assert_eq!(dispatches * 2 - down_drops, virt.net.sent, "fate events vs sent messages");
}

#[test]
fn trace_parity_blocked_lossy_net_identical_block_fates() {
    // Block admission: each reply is chunked into 4 blocks and the fate
    // events carry the delivered-block mask.  Both drivers re-realize the
    // same pure block fates, so the journals' fate subsequences — masks
    // included — must match byte for byte.
    let m = 4;
    let p = problem(m);
    let net = NetSpec {
        default_link: LinkModel {
            drop_prob: 0.25,
            dup_prob: 0.25,
            dup_lag: 0.0005,
            ..LinkModel::ideal()
        },
        block_size: 4,
        min_block_frac: 0.0,
        ..NetSpec::ideal()
    };
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 21,
        ..ClusterSpec::default()
    }
    .with_net(net);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: 2 },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(30);

    let (virt, vsink, real, rsink) = run_both_traced(&p, &cluster, &cfg);
    assert!(virt.status.is_healthy(), "virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "real: {:?}", real.status);

    let vf = vsink.fate_jsonl();
    let rf = rsink.fate_jsonl();
    assert!(
        vf.contains("\"event\":\"block_fate\""),
        "blocking never journaled a block fate"
    );
    assert!(vf.contains("\"delivered_mask\""), "block fates carry no masks");
    assert_journals_identical("blocked-fates", &vf, &rf);
    assert_eq!(virt.stale_blocks, real.stale_blocks, "stale-block admission diverged");
}

// ---------------------------------------------------------------------
// Recovery-policy parity: both drivers fire the same recoveries
// ---------------------------------------------------------------------

/// The canonical scheduled elastic trace (workers 1 and 3 leave at 4,
/// rejoin at 8) with a recovery policy installed.  Scheduled traces are
/// the cross-driver oracle surface: stochastic crashes draw from
/// driver-private RNG streams and cannot be compared.
fn recovery_scenario(
    m: usize,
    p: &KrrProblem,
    policy: hybriditer::recovery::RecoveryPolicy,
    checkpoint_every: u64,
) -> (ClusterSpec, RunConfig) {
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.005,
        slow_nodes: vec![(1, 2.0), (2, 3.0), (3, 4.0)],
        seed: 5,
        ..ClusterSpec::default()
    }
    .with_elastic(ElasticSchedule::crash_and_rejoin(&[1, 3], 4, 8), 1);
    let cfg = RunConfig {
        mode: SyncMode::Hybrid { gamma: m },
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(p.spec.lambda),
        eval_every: 0,
        record_every: 1,
        recovery: hybriditer::recovery::RecoveryConfig { policy, checkpoint_every },
        ..RunConfig::default()
    }
    .with_iters(14);
    (cluster, cfg)
}

/// Shared assertions for one policy: byte-identical normalized journals
/// (recovery events included), equal recovery rollups, bitwise θ.  Hands
/// the runs back for policy-specific follow-up assertions.
fn assert_recovery_parity(
    tag: &str,
    p: &KrrProblem,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
) -> (RunReport, JournalSink, RunReport, JournalSink) {
    let (virt, vsink, real, rsink) = run_both_traced(p, cluster, cfg);
    assert!(virt.status.is_healthy(), "{tag} virtual: {:?}", virt.status);
    assert!(real.status.is_healthy(), "{tag} real: {:?}", real.status);

    let vj = vsink.jsonl_normalized();
    let rj = rsink.jsonl_normalized();
    assert!(
        vj.contains("\"event\":\"recovery_start\""),
        "{tag}: virtual journal recorded no recovery_start"
    );
    assert!(
        vj.contains("\"event\":\"recovery_done\""),
        "{tag}: virtual journal recorded no recovery_done"
    );
    assert!(
        vj.contains(&format!("\"policy\":\"{}\"", cfg.recovery.policy.name())),
        "{tag}: recovery events carry the wrong policy tag"
    );
    assert_journals_identical(tag, &vj, &rj);

    assert!(virt.recoveries > 0, "{tag}: scheduled trace fired no recovery");
    assert_eq!(virt.recoveries, real.recoveries, "{tag}: recovery counts diverged");
    assert_eq!(
        virt.rollback_iters, real.rollback_iters,
        "{tag}: rollback accounting diverged"
    );
    assert_eq!(virt.theta, real.theta, "{tag}: θ bits diverged");
    (virt, vsink, real, rsink)
}

#[test]
fn trace_parity_recovery_rebalance() {
    // Rebalance fires on every membership perturbation: 2 leaves + 2
    // joins = 4 recoveries, zero rollback, and the forced replan keeps
    // both drivers on the same shard plan.
    let m = 4;
    let p = problem(m);
    let (cluster, cfg) =
        recovery_scenario(m, &p, hybriditer::recovery::RecoveryPolicy::Rebalance, 25);
    let (virt, _, real, _) = assert_recovery_parity("recovery-rebalance", &p, &cluster, &cfg);
    assert_eq!(virt.recoveries, 4, "2 leaves + 2 joins must each fire");
    assert_eq!(real.rollback_iters, 0, "rebalance never rolls back");
}

#[test]
fn trace_parity_recovery_partial_catchup() {
    // Partial recovery reconstructs the lost partitions at the rejoin:
    // both drivers must queue the same catch-ups (staleness = 4
    // iterations of downtime), compute them at the same θ over the same
    // post-rebalance assignment, and fold them through the
    // staleness-damped path identically.
    let m = 4;
    let p = problem(m);
    let (cluster, mut cfg) =
        recovery_scenario(m, &p, hybriditer::recovery::RecoveryPolicy::PartialRecovery, 25);
    cfg.aggregator = hybriditer::coordinator::AggregatorKind::StalenessDamped { rho: 0.5 };
    let (virt, _, real, _) = assert_recovery_parity("recovery-partial", &p, &cluster, &cfg);
    assert_eq!(virt.recoveries, 2, "one catch-up per rejoining worker");
    assert_eq!(virt.rollback_iters, 0, "partial recovery never rolls back");

    // The catch-up fold is live: a policy that abandons the same trace
    // lands on a different θ.
    let mut abandon_cfg = cfg.clone();
    abandon_cfg.recovery.policy = hybriditer::recovery::RecoveryPolicy::Abandon;
    let mut pool = p.native_pool();
    let abandoned = sim::run_virtual(&mut pool, &cluster, &abandon_cfg, &NoEval).unwrap();
    assert_ne!(
        real.theta, abandoned.theta,
        "catch-up contributions never reached the aggregator"
    );
}

#[test]
fn trace_parity_recovery_checkpoint_restore() {
    // Checkpoint-restore snapshots θ every 3 iterations; the two leaves
    // at iteration 4 each restore the iteration-3 snapshot (rollback 1).
    // Both drivers must take snapshots at the same cadence points and
    // restore bit-identical θ.
    let m = 4;
    let p = problem(m);
    let (cluster, cfg) =
        recovery_scenario(m, &p, hybriditer::recovery::RecoveryPolicy::CheckpointRestore, 3);
    let (virt, vsink, real, _) =
        assert_recovery_parity("recovery-checkpoint", &p, &cluster, &cfg);
    assert_eq!(virt.recoveries, 2, "each leave restores once");
    assert_eq!(virt.rollback_iters, 2, "leave@4 restores the iter-3 snapshot");
    assert_eq!(real.rollback_iters, 2);
    assert!(
        vsink.jsonl_normalized().contains("\"rollback\":1"),
        "recovery_done events carry no rollback depth"
    );
}
