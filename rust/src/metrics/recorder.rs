//! Per-iteration training metrics.

use crate::math::stats::Summary;

/// One coordinator iteration's record.
#[derive(Clone, Debug)]
pub struct IterRow {
    pub iter: u64,
    /// Elapsed time at the end of the iteration (virtual or wall seconds).
    pub time: f64,
    /// Training-loss estimate from the included shards (objective of eq. 2).
    pub loss: f64,
    /// Exact holdout/eval loss if evaluated this iteration.
    pub eval_loss: Option<f64>,
    /// `‖θ_t − θ*‖₂` when the exact solution is known (KRR).
    pub theta_err: Option<f64>,
    /// Gradient contributions aggregated this iteration.
    pub included: usize,
    /// Results abandoned this iteration (arrived after the barrier closed,
    /// or duplicate copies of an already-admitted result).
    pub abandoned: usize,
    /// Results abandoned as stale this iteration (arrivals carrying an
    /// older iteration number).  Both drivers produce these: the threaded
    /// master sees them on wall-clock, and the virtual engine's event heap
    /// lets a straggling reply out-live its iteration window and land in a
    /// later one (non-ideal nets only; see `docs/SIM.md`).
    pub stale: usize,
    /// Messages the network dropped this iteration.
    pub dropped: usize,
    /// Duplicate deliveries the network injected this iteration.
    pub duplicated: usize,
    /// Gradient blocks delivered this iteration (0 unless block admission
    /// chunks replies into more than one block — see `docs/NETWORK.md`).
    pub blocks: usize,
    /// Blocks claimed off stale arrivals this iteration (the late-block
    /// re-entry path of `docs/SIM.md`; 0 unless block admission is on).
    pub stale_blocks: usize,
    /// Workers alive at the end of the iteration.
    pub alive: usize,
    /// γ in effect this iteration (None for BSP/async).
    pub gamma: Option<usize>,
    /// L2 norm of the aggregated gradient.
    pub grad_norm: f64,
    /// Recovery-policy actions fired this iteration (restores,
    /// lost-partition reconstructions, forced replans); 0 under the
    /// default abandon policy.  See `docs/RECOVERY.md`.
    pub recoveries: usize,
    /// Iterations of progress rolled back by checkpoint restores this
    /// iteration (0 for the rollback-free policies).
    pub rollback_iters: u64,
}

/// Collects [`IterRow`]s and computes run-level summaries.
#[derive(Clone, Debug, Default)]
pub struct Recorder {
    rows: Vec<IterRow>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder { rows: Vec::new() }
    }

    pub fn push(&mut self, row: IterRow) {
        self.rows.push(row);
    }

    pub fn rows(&self) -> &[IterRow] {
        &self.rows
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn last(&self) -> Option<&IterRow> {
        self.rows.last()
    }

    pub fn final_loss(&self) -> f64 {
        self.rows.last().map(|r| r.loss).unwrap_or(f64::NAN)
    }

    pub fn total_time(&self) -> f64 {
        self.rows.last().map(|r| r.time).unwrap_or(0.0)
    }

    /// First time the loss estimate drops below `target`, if ever.
    pub fn time_to_loss(&self, target: f64) -> Option<f64> {
        self.rows.iter().find(|r| r.loss <= target).map(|r| r.time)
    }

    /// First iteration the loss estimate drops below `target`.
    pub fn iters_to_loss(&self, target: f64) -> Option<u64> {
        self.rows.iter().find(|r| r.loss <= target).map(|r| r.iter)
    }

    /// Summary of per-iteration durations.
    ///
    /// Rows are normally time-ordered, but stale-heavy async traces can
    /// record a row pair whose `time` fields are non-monotone; a negative
    /// duration would poison the mean, so each duration clamps at 0.
    pub fn iter_time_summary(&self) -> Option<Summary> {
        if self.rows.len() < 2 {
            return None;
        }
        let mut durs = Vec::with_capacity(self.rows.len() - 1);
        for w in self.rows.windows(2) {
            durs.push((w[1].time - w[0].time).max(0.0));
        }
        Some(Summary::of(&durs))
    }

    /// Fit the empirical Q-linear rate: slope of `ln ‖θ_t − θ*‖` vs `t`
    /// gives `ln q` (§3.3).  Returns `(q, r²)`.
    ///
    /// Partial-gradient noise gives the error a floor (`η²C²` in eq. 30);
    /// fitting through the floor would bias q̂ upward, so only the decay
    /// phase (rows with error > 2× the minimum achieved) enters the fit.
    pub fn qlinear_rate(&self) -> Option<(f64, f64)> {
        let errs: Vec<(u64, f64)> = self
            .rows
            .iter()
            .filter_map(|r| r.theta_err.filter(|e| *e > 1e-12).map(|e| (r.iter, e)))
            .collect();
        let min_err = errs
            .iter()
            .map(|(_, e)| *e)
            .fold(f64::INFINITY, f64::min);
        let cutoff = min_err * 2.0;
        let pts: Vec<(f64, f64)> = errs
            .iter()
            .take_while(|(_, e)| *e > cutoff)
            .map(|(it, e)| (*it as f64, e.ln()))
            .collect();
        if pts.len() < 4 {
            return None;
        }
        let xs: Vec<f64> = pts.iter().map(|p| p.0).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let (_, slope, r2) = crate::math::stats::linfit(&xs, &ys);
        Some((slope.exp(), r2))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(iter: u64, time: f64, loss: f64, err: Option<f64>) -> IterRow {
        IterRow {
            iter,
            time,
            loss,
            eval_loss: None,
            theta_err: err,
            included: 4,
            abandoned: 0,
            stale: 0,
            dropped: 0,
            duplicated: 0,
            blocks: 0,
            stale_blocks: 0,
            alive: 4,
            gamma: Some(4),
            grad_norm: 1.0,
            recoveries: 0,
            rollback_iters: 0,
        }
    }

    #[test]
    fn time_to_loss() {
        let mut rec = Recorder::new();
        rec.push(row(0, 0.1, 10.0, None));
        rec.push(row(1, 0.2, 5.0, None));
        rec.push(row(2, 0.3, 1.0, None));
        assert_eq!(rec.time_to_loss(5.0), Some(0.2));
        assert_eq!(rec.iters_to_loss(0.5), None);
        assert_eq!(rec.final_loss(), 1.0);
    }

    #[test]
    fn qlinear_rate_recovers_geometric_decay() {
        let mut rec = Recorder::new();
        let q = 0.9;
        for t in 0..50 {
            rec.push(row(t, t as f64, 1.0, Some(q_pow(q, t))));
        }
        let (qhat, r2) = rec.qlinear_rate().unwrap();
        assert!((qhat - q).abs() < 1e-6, "qhat={qhat}");
        assert!(r2 > 0.999);
    }

    fn q_pow(q: f64, t: u64) -> f64 {
        q.powi(t as i32)
    }

    #[test]
    fn iter_time_summary() {
        let mut rec = Recorder::new();
        for t in 0..11 {
            rec.push(row(t, t as f64 * 0.5, 1.0, None));
        }
        let s = rec.iter_time_summary().unwrap();
        assert_eq!(s.count, 10);
        assert!((s.mean - 0.5).abs() < 1e-9);
    }

    #[test]
    fn iter_time_summary_clamps_non_monotone_rows() {
        let mut rec = Recorder::new();
        rec.push(row(0, 0.0, 1.0, None));
        rec.push(row(1, 1.0, 1.0, None));
        rec.push(row(2, 0.25, 1.0, None)); // out-of-order stale row
        rec.push(row(3, 1.25, 1.0, None));
        let s = rec.iter_time_summary().unwrap();
        assert_eq!(s.count, 3);
        // durations: 1.0, clamp(-0.75)=0.0, 1.0 — mean 2/3, never negative
        assert!((s.mean - 2.0 / 3.0).abs() < 1e-12, "mean={}", s.mean);
    }
}
