//! Cluster model: topology spec, typed messages, membership tracking, and
//! the **elastic membership schedule**.
//!
//! The paper ran on a physical master/slave cluster; here the cluster is
//! simulated in-process (DESIGN.md §3): workers are OS threads in
//! [`crate::worker`] ("real" timing mode) or discrete-event entities in
//! [`crate::sim`] ("virtual" timing mode).  Both share this module's
//! specification, message, and membership types.
//!
//! # Elastic clusters
//!
//! The seed system's membership was monotone: workers could only leave
//! (crash) and their shards' data stopped contributing forever.  An
//! [`ElasticSchedule`] makes membership a first-class, *scripted* input:
//! deterministic leave/join events applied at iteration boundaries,
//! identically by both drivers.  Combined with
//! [`ClusterSpec::rebalance_every`] the coordinator re-plans shard
//! ownership over the live set ([`crate::data::plan_rebalance`]) so no
//! shard's rows are orphaned by churn.  Scheduled leaves model evictions /
//! network partitions (the worker process survives and can be re-admitted
//! by a later join); stochastic crashes from [`FailureModel`] still exist
//! and compose with the schedule.

pub mod membership;
pub mod message;

pub use membership::Membership;
pub use message::{MasterMsg, ShardGrad, WorkerMsg};

use crate::straggler::{DelayModel, FailureModel, StragglerProfile};
use crate::{Error, Result};

/// What a scheduled membership event does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticKind {
    /// The worker leaves the cluster at the event's iteration boundary
    /// (deterministic crash / eviction: it stops responding).
    Leave,
    /// The worker (re)joins at the event's iteration boundary and responds
    /// again from that iteration on.
    Join,
}

/// One scheduled membership change, applied at the start of `iter`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElasticEvent {
    pub iter: u64,
    pub worker: usize,
    pub kind: ElasticKind,
}

/// A deterministic membership trace: leave/join events sorted by iteration
/// (stable for same-iteration events, so `leave@k` followed by `join@k`
/// nets out alive — the "rejoined the iteration it was declared dead"
/// case).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ElasticSchedule {
    events: Vec<ElasticEvent>,
}

impl ElasticSchedule {
    pub fn new(mut events: Vec<ElasticEvent>) -> ElasticSchedule {
        events.sort_by_key(|e| e.iter);
        ElasticSchedule { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[ElasticEvent] {
        &self.events
    }

    /// Convenience: each listed worker leaves at `leave_at` and rejoins at
    /// `rejoin_at` (the F2 elastic scenario).
    pub fn crash_and_rejoin(workers: &[usize], leave_at: u64, rejoin_at: u64) -> ElasticSchedule {
        let mut events = Vec::with_capacity(workers.len() * 2);
        for &w in workers {
            events.push(ElasticEvent { iter: leave_at, worker: w, kind: ElasticKind::Leave });
            events.push(ElasticEvent { iter: rejoin_at, worker: w, kind: ElasticKind::Join });
        }
        ElasticSchedule::new(events)
    }

    /// Events due at iteration `iter`, in schedule order.
    pub fn at(&self, iter: u64) -> impl Iterator<Item = &ElasticEvent> {
        self.events.iter().filter(move |e| e.iter == iter)
    }

    /// Parse the `--join-schedule` syntax: comma-separated
    /// `<worker>:<leave|join>@<iter>` terms, e.g. `"2:leave@30,2:join@50"`.
    /// An empty string is the empty schedule.
    pub fn parse(text: &str) -> Result<ElasticSchedule> {
        let mut events = Vec::new();
        for term in text.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (worker, rest) = term.split_once(':').ok_or_else(|| {
                Error::Config(format!("bad elastic event '{term}' (want w:kind@iter)"))
            })?;
            let (kind, iter) = rest.split_once('@').ok_or_else(|| {
                Error::Config(format!("bad elastic event '{term}' (want w:kind@iter)"))
            })?;
            let worker: usize = worker.trim().parse().map_err(|_| {
                Error::Config(format!("bad worker index in elastic event '{term}'"))
            })?;
            let iter: u64 = iter.trim().parse().map_err(|_| {
                Error::Config(format!("bad iteration in elastic event '{term}'"))
            })?;
            let kind = match kind.trim() {
                "leave" => ElasticKind::Leave,
                "join" => ElasticKind::Join,
                other => {
                    return Err(Error::Config(format!(
                        "unknown elastic event kind '{other}' (want leave|join)"
                    )))
                }
            };
            events.push(ElasticEvent { iter, worker, kind });
        }
        Ok(ElasticSchedule::new(events))
    }

    /// Validate against the cluster size: worker indices must be in range,
    /// and the schedule alone must never evict *every* worker while later
    /// events are still pending — a fully evicted cluster ends the run
    /// (`ClusterDead`), so those later joins could never execute.  (This
    /// replays only scheduled events; stochastic crashes can still kill
    /// the cluster at runtime.)
    pub fn validate(&self, workers: usize) -> Result<()> {
        for e in &self.events {
            if e.worker >= workers {
                return Err(Error::Cluster(format!(
                    "elastic event names worker {} but cluster has {workers}",
                    e.worker
                )));
            }
        }
        let mut scheduled_out = vec![false; workers];
        let mut i = 0;
        while i < self.events.len() {
            let iter = self.events[i].iter;
            while i < self.events.len() && self.events[i].iter == iter {
                let e = &self.events[i];
                scheduled_out[e.worker] = e.kind == ElasticKind::Leave;
                i += 1;
            }
            if i < self.events.len() && scheduled_out.iter().all(|&o| o) {
                return Err(Error::Cluster(format!(
                    "elastic schedule evicts all {workers} workers at iteration \
                     {iter}; the run would end (ClusterDead) before the \
                     schedule's later events"
                )));
            }
        }
        Ok(())
    }
}

/// Per-run elastic state shared by both drivers: the shard ownership map,
/// the membership epoch the last rebalance saw, and the rebalance counter.
///
/// The *boundary protocol* — apply scheduled events, then re-plan if due —
/// lives in the event engine's boundary handler for the virtual drivers
/// (`crate::sim::engine`) and inline in the threaded master
/// (`crate::worker`); both are built from the primitives here
/// ([`ElasticRuntime::maybe_rebalance`], [`ElasticRuntime::replan_orphans`]),
/// so the drivers cannot drift apart on *when* a boundary plan is computed
/// or applied (see `tests/parity_drivers.rs`).  One deliberate asymmetry:
/// [`ElasticRuntime::replan_orphans`] — the mid-barrier repair for an
/// owner crashing after the boundary plan — runs only in the virtual
/// driver, which observes crashes *before* dispatching work; the threaded
/// master learns of a crash mid-collect, after work is already assigned,
/// so it repairs at the next boundary (its epoch-change trigger).
/// Stochastic-crash traces therefore remain outside the cross-driver
/// parity guarantee, as they already were.
pub struct ElasticRuntime {
    /// Which worker owns each shard.  Drivers read it for assignment and
    /// latency scaling; BSP-retry mutates it directly for permanent
    /// Hadoop-style reassignment.
    pub ownership: crate::data::OwnershipMap,
    last_epoch: u64,
    rebalances: u64,
    /// Per-worker relative hardware capacity (1.0 = baseline).
    capacity: Vec<f64>,
    /// Warm-up ramp length for scheduled rejoins, in boundaries (0 = off).
    warmup_iters: u64,
    /// Remaining warm-up boundaries per worker (0 = fully warmed).
    warmup_left: Vec<u64>,
    /// Whether the planner apportions by capacity (false = legacy level
    /// loads even on skewed hardware — the F2d ablation baseline).
    weighted: bool,
    /// Scratch for the planner's weight vector (capacity kept).
    weights: Vec<f64>,
}

impl ElasticRuntime {
    /// Identity ownership (shard `s` on worker `s`), epoch synced to the
    /// membership view, homogeneous capacity, no warm-up.
    pub fn new(membership: &Membership) -> ElasticRuntime {
        ElasticRuntime {
            ownership: crate::data::OwnershipMap::identity(membership.len()),
            last_epoch: membership.epoch(),
            rebalances: 0,
            capacity: vec![1.0; membership.len()],
            warmup_iters: 0,
            warmup_left: vec![0; membership.len()],
            weighted: true,
            weights: Vec::new(),
        }
    }

    /// Install the cluster's capacity model: per-worker relative capacity,
    /// the warm-up ramp length for scheduled rejoins, and whether the
    /// planner apportions by capacity.  Resets any warm-up in progress.
    /// With uniform capacities and `warmup_iters == 0` — the defaults —
    /// every plan is bit-for-bit the legacy planner's.
    pub fn configure_capacity(&mut self, capacity: Vec<f64>, warmup_iters: u64, weighted: bool) {
        assert_eq!(
            capacity.len(),
            self.capacity.len(),
            "capacity vector size mismatch"
        );
        assert!(
            capacity.iter().all(|&c| c > 0.0 && c.is_finite()),
            "capacities must be positive and finite"
        );
        self.capacity = capacity;
        self.warmup_iters = warmup_iters;
        self.weighted = weighted;
        self.warmup_left.fill(0);
    }

    /// A scheduled join re-admitted worker `w`: it starts its warm-up ramp
    /// (no-op when `warmup_iters == 0`).  Stochastic `rejoin_after`
    /// revivals do not ramp — only the deterministic elastic schedule does,
    /// so both drivers realize identical ramps.
    pub fn note_join(&mut self, w: usize) {
        self.warmup_left[w] = self.warmup_iters;
    }

    /// Advance every warm-up ramp by one boundary.  Called exactly once
    /// per boundary by both drivers, *before* that boundary's scheduled
    /// events are applied.
    pub fn tick_warmup(&mut self) {
        for l in self.warmup_left.iter_mut() {
            *l = l.saturating_sub(1);
        }
    }

    /// Warm-up ramp of worker `w` in (0, 1]: `1/(k+1)` at the boundary it
    /// rejoined, climbing linearly to `k/(k+1)` at its k-th warm-up
    /// boundary, then 1.
    pub fn ramp(&self, w: usize) -> f64 {
        if self.warmup_left[w] == 0 {
            1.0
        } else {
            ((self.warmup_iters - self.warmup_left[w]) as f64 + 1.0)
                / (self.warmup_iters as f64 + 1.0)
        }
    }

    /// Service-time dilation while a worker is cold: `1/ramp` (1.0 once
    /// warmed, so steady-state latency arithmetic is untouched).
    pub fn latency_scale(&self, w: usize) -> f64 {
        1.0 / self.ramp(w)
    }

    /// The apportionment weight the planner sees for worker `w`:
    /// `capacity · ramp` while warming, `capacity` once warm — or 1.0 with
    /// weighting disabled.
    pub fn plan_weight(&self, w: usize) -> f64 {
        if self.weighted {
            self.capacity[w] * self.ramp(w)
        } else {
            1.0
        }
    }

    /// Rebalance plans executed so far.
    pub fn rebalances(&self) -> u64 {
        self.rebalances
    }

    /// Re-plan shard ownership over the live set if a plan is due: the
    /// membership epoch changed since the last plan, or the fixed cadence
    /// hit.  `rebalance_every == 0` disables elastic rebalancing entirely
    /// (the seed behaviour).  Returns whether a non-empty plan was applied.
    pub fn maybe_rebalance(
        &mut self,
        iter: u64,
        rebalance_every: u64,
        membership: &Membership,
    ) -> Result<bool> {
        if rebalance_every == 0
            || (membership.epoch() == self.last_epoch && iter % rebalance_every != 0)
        {
            return Ok(false);
        }
        let applied = self.replan(membership)?;
        self.last_epoch = membership.epoch();
        Ok(applied)
    }

    /// Crash-during-rebalance repair: when a shard's owner died *after*
    /// this boundary's plan was applied — e.g. an adopter crashing in the
    /// same iteration it adopted orphaned shards — re-plan immediately
    /// inside the barrier instead of leaving the shards on a dead owner
    /// until the next boundary.  Cheap no-op when rebalancing is disabled
    /// or every owner is alive.
    pub fn replan_orphans(
        &mut self,
        rebalance_every: u64,
        membership: &Membership,
    ) -> Result<bool> {
        if rebalance_every == 0 {
            return Ok(false);
        }
        let orphaned = (0..self.ownership.shards())
            .any(|s| !membership.is_alive(self.ownership.owner(s)));
        if !orphaned || membership.alive() == 0 {
            return Ok(false);
        }
        let applied = self.replan(membership)?;
        self.last_epoch = membership.epoch();
        Ok(applied)
    }

    fn replan(&mut self, membership: &Membership) -> Result<bool> {
        let mut weights = std::mem::take(&mut self.weights);
        weights.clear();
        for w in 0..self.capacity.len() {
            weights.push(self.plan_weight(w));
        }
        let plan = crate::data::plan_rebalance_weighted(
            &self.ownership,
            &membership.alive_mask(),
            &weights,
        );
        self.weights = weights;
        if plan.is_empty() {
            return Ok(false);
        }
        self.ownership.apply(&plan).map_err(Error::Cluster)?;
        self.rebalances += 1;
        Ok(true)
    }
}

/// How iteration latency is realized.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimingMode {
    /// Worker threads actually sleep their injected delays; the master
    /// measures wall-clock.  Used by the examples that demonstrate real
    /// time savings.
    Real,
    /// Discrete-event simulation: latencies are bookkept, nothing sleeps.
    /// Deterministic and fast — the default for benches.
    Virtual,
}

/// The cluster an experiment runs on.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    /// Number of slave machines `M`.
    pub workers: usize,
    /// Baseline per-iteration compute time (virtual seconds) of a healthy
    /// worker.  In `Real` mode this also scales the injected sleeps.
    pub base_compute: f64,
    /// Stochastic extra delay, applied to every worker.
    pub delay: DelayModel,
    /// Chronically slow nodes: `(worker index, multiplier)`.
    pub slow_nodes: Vec<(usize, f64)>,
    /// Heterogeneous hardware: `(worker index, relative capacity)` — every
    /// unlisted worker is 1.0.  Service time scales by `1/capacity`, and
    /// with [`ClusterSpec::weighted_rebalance`] the planner apportions
    /// shards proportionally to capacity (see `docs/ELASTIC.md`).
    pub capacities: Vec<(usize, f64)>,
    /// Warm-up ramp length for scheduled rejoins, iterations (0 = rejoins
    /// are instantly at full capacity, the pre-capacity behaviour).  While
    /// warming, a worker's service time dilates by `1/ramp` and its
    /// apportionment weight shrinks by `ramp`, with
    /// `ramp = (j+1)/(warmup_iters+1)` on its j-th post-join boundary.
    pub warmup_iters: u64,
    /// Capacity-weighted shard apportionment (default).  `false` keeps the
    /// legacy level-load planner even on skewed hardware — the F2d
    /// ablation baseline.  Irrelevant on homogeneous clusters, where the
    /// weighted planner delegates to the legacy one bit-for-bit.
    pub weighted_rebalance: bool,
    /// Failure behaviour, applied to every worker (unless `failure_only`
    /// narrows it).
    pub failure: FailureModel,
    /// If non-empty, only these workers get the failure model (the rest are
    /// failure-free) — lets experiments kill *specific* nodes.
    pub failure_only: Vec<usize>,
    /// Master-side per-iteration overhead (aggregate + update), seconds.
    pub master_overhead: f64,
    /// Deterministic leave/join trace applied at iteration boundaries
    /// (empty = static membership, the seed behaviour).
    pub elastic: ElasticSchedule,
    /// Shard-rebalance cadence: `0` disables elastic rebalancing (the seed
    /// behaviour); `k > 0` re-plans ownership every `k` iterations *and*
    /// whenever the membership epoch changed since the last plan.
    pub rebalance_every: u64,
    /// Coordinator↔worker network model (loss, delay, duplication,
    /// scripted partitions).  [`crate::net::NetSpec::ideal`] — the default
    /// — reproduces pre-transport behaviour bit for bit.
    pub net: crate::net::NetSpec,
    /// Aggregation topology (star/tree/ring) the gradient replies travel
    /// ([`crate::agg`]).  [`crate::agg::AggSpec::star`] — the default —
    /// is the legacy single-coordinator fold, bit for bit.
    pub agg: crate::agg::AggSpec,
    /// RNG seed for all injected randomness (delays, failures, and the
    /// per-message network realizations).
    pub seed: u64,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            workers: 8,
            base_compute: 0.010,
            delay: DelayModel::None,
            slow_nodes: vec![],
            capacities: vec![],
            warmup_iters: 0,
            weighted_rebalance: true,
            failure: FailureModel::none(),
            failure_only: vec![],
            master_overhead: 0.0005,
            elastic: ElasticSchedule::default(),
            rebalance_every: 0,
            net: crate::net::NetSpec::ideal(),
            agg: crate::agg::AggSpec::star(),
            seed: 0x5eed,
        }
    }
}

impl ClusterSpec {
    /// Build each worker's [`StragglerProfile`].
    pub fn profiles(&self) -> Vec<StragglerProfile> {
        (0..self.workers)
            .map(|w| {
                let slow_factor = self
                    .slow_nodes
                    .iter()
                    .find(|(idx, _)| *idx == w)
                    .map(|(_, f)| *f)
                    .unwrap_or(1.0);
                let failure = if self.failure_only.is_empty() || self.failure_only.contains(&w)
                {
                    self.failure.clone()
                } else {
                    FailureModel::none()
                };
                StragglerProfile {
                    base_compute: self.base_compute,
                    slow_factor,
                    capacity: self.capacity_of(w),
                    delay: self.delay.clone(),
                    failure,
                }
            })
            .collect()
    }

    /// Relative capacity of worker `w` (1.0 unless listed in
    /// [`ClusterSpec::capacities`]).
    pub fn capacity_of(&self, w: usize) -> f64 {
        self.capacities
            .iter()
            .find(|(idx, _)| *idx == w)
            .map(|(_, c)| *c)
            .unwrap_or(1.0)
    }

    /// All per-worker capacities, indexed by worker.
    pub fn capacity_vec(&self) -> Vec<f64> {
        (0..self.workers).map(|w| self.capacity_of(w)).collect()
    }

    /// Parse the `--capacities` syntax: comma-separated `<worker>:<cap>`
    /// terms, e.g. `"8:0.25,9:0.5"`.  An empty string is the empty list.
    pub fn parse_capacities(text: &str) -> Result<Vec<(usize, f64)>> {
        let mut out = Vec::new();
        for term in text.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let (worker, cap) = term.split_once(':').ok_or_else(|| {
                Error::Config(format!("bad capacity entry '{term}' (want w:cap)"))
            })?;
            let worker: usize = worker.trim().parse().map_err(|_| {
                Error::Config(format!("bad worker index in capacity entry '{term}'"))
            })?;
            let cap: f64 = cap.trim().parse().map_err(|_| {
                Error::Config(format!("bad capacity value in entry '{term}'"))
            })?;
            if !(cap > 0.0 && cap.is_finite()) {
                return Err(Error::Config(format!(
                    "capacity of worker {worker} must be positive and finite, got {cap}"
                )));
            }
            out.push((worker, cap));
        }
        Ok(out)
    }

    /// Convenience: mark the last `n` workers as chronically `factor`× slow.
    pub fn with_slow_tail(mut self, n: usize, factor: f64) -> Self {
        assert!(n <= self.workers);
        self.slow_nodes = ((self.workers - n)..self.workers)
            .map(|w| (w, factor))
            .collect();
        self
    }

    /// Convenience: the last `n` workers run at relative capacity `cap`
    /// (the F2d mixed-hardware scenario).
    pub fn with_capacity_tail(mut self, n: usize, cap: f64) -> Self {
        assert!(n <= self.workers);
        self.capacities = ((self.workers - n)..self.workers).map(|w| (w, cap)).collect();
        self
    }

    /// Convenience: set the scheduled-rejoin warm-up ramp length.
    pub fn with_warmup(mut self, warmup_iters: u64) -> Self {
        self.warmup_iters = warmup_iters;
        self
    }

    /// Convenience: attach an elastic schedule and a rebalance cadence.
    pub fn with_elastic(mut self, schedule: ElasticSchedule, rebalance_every: u64) -> Self {
        self.elastic = schedule;
        self.rebalance_every = rebalance_every;
        self
    }

    /// Convenience: attach a network model.
    pub fn with_net(mut self, net: crate::net::NetSpec) -> Self {
        self.net = net;
        self
    }

    /// Convenience: attach an aggregation topology.
    pub fn with_agg(mut self, agg: crate::agg::AggSpec) -> Self {
        self.agg = agg;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_apply_slow_nodes() {
        let spec = ClusterSpec {
            workers: 4,
            slow_nodes: vec![(1, 8.0)],
            ..ClusterSpec::default()
        };
        let ps = spec.profiles();
        assert_eq!(ps.len(), 4);
        assert_eq!(ps[0].slow_factor, 1.0);
        assert_eq!(ps[1].slow_factor, 8.0);
    }

    #[test]
    fn slow_tail_marks_last_workers() {
        let spec = ClusterSpec {
            workers: 6,
            ..ClusterSpec::default()
        }
        .with_slow_tail(2, 4.0);
        assert_eq!(spec.slow_nodes, vec![(4, 4.0), (5, 4.0)]);
    }

    #[test]
    fn elastic_schedule_parses_and_sorts() {
        let s = ElasticSchedule::parse("2:join@50, 2:leave@30,0:leave@30").unwrap();
        assert_eq!(s.events().len(), 3);
        assert_eq!(
            s.events()[0],
            ElasticEvent { iter: 30, worker: 2, kind: ElasticKind::Leave }
        );
        assert_eq!(
            s.events()[1],
            ElasticEvent { iter: 30, worker: 0, kind: ElasticKind::Leave }
        );
        assert_eq!(
            s.events()[2],
            ElasticEvent { iter: 50, worker: 2, kind: ElasticKind::Join }
        );
        assert_eq!(s.at(30).count(), 2);
        assert_eq!(s.at(31).count(), 0);
        assert!(ElasticSchedule::parse("").unwrap().is_empty());
    }

    #[test]
    fn elastic_schedule_rejects_garbage() {
        assert!(ElasticSchedule::parse("nope").is_err());
        assert!(ElasticSchedule::parse("1:evaporate@3").is_err());
        assert!(ElasticSchedule::parse("x:leave@3").is_err());
        assert!(ElasticSchedule::parse("1:leave@y").is_err());
    }

    #[test]
    fn elastic_schedule_validates_worker_range() {
        let s = ElasticSchedule::parse("7:leave@1").unwrap();
        assert!(s.validate(8).is_ok());
        assert!(s.validate(7).is_err());
    }

    #[test]
    fn elastic_schedule_rejects_full_eviction_before_later_events() {
        // Evicting everyone with joins still pending can never replay: the
        // run ends ClusterDead at the full eviction.
        let s = ElasticSchedule::crash_and_rejoin(&[0, 1], 10, 20);
        assert!(s.validate(2).is_err());
        assert!(s.validate(3).is_ok());
        // Full eviction as the *final* act is allowed (run honestly ends).
        let s = ElasticSchedule::parse("0:leave@5,1:leave@5").unwrap();
        assert!(s.validate(2).is_ok());
        // Same-iteration leave+join nets out alive, so it is not a full
        // eviction even with events still pending after it.
        let s = ElasticSchedule::parse("0:leave@5,1:leave@5,1:join@5,0:join@9").unwrap();
        assert!(s.validate(2).is_ok());
    }

    #[test]
    fn elastic_runtime_rebalances_on_epoch_change_and_cadence() {
        let mut membership = Membership::new(4);
        let mut rt = ElasticRuntime::new(&membership);

        // Iter 0: no membership change, balanced → no plan even on the
        // cadence tick.
        assert!(!rt.maybe_rebalance(0, 1, &membership).unwrap());

        // Iter 2: worker 3 leaves → shard 3 adopted, plan applied.
        membership.mark_down(3);
        assert!(rt.maybe_rebalance(2, 1, &membership).unwrap());
        assert_eq!(membership.alive(), 3);
        assert_eq!(rt.ownership.load(3), 0);
        assert_eq!(rt.rebalances(), 1);

        // Iter 3: unchanged membership, already level → empty plan.
        assert!(!rt.maybe_rebalance(3, 1, &membership).unwrap());

        // Iter 5: worker 3 rejoins → load levels back onto worker 3.
        membership.mark_alive(3);
        assert!(rt.maybe_rebalance(5, 1, &membership).unwrap());
        assert_eq!(membership.alive(), 4);
        assert_eq!(rt.ownership.load(3), 1);
        assert_eq!(rt.rebalances(), 2);

        // Epoch bumps (down + straight back up) off the cadence: the
        // change triggers a re-plan *check*, but loads are level so the
        // plan is empty and nothing is counted.
        membership.mark_down(0);
        membership.mark_alive(0);
        assert!(!rt.maybe_rebalance(7, 10, &membership).unwrap());
        assert_eq!(rt.rebalances(), 2);
    }

    #[test]
    fn elastic_runtime_disabled_without_cadence() {
        let mut membership = Membership::new(3);
        let mut rt = ElasticRuntime::new(&membership);
        // rebalance_every = 0: membership changes never move ownership.
        membership.mark_down(2);
        assert!(!rt.maybe_rebalance(1, 0, &membership).unwrap());
        assert!(!rt.replan_orphans(0, &membership).unwrap());
        assert_eq!(membership.alive(), 2);
        assert_eq!(rt.ownership.load(2), 1);
        assert_eq!(rt.rebalances(), 0);
    }

    #[test]
    fn replan_orphans_repairs_adopter_crash_in_same_boundary() {
        // Worker 3 leaves at a boundary; its shard is adopted by worker 0
        // (least-loaded, lowest index).  Worker 0 then crashes *in the same
        // iteration* — before the fix its shards stayed on the dead adopter
        // until the next boundary's re-plan; replan_orphans repairs the map
        // immediately inside the barrier.
        let mut membership = Membership::new(4);
        let mut rt = ElasticRuntime::new(&membership);
        membership.mark_down(3);
        assert!(rt.maybe_rebalance(5, 1, &membership).unwrap());
        assert_eq!(rt.ownership.owner(3), 0);
        assert_eq!(rt.ownership.load(0), 2);

        // The adopter crashes after the boundary plan was applied.
        membership.mark_down(0);
        assert!(rt.replan_orphans(1, &membership).unwrap());
        for s in 0..4 {
            assert!(
                membership.is_alive(rt.ownership.owner(s)),
                "shard {s} still owned by dead worker {}",
                rt.ownership.owner(s)
            );
        }
        assert_eq!(rt.rebalances(), 2);

        // With everyone healthy and level, replan_orphans is a no-op.
        membership.mark_alive(0);
        membership.mark_alive(3);
        rt.maybe_rebalance(6, 1, &membership).unwrap();
        assert!(!rt.replan_orphans(1, &membership).unwrap());
    }

    #[test]
    fn profiles_apply_capacities() {
        let spec = ClusterSpec {
            workers: 4,
            capacities: vec![(2, 0.25), (3, 2.0)],
            ..ClusterSpec::default()
        };
        let ps = spec.profiles();
        assert_eq!(ps[0].capacity, 1.0);
        assert_eq!(ps[2].capacity, 0.25);
        assert_eq!(ps[3].capacity, 2.0);
        assert_eq!(spec.capacity_vec(), vec![1.0, 1.0, 0.25, 2.0]);
    }

    #[test]
    fn capacity_tail_marks_last_workers() {
        let spec = ClusterSpec { workers: 4, ..ClusterSpec::default() }
            .with_capacity_tail(2, 0.5);
        assert_eq!(spec.capacities, vec![(2, 0.5), (3, 0.5)]);
    }

    #[test]
    fn parse_capacities_accepts_and_rejects() {
        let caps = ClusterSpec::parse_capacities("8:0.25, 9:0.5").unwrap();
        assert_eq!(caps, vec![(8, 0.25), (9, 0.5)]);
        assert!(ClusterSpec::parse_capacities("").unwrap().is_empty());
        assert!(ClusterSpec::parse_capacities("nope").is_err());
        assert!(ClusterSpec::parse_capacities("x:1.0").is_err());
        assert!(ClusterSpec::parse_capacities("1:fast").is_err());
        assert!(ClusterSpec::parse_capacities("1:0").is_err());
        assert!(ClusterSpec::parse_capacities("1:-2").is_err());
    }

    #[test]
    fn warmup_ramp_climbs_linearly_then_saturates() {
        let membership = Membership::new(2);
        let mut rt = ElasticRuntime::new(&membership);
        rt.configure_capacity(vec![1.0, 0.5], 3, true);
        // Fully warmed: ramp 1, no dilation, weight = capacity.
        assert_eq!(rt.ramp(1), 1.0);
        assert_eq!(rt.latency_scale(1), 1.0);
        assert_eq!(rt.plan_weight(1), 0.5);
        // Rejoin: ramp starts at 1/(k+1) and climbs one step per boundary.
        rt.note_join(1);
        assert!((rt.ramp(1) - 0.25).abs() < 1e-12);
        assert!((rt.latency_scale(1) - 4.0).abs() < 1e-12);
        assert!((rt.plan_weight(1) - 0.125).abs() < 1e-12);
        rt.tick_warmup();
        assert!((rt.ramp(1) - 0.5).abs() < 1e-12);
        rt.tick_warmup();
        assert!((rt.ramp(1) - 0.75).abs() < 1e-12);
        rt.tick_warmup();
        assert_eq!(rt.ramp(1), 1.0);
        rt.tick_warmup(); // saturates, no underflow
        assert_eq!(rt.ramp(1), 1.0);
        // Warm-up never touches the unaffected worker.
        assert_eq!(rt.ramp(0), 1.0);
        // Disabled weighting flattens plan weights but not the ramp.
        rt.note_join(1);
        rt.configure_capacity(vec![1.0, 0.5], 3, false);
        assert_eq!(rt.plan_weight(1), 1.0);
    }

    #[test]
    fn weighted_replan_strips_slow_half() {
        // 2 of 4 workers at 0.25×: the capacity-weighted planner hands
        // their shards to the fast pair (quotas 1.6/0.4 → targets 2/0).
        let membership = Membership::new(4);
        let mut rt = ElasticRuntime::new(&membership);
        rt.configure_capacity(vec![1.0, 1.0, 0.25, 0.25], 0, true);
        assert!(rt.maybe_rebalance(0, 1, &membership).unwrap());
        assert_eq!(rt.ownership.loads(), vec![2, 2, 0, 0]);
        assert_eq!(rt.rebalances(), 1);
        // Fixpoint: the next boundary plans nothing.
        assert!(!rt.maybe_rebalance(1, 1, &membership).unwrap());
        // The ablation baseline keeps the legacy level layout.
        let mut rt = ElasticRuntime::new(&membership);
        rt.configure_capacity(vec![1.0, 1.0, 0.25, 0.25], 0, false);
        assert!(!rt.maybe_rebalance(0, 1, &membership).unwrap());
        assert_eq!(rt.ownership.loads(), vec![1, 1, 1, 1]);
    }

    #[test]
    fn crash_and_rejoin_builder() {
        let s = ElasticSchedule::crash_and_rejoin(&[1, 3], 10, 25);
        assert_eq!(s.events().len(), 4);
        assert_eq!(s.at(10).count(), 2);
        assert_eq!(s.at(25).count(), 2);
        assert!(s.at(10).all(|e| e.kind == ElasticKind::Leave));
        assert!(s.at(25).all(|e| e.kind == ElasticKind::Join));
    }
}
