"""L2: decoder-only transformer LM for the end-to-end training example.

A GPT-style pre-LN decoder with learned positional embeddings and a weight-
tied output head, written against a *flat ordered parameter list* so the
rust coordinator can treat the model as an opaque ``Vec<Vec<f32>>``:

  * ``param_specs(cfg)`` gives the canonical (name, shape) order;
  * ``init_params(cfg, seed)`` initializes that list;
  * ``lm_step(cfg)(tokens, *params)`` returns ``(loss, *grads)`` in the
    same order — one PJRT executable per LM config, executed by every
    data-parallel worker on its own microbatch.

The hybrid coordinator then aggregates the first-``gamma`` workers' grads
exactly as it does for KRR — the paper's technique is model-agnostic, and
this module is the "real workload" demonstration of that.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .shapes import LmConfig


def param_specs(cfg: LmConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Canonical flat parameter order: (name, shape) pairs.

    The rust side mirrors this order (it reads it from the manifest), so
    NEVER reorder — append only.
    """
    D, F, V, T = cfg.d_model, cfg.ff, cfg.vocab, cfg.seq
    specs: list[tuple[str, tuple[int, ...]]] = [
        ("embed", (V, D)),
        ("pos", (T, D)),
    ]
    for i in range(cfg.n_layer):
        p = f"layer{i}."
        specs += [
            (p + "ln1_scale", (D,)),
            (p + "ln1_bias", (D,)),
            (p + "wq", (D, D)),
            (p + "wk", (D, D)),
            (p + "wv", (D, D)),
            (p + "wo", (D, D)),
            (p + "ln2_scale", (D,)),
            (p + "ln2_bias", (D,)),
            (p + "w1", (D, F)),
            (p + "b1", (F,)),
            (p + "w2", (F, D)),
            (p + "b2", (D,)),
        ]
    specs += [("lnf_scale", (D,)), ("lnf_bias", (D,))]
    return specs


def init_params(cfg: LmConfig, seed: int = 0) -> list[np.ndarray]:
    """GPT-2-style init: N(0, 0.02), residual projections scaled by depth."""
    rng = np.random.default_rng(seed)
    out: list[np.ndarray] = []
    resid_scale = 1.0 / np.sqrt(2.0 * cfg.n_layer)
    for name, shape in param_specs(cfg):
        base = name.split(".")[-1]
        if base.endswith(("_scale",)):
            arr = np.ones(shape, np.float32)
        elif base.endswith(("_bias",)) or base in ("b1", "b2"):
            arr = np.zeros(shape, np.float32)
        else:
            arr = rng.normal(0.0, 0.02, size=shape).astype(np.float32)
            if base in ("wo", "w2"):
                arr *= resid_scale
        out.append(arr)
    return out


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(x, wq, wk, wv, wo, n_head: int):
    B, T, D = x.shape
    H = n_head
    hd = D // H

    def split(w):
        return (x @ w).reshape(B, T, H, hd).transpose(0, 2, 1, 3)

    q, k, v = split(wq), split(wk), split(wv)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd).astype(x.dtype)
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask, att, jnp.float32(-1e30))
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ wo


def _forward(cfg: LmConfig, tokens, params):
    """tokens: (B, T) int32 inputs. Returns (B, T, V) logits."""
    it = iter(params)
    embed = next(it)
    pos = next(it)
    x = embed[tokens] + pos[None, :, :]
    for _ in range(cfg.n_layer):
        ln1_s, ln1_b = next(it), next(it)
        wq, wk, wv, wo = next(it), next(it), next(it), next(it)
        ln2_s, ln2_b = next(it), next(it)
        w1, b1, w2, b2 = next(it), next(it), next(it), next(it)
        h = _layer_norm(x, ln1_s, ln1_b)
        x = x + _attention(h, wq, wk, wv, wo, cfg.n_head)
        h = _layer_norm(x, ln2_s, ln2_b)
        x = x + jax.nn.gelu(h @ w1 + b1) @ w2 + b2
    lnf_s, lnf_b = next(it), next(it)
    x = _layer_norm(x, lnf_s, lnf_b)
    return x @ embed.T  # weight-tied head


def loss_fn(cfg: LmConfig, tokens, params):
    """Next-token cross-entropy. tokens: (B, T+1) int32."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = _forward(cfg, inputs, params)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def lm_step(cfg: LmConfig):
    """AOT entry point: (tokens, *params) -> (loss, *grads)."""

    def step(tokens, *params):
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, tokens, list(ps))
        )(tuple(params))
        return (loss,) + tuple(grads)

    return step


def lm_loss(cfg: LmConfig):
    """AOT entry point: (tokens, *params) -> (loss,) — eval only."""

    def ev(tokens, *params):
        return (loss_fn(cfg, tokens, list(params)),)

    return ev


def example_args(cfg: LmConfig):
    """ShapeDtypeStructs matching lm_step's signature, for jax.jit().lower."""
    toks = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.int32)
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in param_specs(cfg)
    ]
    return [toks] + params


@functools.lru_cache(maxsize=None)
def jitted_loss(cfg: LmConfig):
    return jax.jit(lm_loss(cfg))
