//! Gradient aggregation policies (Algorithm 2 line 3 and ablations).

use crate::math::vec_ops;

/// How included gradients combine into the master's update direction.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggregatorKind {
    /// Paper default: plain mean of the γ included gradients.
    Mean,
    /// Weight by shard example counts (relevant when shards are uneven or a
    /// rejoined worker carries a partial shard).
    ExampleWeighted,
    /// DESIGN.md §6 "hybrid-reuse" ablation: also fold in gradients that
    /// arrived after the previous barrier closed, damped by
    /// `rho^staleness` (staleness in iterations).
    StalenessDamped { rho: f64 },
}

/// One gradient contribution.
#[derive(Clone, Copy)]
pub struct Contribution<'a> {
    pub grad: &'a [f32],
    pub examples: usize,
    /// 0 = computed for this iteration, k = k iterations old.
    pub staleness: u64,
}

/// Aggregate a contribution stream into `out` without materializing a
/// slice — the virtual driver's zero-alloc hot path feeds it an iterator
/// chained straight off its scratch arena.  Returns the effective weight
/// sum.  Panics on an empty stream (same contract as [`aggregate`]).
pub fn aggregate_iter<'a>(
    kind: AggregatorKind,
    contribs: impl IntoIterator<Item = Contribution<'a>>,
    out: &mut [f32],
) -> f64 {
    out.fill(0.0);
    let mut wsum = 0.0f64;
    let mut seen = 0usize;
    for c in contribs {
        seen += 1;
        let w = match kind {
            AggregatorKind::Mean => {
                if c.staleness > 0 {
                    0.0 // fresh-only: late results are abandoned
                } else {
                    1.0
                }
            }
            AggregatorKind::ExampleWeighted => {
                if c.staleness > 0 {
                    0.0
                } else {
                    c.examples as f64
                }
            }
            AggregatorKind::StalenessDamped { rho } => rho.powi(c.staleness as i32),
        };
        if w > 0.0 {
            vec_ops::axpy(w as f32, c.grad, out);
            wsum += w;
        }
    }
    assert!(seen > 0, "aggregate with no contributions");
    if wsum > 0.0 {
        vec_ops::scale(out, (1.0 / wsum) as f32);
    }
    wsum
}

/// Aggregate contributions into `out`. Returns the effective weight sum.
pub fn aggregate(kind: AggregatorKind, contribs: &[Contribution<'_>], out: &mut [f32]) -> f64 {
    aggregate_iter(kind, contribs.iter().copied(), out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c(grad: &[f32], staleness: u64) -> Contribution<'_> {
        Contribution {
            grad,
            examples: 10,
            staleness,
        }
    }

    #[test]
    fn mean_ignores_stale() {
        let g1 = vec![2.0, 0.0];
        let g2 = vec![0.0, 2.0];
        let stale = vec![100.0, 100.0];
        let mut out = vec![0.0; 2];
        let w = aggregate(
            AggregatorKind::Mean,
            &[c(&g1, 0), c(&g2, 0), c(&stale, 1)],
            &mut out,
        );
        assert_eq!(w, 2.0);
        assert_eq!(out, vec![1.0, 1.0]);
    }

    #[test]
    fn example_weighted() {
        let g1 = vec![1.0];
        let g2 = vec![4.0];
        let contribs = [
            Contribution { grad: &g1, examples: 30, staleness: 0 },
            Contribution { grad: &g2, examples: 10, staleness: 0 },
        ];
        let mut out = vec![0.0];
        aggregate(AggregatorKind::ExampleWeighted, &contribs, &mut out);
        // (30*1 + 10*4)/40 = 1.75
        assert!((out[0] - 1.75).abs() < 1e-6);
    }

    #[test]
    fn staleness_damped_includes_late() {
        let fresh = vec![1.0];
        let late = vec![3.0];
        let mut out = vec![0.0];
        let w = aggregate(
            AggregatorKind::StalenessDamped { rho: 0.5 },
            &[c(&fresh, 0), c(&late, 1)],
            &mut out,
        );
        // (1*1 + 0.5*3) / 1.5 = 5/3
        assert!((w - 1.5).abs() < 1e-12);
        assert!((out[0] - 5.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn single_contribution_passthrough() {
        let g = vec![0.5, -0.5];
        let mut out = vec![0.0; 2];
        aggregate(AggregatorKind::Mean, &[c(&g, 0)], &mut out);
        assert_eq!(out, g);
    }
}
