//! F2 — speedup vs straggler severity, and fault tolerance vs crash rate
//! (abstract: "high fault-tolerant", "dramatically reduce calculation
//! time ... can be used in many platforms").
//!
//! Part 1: sweep lognormal σ (straggler severity) and report hybrid's
//! time-per-iteration speedup over BSP.  Expected: speedup grows with σ
//! (the heavier the tail, the more the partial barrier saves); ≈1 at σ=0.
//!
//! Part 2: sweep per-iteration crash probability; report each policy's
//! terminal status and progress.  Expected: BSP-stall dies immediately,
//! BSP-retry survives with growing overhead, hybrid sails until the alive
//! count drops below γ.
//!
//! All three parts' sweep points run concurrently on the sweep engine
//! (`--threads N` overrides the pool size); each point is seed-determined,
//! so the tables match a serial run exactly.

use hybriditer::bench_harness::sweep::{ProblemCache, SweepEngine};
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::{ClusterSpec, ElasticSchedule};
use hybriditer::coordinator::{BspRecovery, LossForm, RunConfig, RunReport, RunStatus, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::{DelayModel, FailureModel};

const M: usize = 16;
const ITERS: u64 = 150;
const SEEDS: u64 = 3;

fn mean_time(
    cache: &ProblemCache,
    mode: SyncMode,
    delay: DelayModel,
    failure: FailureModel,
    recovery: BspRecovery,
) -> (f64, String, u64) {
    let spec = KrrProblemSpec::small().with_machines(M);
    let problem = cache.get(&spec);
    let mut times = Vec::new();
    let mut status = String::new();
    let mut iters_done = 0;
    for seed in 0..SEEDS {
        let cluster = ClusterSpec {
            workers: M,
            base_compute: 0.01,
            delay: delay.clone(),
            failure: failure.clone(),
            seed: 40 + seed,
            ..ClusterSpec::default()
        };
        let cfg = RunConfig {
            mode: mode.clone(),
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec.lambda),
            bsp_recovery: recovery,
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        times.push(rep.total_time());
        iters_done = iters_done.max(rep.recorder.len() as u64);
        status = match rep.status {
            RunStatus::Completed => "ok".into(),
            RunStatus::Converged { .. } => "ok".into(),
            RunStatus::Stalled { iter } => format!("stall@{iter}"),
            RunStatus::ClusterDead { iter } => format!("dead@{iter}"),
        };
    }
    (
        times.iter().sum::<f64>() / times.len() as f64,
        status,
        iters_done,
    )
}

fn main() {
    let engine = SweepEngine::from_env();
    println!(
        "F2: straggler severity sweep + fault tolerance — M={M}, {ITERS} iters, {SEEDS} seeds"
    );
    println!("sweep pool: {} threads\n", engine.threads());

    // Part 1: severity sweep.
    let gamma = M * 3 / 4;
    let mut t1 = Table::new(
        format!("F2a speedup vs lognormal sigma (gamma={gamma})"),
        &["sigma", "bsp_s", "hybrid_s", "async_s", "hybrid_speedup"],
    );
    let sigmas = [0.0, 0.5, 1.0, 1.5, 2.0];
    let severity = engine.run(&sigmas, |cache, &sigma| {
        let delay = if sigma == 0.0 {
            DelayModel::None
        } else {
            DelayModel::LogNormal { mu: -4.0, sigma }
        };
        let none = FailureModel::none();
        let (bsp, _, _) = mean_time(
            cache,
            SyncMode::Bsp,
            delay.clone(),
            none.clone(),
            BspRecovery::Stall,
        );
        let (hyb, _, _) = mean_time(
            cache,
            SyncMode::Hybrid { gamma },
            delay.clone(),
            none.clone(),
            BspRecovery::Stall,
        );
        let (asy, _, _) = mean_time(
            cache,
            SyncMode::Async { damping: 0.0 },
            delay,
            none,
            BspRecovery::Stall,
        );
        (bsp, hyb, asy)
    });
    for (&sigma, &(bsp, hyb, asy)) in sigmas.iter().zip(&severity) {
        t1.row(vec![
            f(sigma, 1),
            f(bsp, 2),
            f(hyb, 2),
            f(asy / M as f64, 2), // per equivalent-iteration
            f(bsp / hyb, 2),
        ]);
    }
    t1.print();
    t1.save_csv("f2a_severity_sweep").unwrap();

    // Part 2: crash-rate sweep.
    let mut t2 = Table::new(
        format!("F2b fault tolerance vs crash probability (gamma={})", M / 2),
        &["crash_prob", "bsp_stall", "bsp_retry_s", "hybrid_s", "hybrid_status"],
    );
    let probs = [0.0, 0.001, 0.005, 0.01, 0.02];
    let crash = engine.run(&probs, |cache, &p| {
        let failure = FailureModel {
            crash_prob: p,
            transient_prob: 0.0,
            rejoin_after: None,
        };
        let delay = DelayModel::LogNormal { mu: -4.0, sigma: 0.5 };
        let (_, stall_status, stall_iters) = mean_time(
            cache,
            SyncMode::Bsp,
            delay.clone(),
            failure.clone(),
            BspRecovery::Stall,
        );
        let (retry_t, _, _) = mean_time(
            cache,
            SyncMode::Bsp,
            delay.clone(),
            failure.clone(),
            BspRecovery::Retry { detect_timeout: 0.05 },
        );
        let (hyb_t, hyb_status, _) = mean_time(
            cache,
            SyncMode::Hybrid { gamma: M / 2 },
            delay,
            failure,
            BspRecovery::Stall,
        );
        (stall_status, stall_iters, retry_t, hyb_t, hyb_status)
    });
    for (&p, (stall_status, stall_iters, retry_t, hyb_t, hyb_status)) in probs.iter().zip(&crash) {
        t2.row(vec![
            f(p, 3),
            format!("{stall_status} ({stall_iters} iters)"),
            f(*retry_t, 2),
            f(*hyb_t, 2),
            hyb_status.clone(),
        ]);
    }
    t2.print();
    t2.save_csv("f2b_crash_sweep").unwrap();

    // Part 3: elastic churn — 2 of M workers leave at iteration 50 and
    // rejoin at 100.  Static is the no-churn reference; "orphaned" keeps
    // the seed behaviour (leavers' shards stop contributing); "rebalanced"
    // migrates them onto survivors and levels load after the rejoin.
    let gamma3 = M * 3 / 4;
    let mut t3 = Table::new(
        format!("F2c elastic churn: 2/{M} leave@50 join@100 (gamma={gamma3})"),
        &["policy", "time_s", "final_loss", "theta_err", "rebalances"],
    );
    let churn = ElasticSchedule::crash_and_rejoin(&[M - 2, M - 1], 50, 100);
    let policies = [
        ("static", ElasticSchedule::default(), 0u64),
        ("churn-orphaned", churn.clone(), 0),
        ("churn-rebalanced", churn.clone(), 1),
    ];
    let spec = KrrProblemSpec::small().with_machines(M);
    let churn_rows = engine.run(&policies, |cache, (_, elastic, rebalance_every)| {
        let problem = cache.get(&spec);
        let cluster = ClusterSpec {
            workers: M,
            base_compute: 0.01,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 0.5 },
            seed: 44,
            ..ClusterSpec::default()
        }
        .with_elastic(elastic.clone(), *rebalance_every);
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma: gamma3 },
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, problem.as_ref()).unwrap();
        (
            rep.total_time(),
            rep.final_loss(),
            rep.final_theta_err(),
            rep.rebalances,
        )
    });
    for ((name, _, _), (time, loss, err, rebalances)) in policies.iter().zip(&churn_rows) {
        t3.row(vec![
            name.to_string(),
            f(*time, 2),
            format!("{loss:.6}"),
            err.map(|e| format!("{e:.3e}")).unwrap_or_else(|| "-".into()),
            rebalances.to_string(),
        ]);
    }
    t3.print();
    t3.save_csv("f2c_elastic_churn").unwrap();

    // Part 4 (F2d): heterogeneous hardware — capacity skew × abandon rate
    // (γ), capacity-weighted vs. legacy level-load apportionment, plus the
    // cold-rejoin warm-up ramp.  Emits results/BENCH_f2_hetero.json.
    let spec_d = KrrProblemSpec::small().with_machines(M);
    let mut t4 = Table::new(
        format!("F2d hetero: {}/{M} workers at 1/skew capacity, rebalance_every=1", M / 2),
        &[
            "skew",
            "gamma",
            "weighted",
            "time_per_iter_s",
            "coverage_pct",
            "abandon_pct",
            "final_loss",
            "rebalances",
        ],
    );
    let mut skew_points: Vec<(f64, usize, bool)> = Vec::new();
    for &skew in &[1.0f64, 2.0, 4.0, 8.0] {
        for &gamma in &[M * 3 / 4, M] {
            for &weighted in &[true, false] {
                skew_points.push((skew, gamma, weighted));
            }
        }
    }
    struct HeteroCell {
        time_per_iter: f64,
        coverage_pct: f64,
        abandon_pct: f64,
        final_loss: f64,
        rebalances: u64,
    }
    let run_hetero = |cache: &ProblemCache, skew: f64, gamma: usize, weighted: bool, seed: u64| {
        let problem = cache.get(&spec_d);
        let cluster = ClusterSpec {
            workers: M,
            base_compute: 0.01,
            // Mild jitter so the tables are not perfectly degenerate, but
            // small against base_compute: the capacity signal dominates.
            delay: DelayModel::LogNormal { mu: -6.0, sigma: 0.5 },
            rebalance_every: 1,
            weighted_rebalance: weighted,
            seed: 90 + seed,
            ..ClusterSpec::default()
        }
        .with_capacity_tail(M / 2, 1.0 / skew);
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma },
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec_d.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap()
    };
    let hetero = engine.run(&skew_points, |cache, &(skew, gamma, weighted)| {
        let mut time = 0.0;
        let mut coverage = 0.0;
        let mut abandon = 0.0;
        let mut loss = 0.0;
        let mut rebalances = 0;
        for seed in 0..SEEDS {
            let rep = run_hetero(cache, skew, gamma, weighted, seed);
            let rows = rep.recorder.rows();
            time += rep.total_time() / rows.len().max(1) as f64;
            coverage += rows.iter().map(|r| r.included).sum::<usize>() as f64
                / (rows.len().max(1) * M) as f64;
            abandon += rep.abandon_rate();
            loss += rep.final_loss();
            rebalances = rebalances.max(rep.rebalances);
        }
        let n = SEEDS as f64;
        HeteroCell {
            time_per_iter: time / n,
            coverage_pct: coverage / n * 100.0,
            abandon_pct: abandon / n * 100.0,
            final_loss: loss / n,
            rebalances,
        }
    });
    for (&(skew, gamma, weighted), cell) in skew_points.iter().zip(&hetero) {
        t4.row(vec![
            f(skew, 0),
            gamma.to_string(),
            weighted.to_string(),
            format!("{:.5}", cell.time_per_iter),
            f(cell.coverage_pct, 1),
            f(cell.abandon_pct, 1),
            format!("{:.6}", cell.final_loss),
            cell.rebalances.to_string(),
        ]);
    }
    t4.print();
    t4.save_csv("f2d_hetero_skew").unwrap();

    // Warm-up ramp: half the cluster rejoins cold at iteration 100.  With
    // level-load planning the cold nodes get full shares immediately and
    // the γ=M barrier eats a (k+1)× latency spike; the capacity-weighted
    // planner ramps their share with the warm-up instead.
    let mut t5 = Table::new(
        format!("F2d warm-up: {}/{M} leave@50 rejoin@100 cold (gamma={M})", M / 2),
        &["warmup_iters", "weighted", "peak_post_join_s", "time_per_iter_s", "final_loss"],
    );
    let warm_points: Vec<(u64, bool)> = vec![(0, true), (8, true), (8, false)];
    let rejoiners: Vec<usize> = (M / 2..M).collect();
    let peak_post_join = |rep: &RunReport| {
        let rows = rep.recorder.rows();
        let mut peak = 0.0f64;
        for pair in rows.windows(2) {
            if (100..120).contains(&pair[1].iter) {
                peak = peak.max(pair[1].time - pair[0].time);
            }
        }
        peak
    };
    let warm = engine.run(&warm_points, |cache, &(warmup, weighted)| {
        let problem = cache.get(&spec_d);
        let cluster = ClusterSpec {
            workers: M,
            base_compute: 0.01,
            rebalance_every: 1,
            weighted_rebalance: weighted,
            seed: 97,
            ..ClusterSpec::default()
        }
        .with_elastic(ElasticSchedule::crash_and_rejoin(&rejoiners, 50, 100), 1)
        .with_warmup(warmup);
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma: M },
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec_d.lambda),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(ITERS);
        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
        let rows = rep.recorder.rows().len().max(1);
        (peak_post_join(&rep), rep.total_time() / rows as f64, rep.final_loss())
    });
    for (&(warmup, weighted), &(peak, tpi, loss)) in warm_points.iter().zip(&warm) {
        t5.row(vec![
            warmup.to_string(),
            weighted.to_string(),
            format!("{peak:.5}"),
            format!("{tpi:.5}"),
            format!("{loss:.6}"),
        ]);
    }
    t5.print();
    t5.save_csv("f2d_warmup_rejoin").unwrap();

    // Machine-readable trajectory point: the 4×-skew full-coverage headline
    // (both policies at γ=M include every shard and abandon nothing, so
    // the comparison is at equal — zero — abandon rate) plus the warm-up
    // spike ratio.
    let pick = |skew: f64, gamma: usize, weighted: bool| -> &HeteroCell {
        skew_points
            .iter()
            .position(|&p| p == (skew, gamma, weighted))
            .map(|i| &hetero[i])
            .expect("headline cell")
    };
    let w4 = pick(4.0, M, true);
    let u4 = pick(4.0, M, false);
    let speedup = u4.time_per_iter / w4.time_per_iter;
    let spike_ratio = warm[2].0 / warm[1].0.max(1e-12);
    let cell_json = |(&(skew, gamma, weighted), c): (&(f64, usize, bool), &HeteroCell)| {
        format!(
            "    {{\"skew\": {skew}, \"gamma\": {gamma}, \"weighted\": {weighted}, \
             \"time_per_iter_s\": {:.6}, \"coverage_pct\": {:.1}, \"abandon_pct\": {:.1}, \
             \"final_loss\": {:.6}, \"rebalances\": {}}}",
            c.time_per_iter, c.coverage_pct, c.abandon_pct, c.final_loss, c.rebalances
        )
    };
    let skew_json: Vec<String> = skew_points.iter().zip(&hetero).map(cell_json).collect();
    let warm_json: Vec<String> = warm_points
        .iter()
        .zip(&warm)
        .map(|(&(warmup, weighted), &(peak, tpi, loss))| {
            format!(
                "    {{\"warmup_iters\": {warmup}, \"weighted\": {weighted}, \
                 \"peak_post_join_s\": {peak:.6}, \"time_per_iter_s\": {tpi:.6}, \
                 \"final_loss\": {loss:.6}}}"
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"f2_hetero\",\n  \"machines\": {M},\n  \"iters\": {ITERS},\n  \
         \"seeds\": {SEEDS},\n  \"headline\": {{\n    \"skew\": 4.0,\n    \"gamma\": {M},\n    \
         \"weighted_time_per_iter_s\": {:.6},\n    \"unweighted_time_per_iter_s\": {:.6},\n    \
         \"weighted_speedup\": {speedup:.3},\n    \"warmup_spike_unweighted_s\": {:.6},\n    \
         \"warmup_spike_weighted_s\": {:.6},\n    \"warmup_spike_ratio\": {spike_ratio:.3}\n  \
         }},\n  \"skew_points\": [\n{}\n  ],\n  \"warmup_points\": [\n{}\n  ]\n}}\n",
        w4.time_per_iter,
        u4.time_per_iter,
        warm[2].0,
        warm[1].0,
        skew_json.join(",\n"),
        warm_json.join(",\n")
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/BENCH_f2_hetero.json", json).unwrap();
    println!(
        "\nheadline: 4x-skew half-slow cluster at gamma={M}: weighted {:.5}s/iter vs \
         unweighted {:.5}s/iter (x{speedup:.2} at equal 0% abandon); cold-rejoin spike \
         {:.5}s -> {:.5}s (x{spike_ratio:.2})",
        w4.time_per_iter, u4.time_per_iter, warm[2].0, warm[1].0
    );
    println!("trajectory point -> results/BENCH_f2_hetero.json");

    // Part 6 (F2e): self-healing recovery policies — policy × crash rate
    // × γ, supervisor respawn on for the self-healing policies.  Overhead
    // is measured in iteration-equivalents: rolled-back iterations
    // (checkpoint-restore) plus catch-up recomputes at 1/M of an
    // iteration each (partial recovery).  Emits
    // results/BENCH_f2_recovery.json.
    let spec_e = KrrProblemSpec::small().with_machines(M);
    let ckpt_every = 10u64;
    let mut t6 = Table::new(
        format!("F2e recovery policies (rebalance_every=1, checkpoint_every={ckpt_every})"),
        &[
            "policy",
            "crash_prob",
            "gamma",
            "time_per_iter_s",
            "final_loss",
            "recoveries",
            "rollback_iters",
            "overhead_iters",
            "status",
        ],
    );
    let rec_policies = ["abandon", "rebalance", "partial-recovery", "checkpoint-restore"];
    let mut rec_points: Vec<(&str, f64, usize)> = Vec::new();
    for &pol in &rec_policies {
        for &prob in &[0.0f64, 0.005, 0.02] {
            for &gamma in &[M * 3 / 4, M] {
                rec_points.push((pol, prob, gamma));
            }
        }
    }
    struct RecCell {
        time_per_iter: f64,
        final_loss: f64,
        recoveries: f64,
        rollback_iters: f64,
        overhead_iters: f64,
        status: String,
    }
    let rec = engine.run(&rec_points, |cache, &(pol, prob, gamma)| {
        let problem = cache.get(&spec_e);
        let policy = hybriditer::recovery::RecoveryPolicy::parse(pol).unwrap();
        let mut time = 0.0;
        let mut loss = 0.0;
        let mut recov = 0.0;
        let mut roll = 0.0;
        let mut status = String::new();
        for seed in 0..SEEDS {
            let cluster = ClusterSpec {
                workers: M,
                base_compute: 0.01,
                delay: DelayModel::LogNormal { mu: -4.0, sigma: 0.5 },
                failure: FailureModel {
                    crash_prob: prob,
                    transient_prob: 0.0,
                    rejoin_after: None,
                },
                rebalance_every: 1,
                seed: 120 + seed,
                ..ClusterSpec::default()
            };
            let cfg = RunConfig {
                mode: SyncMode::Hybrid { gamma },
                optimizer: OptimizerKind::sgd(1.0),
                loss_form: LossForm::krr(spec_e.lambda),
                eval_every: 0,
                record_every: 1,
                recovery: hybriditer::recovery::RecoveryConfig {
                    policy,
                    checkpoint_every: ckpt_every,
                },
                ..RunConfig::default()
            }
            .with_iters(ITERS);
            let mut pool = problem.native_pool();
            let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
            let rows = rep.recorder.rows().len().max(1);
            time += rep.total_time() / rows as f64;
            loss += rep.final_loss();
            recov += rep.recoveries as f64;
            roll += rep.rollback_iters as f64;
            status = match rep.status {
                RunStatus::Completed | RunStatus::Converged { .. } => "ok".into(),
                RunStatus::Stalled { iter } => format!("stall@{iter}"),
                RunStatus::ClusterDead { iter } => format!("dead@{iter}"),
            };
        }
        let n = SEEDS as f64;
        let catchup = if policy.catches_up() { recov / M as f64 } else { 0.0 };
        RecCell {
            time_per_iter: time / n,
            final_loss: loss / n,
            recoveries: recov / n,
            rollback_iters: roll / n,
            overhead_iters: (roll + catchup) / n,
            status,
        }
    });
    for (&(pol, prob, gamma), cell) in rec_points.iter().zip(&rec) {
        t6.row(vec![
            pol.to_string(),
            f(prob, 3),
            gamma.to_string(),
            format!("{:.5}", cell.time_per_iter),
            format!("{:.6}", cell.final_loss),
            f(cell.recoveries, 1),
            f(cell.rollback_iters, 1),
            f(cell.overhead_iters, 2),
            cell.status.clone(),
        ]);
    }
    t6.print();
    t6.save_csv("f2e_recovery_policies").unwrap();

    // Machine-readable trajectory point: the high-crash-rate headline at
    // γ = 3M/4 — partial recovery's reconstruction cost vs
    // checkpoint-restore's rollback cost, and what each policy's final
    // loss looks like when the abandon baseline is losing workers for
    // good.
    let head_prob = 0.02;
    let head_gamma = M * 3 / 4;
    let rec_pick = |pol: &str| -> &RecCell {
        rec_points
            .iter()
            .position(|&p| p == (pol, head_prob, head_gamma))
            .map(|i| &rec[i])
            .expect("recovery headline cell")
    };
    let ab = rec_pick("abandon");
    let pr = rec_pick("partial-recovery");
    let ck = rec_pick("checkpoint-restore");
    let rec_json: Vec<String> = rec_points
        .iter()
        .zip(&rec)
        .map(|(&(pol, prob, gamma), c)| {
            format!(
                "    {{\"policy\": \"{pol}\", \"crash_prob\": {prob}, \"gamma\": {gamma}, \
                 \"time_per_iter_s\": {:.6}, \"final_loss\": {:.6}, \"recoveries\": {:.1}, \
                 \"rollback_iters\": {:.1}, \"overhead_iters\": {:.3}, \"status\": \"{}\"}}",
                c.time_per_iter, c.final_loss, c.recoveries, c.rollback_iters, c.overhead_iters,
                c.status
            )
        })
        .collect();
    let rec_json = format!(
        "{{\n  \"bench\": \"f2_recovery\",\n  \"machines\": {M},\n  \"iters\": {ITERS},\n  \
         \"seeds\": {SEEDS},\n  \"checkpoint_every\": {ckpt_every},\n  \"headline\": {{\n    \
         \"crash_prob\": {head_prob},\n    \"gamma\": {head_gamma},\n    \
         \"partial_overhead_iters\": {:.3},\n    \"checkpoint_overhead_iters\": {:.3},\n    \
         \"abandon_final_loss\": {:.6},\n    \"partial_final_loss\": {:.6},\n    \
         \"checkpoint_final_loss\": {:.6}\n  }},\n  \"points\": [\n{}\n  ]\n}}\n",
        pr.overhead_iters,
        ck.overhead_iters,
        ab.final_loss,
        pr.final_loss,
        ck.final_loss,
        rec_json.join(",\n")
    );
    std::fs::write("results/BENCH_f2_recovery.json", rec_json).unwrap();
    println!(
        "\nheadline: crash_prob={head_prob} gamma={head_gamma}: partial-recovery overhead \
         {:.2} iters vs checkpoint-restore {:.2} iters; final loss abandon {:.6} / partial \
         {:.6} / checkpoint {:.6}",
        pr.overhead_iters, ck.overhead_iters, ab.final_loss, pr.final_loss, ck.final_loss
    );
    println!("trajectory point -> results/BENCH_f2_recovery.json");

    println!(
        "\nReading: F2a — hybrid's speedup over BSP grows with tail heaviness\n\
         (≈1 with no stragglers).  F2b — BSP without recovery stalls at the\n\
         first crash; hybrid keeps full-speed progress while alive ≥ gamma.\n\
         F2c — rebalancing keeps the leavers' shards contributing, closing\n\
         the accuracy gap the orphaned run shows, at unchanged time cost.\n\
         F2d — on mixed hardware, level shard counts are not level loads:\n\
         capacity-weighted apportionment moves work off the slow half, so\n\
         the full-coverage barrier closes ~2× sooner at the same (zero)\n\
         abandon rate, and a cold rejoiner ramps back in without the\n\
         (k+1)× latency spike level-load planning re-creates.  F2e — at\n\
         high crash rates abandon loses workers for good and the run dies\n\
         early; the self-healing policies keep the pool full, with partial\n\
         recovery paying a fraction of an iteration per crash where\n\
         checkpoint-restore pays up to a whole snapshot window."
    );
}
