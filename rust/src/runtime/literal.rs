//! Literal construction/extraction helpers for the PJRT boundary.

use crate::runtime::manifest::{Dtype, TensorSpec};
use crate::{Error, Result};

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        return Err(Error::Shape(format!(
            "lit_f32: {} elements for shape {shape:?} (want {n})",
            data.len()
        )));
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        return Err(Error::Shape(format!(
            "lit_i32: {} elements for shape {shape:?} (want {n})",
            data.len()
        )));
    }
    if shape.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Rank-0 f32 literal.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Build a literal matching a manifest [`TensorSpec`] from raw f32 data
/// (i32 specs are converted elementwise).
pub fn lit_for_spec_f32(spec: &TensorSpec, data: &[f32]) -> Result<xla::Literal> {
    match spec.dtype {
        Dtype::F32 => lit_f32(data, &spec.shape),
        other => Err(Error::Shape(format!(
            "input '{}' wants {other:?}, got f32 data",
            spec.name
        ))),
    }
}

/// Extract a flat f32 vector.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a flat f32 vector into a caller-owned buffer (cleared and
/// refilled; capacity is reused).  The literal still materializes one host
/// `Vec` at the PJRT boundary — this saves the *second* copy the `grad_into`
/// hot path would otherwise allocate per dispatch.
pub fn read_f32_into(lit: &xla::Literal, out: &mut Vec<f32>) -> Result<()> {
    let v = lit.to_vec::<f32>()?;
    out.clear();
    out.extend_from_slice(&v);
    Ok(())
}

/// Extract a single f32 scalar (rank-0 or single-element).
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first()
        .copied()
        .ok_or_else(|| Error::Shape("empty literal where scalar expected".into()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip_2d() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = lit_f32(&data, &[2, 3]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = lit_f32(&[7.5], &[]).unwrap();
        assert_eq!(to_scalar_f32(&lit).unwrap(), 7.5);
    }

    #[test]
    fn i32_roundtrip() {
        let data = vec![1i32, -2, 3, 4];
        let lit = lit_i32(&data, &[2, 2]).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(lit_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(lit_i32(&[1], &[2]).is_err());
    }
}
