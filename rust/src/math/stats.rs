//! Streaming and batch statistics for metrics and the adaptive-γ estimator.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch summary with percentiles (sorts a copy).
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty slice");
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut st = OnlineStats::new();
        for &x in xs {
            st.push(x);
        }
        Summary {
            count: xs.len(),
            mean: st.mean(),
            std: st.std(),
            min: sorted[0],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
            max: *sorted.last().unwrap(),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Ordinary least squares fit `y = a + b x`; returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { sxy * sxy / (sxx * syy) };
    (a, b, r2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut st = OnlineStats::new();
        for &x in &xs {
            st.push(x);
        }
        assert!((st.mean() - 5.0).abs() < 1e-12);
        assert!((st.variance() - 4.0).abs() < 1e-12);
        assert_eq!(st.min(), 2.0);
        assert_eq!(st.max(), 9.0);
    }

    #[test]
    fn merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0).collect();
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        let mut all = OnlineStats::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.push(x)
            } else {
                b.push(x)
            }
            all.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-10);
        assert!((a.variance() - all.variance()).abs() < 1e-10);
    }

    #[test]
    fn percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile_sorted(&sorted, 0.5) - 50.5).abs() < 1e-9);
        assert_eq!(percentile_sorted(&sorted, 0.0), 1.0);
        assert_eq!(percentile_sorted(&sorted, 1.0), 100.0);
    }

    #[test]
    fn summary_sane() {
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 1000);
        assert!((s.mean - 499.5).abs() < 1e-9);
        assert!(s.p50 > 490.0 && s.p50 < 510.0);
        assert!(s.p99 > 980.0);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 - 0.5 * x).collect();
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b + 0.5).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }
}
