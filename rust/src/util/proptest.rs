//! Tiny property-testing helper (proptest is not in the vendor set).
//!
//! `check` runs a property over `n` seeded-random cases; on failure it
//! reports the case index and the seed that reproduces it, so a failing
//! property can be re-run deterministically:
//!
//! ```no_run
//! // (no_run: doctest binaries don't get the crate's rpath rustflags, so
//! // anything linking the xla-backed lib can't resolve libstdc++ at doctest
//! // runtime; the same property runs for real in this module's #[test]s.)
//! use hybriditer::util::{proptest::check, rng::Pcg64};
//! check("mean_of_two_in_between", 200, |rng: &mut Pcg64| {
//!     let (a, b) = (rng.next_f64(), rng.next_f64());
//!     let m = (a + b) / 2.0;
//!     if m < a.min(b) || m > a.max(b) {
//!         return Err(format!("mean {m} outside [{a}, {b}]"));
//!     }
//!     Ok(())
//! });
//! ```

use crate::util::rng::Pcg64;

/// Base seed; override with `HYBRIDITER_PROPTEST_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("HYBRIDITER_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_cafe)
}

/// Run `prop` over `n` cases. Each case gets an independent RNG stream.
/// Panics (test failure) with seed info on the first failing case.
pub fn check<F>(name: &str, n: usize, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    let seed = base_seed();
    for case in 0..n {
        let mut rng = Pcg64::new(seed, case as u64);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{n}: {msg}\n\
                 reproduce with HYBRIDITER_PROPTEST_SEED={seed} (stream {case})"
            );
        }
    }
}

/// Like [`check`] but the property builds its case from a drawn size in
/// `[lo, hi]` — convenient for shape sweeps.
pub fn check_sized<F>(name: &str, n: usize, lo: usize, hi: usize, mut prop: F)
where
    F: FnMut(usize, &mut Pcg64) -> Result<(), String>,
{
    check(name, n, |rng| {
        let size = lo + rng.below((hi - lo + 1) as u64) as usize;
        prop(size, rng)
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs_nonneg", 100, |rng| {
            let v = rng.normal();
            if v.abs() < 0.0 {
                Err("negative abs".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_panics_with_seed() {
        check("always_fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn sized_draws_in_range() {
        check_sized("size_in_range", 100, 3, 17, |size, _| {
            if (3..=17).contains(&size) {
                Ok(())
            } else {
                Err(format!("size {size} out of range"))
            }
        });
    }
}
