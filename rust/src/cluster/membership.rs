//! Master-side membership view: which workers are alive, crashed, or late.
//!
//! The hybrid barrier needs this to (a) size `γ` against *alive* workers and
//! (b) detect the BSP stall condition when a worker dies.

use crate::straggler::FailureEvent;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    Alive,
    Down,
}

/// Tracks per-worker liveness plus abandon accounting.
#[derive(Clone, Debug)]
pub struct Membership {
    states: Vec<WorkerState>,
    /// Results abandoned per worker (arrived after the barrier closed).
    abandoned: Vec<u64>,
    /// Results contributed per worker.
    contributed: Vec<u64>,
    crashes: u64,
    rejoins: u64,
}

impl Membership {
    pub fn new(workers: usize) -> Membership {
        Membership {
            states: vec![WorkerState::Alive; workers],
            abandoned: vec![0; workers],
            contributed: vec![0; workers],
            crashes: 0,
            rejoins: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    pub fn alive(&self) -> usize {
        self.states
            .iter()
            .filter(|s| **s == WorkerState::Alive)
            .count()
    }

    pub fn is_alive(&self, w: usize) -> bool {
        self.states[w] == WorkerState::Alive
    }

    /// Record a failure-model event observed for worker `w`.
    pub fn observe(&mut self, w: usize, ev: FailureEvent) {
        match ev {
            FailureEvent::Crashed => {
                self.states[w] = WorkerState::Down;
                self.crashes += 1;
            }
            FailureEvent::Rejoined => {
                self.states[w] = WorkerState::Alive;
                self.rejoins += 1;
            }
            FailureEvent::Down => self.states[w] = WorkerState::Down,
            FailureEvent::Healthy | FailureEvent::TransientDrop => {
                self.states[w] = WorkerState::Alive;
            }
        }
    }

    pub fn mark_down(&mut self, w: usize) {
        if self.states[w] == WorkerState::Alive {
            self.states[w] = WorkerState::Down;
            self.crashes += 1;
        }
    }

    pub fn record_contribution(&mut self, w: usize) {
        self.contributed[w] += 1;
    }

    pub fn record_abandoned(&mut self, w: usize) {
        self.abandoned[w] += 1;
    }

    pub fn total_abandoned(&self) -> u64 {
        self.abandoned.iter().sum()
    }

    pub fn total_contributed(&self) -> u64 {
        self.contributed.iter().sum()
    }

    pub fn crashes(&self) -> u64 {
        self.crashes
    }

    pub fn rejoins(&self) -> u64 {
        self.rejoins
    }

    /// Per-worker (contributed, abandoned) counters, for fairness reports.
    pub fn per_worker(&self) -> Vec<(u64, u64)> {
        self.contributed
            .iter()
            .zip(&self.abandoned)
            .map(|(&c, &a)| (c, a))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crash_and_rejoin_counts() {
        let mut m = Membership::new(3);
        assert_eq!(m.alive(), 3);
        m.observe(1, FailureEvent::Crashed);
        assert_eq!(m.alive(), 2);
        assert!(!m.is_alive(1));
        m.observe(1, FailureEvent::Down);
        assert_eq!(m.crashes(), 1);
        m.observe(1, FailureEvent::Rejoined);
        assert_eq!(m.alive(), 3);
        assert_eq!(m.rejoins(), 1);
    }

    #[test]
    fn abandon_accounting() {
        let mut m = Membership::new(2);
        m.record_contribution(0);
        m.record_contribution(0);
        m.record_abandoned(1);
        assert_eq!(m.total_contributed(), 2);
        assert_eq!(m.total_abandoned(), 1);
        assert_eq!(m.per_worker(), vec![(2, 0), (0, 1)]);
    }

    #[test]
    fn mark_down_idempotent_on_crash_count() {
        let mut m = Membership::new(2);
        m.mark_down(0);
        m.mark_down(0);
        assert_eq!(m.crashes(), 1);
        assert_eq!(m.alive(), 1);
    }
}
