//! Minimal declarative flag parser: `--key value`, `--flag`, positionals.
//!
//! Supports exactly what the `hybriditer` binary and the examples need:
//! long options with values, boolean flags, required/optional args with
//! defaults, and generated `--help` text.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// One declared option.
#[derive(Clone, Debug)]
struct Opt {
    name: &'static str,
    help: &'static str,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument specification.
#[derive(Clone, Debug, Default)]
pub struct ArgSpec {
    program: &'static str,
    about: &'static str,
    opts: Vec<Opt>,
    positionals: Vec<(&'static str, &'static str)>,
}

impl ArgSpec {
    pub fn new(program: &'static str, about: &'static str) -> ArgSpec {
        ArgSpec {
            program,
            about,
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// `--name <value>`, required.
    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean `--name`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    /// Positional argument (all required, in order).
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else if let Some(d) = &o.default {
                format!("  --{} <v> (default {d})", o.name)
            } else {
                format!("  --{} <v> (required)", o.name)
            };
            s.push_str(&format!("{head:40} {}\n", o.help));
        }
        for (p, h) in &self.positionals {
            s.push_str(&format!("  <{p}>{:34} {h}\n", ""));
        }
        s.push_str("  --help                                 print this help\n");
        s
    }

    /// Parse a raw argv slice (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();

        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if arg == "--help" || arg == "-h" {
                return Err(Error::Config(self.usage()));
            }
            if let Some(name) = arg.strip_prefix("--") {
                // Support --key=value too.
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let opt = self.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    Error::Config(format!("unknown option --{name}\n\n{}", self.usage()))
                })?;
                if opt.is_flag {
                    if inline.is_some() {
                        return Err(Error::Config(format!("--{name} takes no value")));
                    }
                    flags.push(name.to_string());
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| Error::Config(format!("--{name} needs a value")))?
                        }
                    };
                    values.insert(name.to_string(), v);
                }
            } else {
                positionals.push(arg.clone());
            }
            i += 1;
        }

        // Defaults + required checks.
        for o in &self.opts {
            if !o.is_flag && !values.contains_key(o.name) {
                match &o.default {
                    Some(d) => {
                        values.insert(o.name.to_string(), d.clone());
                    }
                    None => {
                        return Err(Error::Config(format!(
                            "missing required --{}\n\n{}",
                            o.name,
                            self.usage()
                        )))
                    }
                }
            }
        }
        if positionals.len() != self.positionals.len() {
            return Err(Error::Config(format!(
                "expected {} positional arg(s), got {}\n\n{}",
                self.positionals.len(),
                positionals.len(),
                self.usage()
            )));
        }

        Ok(Parsed {
            values,
            flags,
            positionals,
        })
    }

    /// Parse `std::env::args().skip(1)`; on `--help` or error, print + exit.
    pub fn parse_or_exit(&self) -> Parsed {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(p) => p,
            Err(Error::Config(msg)) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with(self.program) { 0 } else { 2 });
            }
            Err(e) => {
                eprintln!("{e}");
                std::process::exit(2);
            }
        }
    }
}

/// Parsed arguments with typed accessors.
#[derive(Clone, Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name).parse().map_err(|_| {
            Error::Config(format!("--{name}: expected integer, got '{}'", self.get(name)))
        })
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name).parse().map_err(|_| {
            Error::Config(format!("--{name}: expected integer, got '{}'", self.get(name)))
        })
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name).parse().map_err(|_| {
            Error::Config(format!("--{name}: expected float, got '{}'", self.get(name)))
        })
    }

    /// Like [`Parsed::get_f64`], but the empty string — the conventional
    /// default of "unset" override options — is `None`.
    pub fn get_opt_f64(&self, name: &str) -> Result<Option<f64>> {
        let raw = self.get(name);
        if raw.is_empty() {
            return Ok(None);
        }
        raw.parse().map(Some).map_err(|_| {
            Error::Config(format!("--{name}: expected float, got '{raw}'"))
        })
    }

    /// Like [`Parsed::get_usize`], but the empty string — the conventional
    /// default of "unset" override options — is `None`.
    pub fn get_opt_usize(&self, name: &str) -> Result<Option<usize>> {
        let raw = self.get(name);
        if raw.is_empty() {
            return Ok(None);
        }
        raw.parse().map(Some).map_err(|_| {
            Error::Config(format!("--{name}: expected integer, got '{raw}'"))
        })
    }

    pub fn has(&self, flag: &str) -> bool {
        self.flags.iter().any(|f| f == flag)
    }

    pub fn positional(&self, i: usize) -> &str {
        &self.positionals[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("prog", "test program")
            .opt("workers", "8", "number of workers")
            .opt("eta", "0.5", "step size")
            .req("mode", "sync mode")
            .flag("verbose", "chatty")
            .positional("config", "config file")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let p = spec()
            .parse(&sv(&["--workers", "16", "--mode=hybrid", "--verbose", "conf.toml"]))
            .unwrap();
        assert_eq!(p.get_usize("workers").unwrap(), 16);
        assert_eq!(p.get("mode"), "hybrid");
        assert_eq!(p.get_f64("eta").unwrap(), 0.5); // default
        assert!(p.has("verbose"));
        assert_eq!(p.positional(0), "conf.toml");
    }

    #[test]
    fn missing_required_errors() {
        let e = spec().parse(&sv(&["conf.toml"])).unwrap_err();
        assert!(format!("{e}").contains("--mode"));
    }

    #[test]
    fn unknown_option_errors() {
        let e = spec()
            .parse(&sv(&["--nope", "1", "--mode", "bsp", "c"]))
            .unwrap_err();
        assert!(format!("{e}").contains("--nope"));
    }

    #[test]
    fn positional_count_checked() {
        assert!(spec().parse(&sv(&["--mode", "bsp"])).is_err());
        assert!(spec().parse(&sv(&["--mode", "bsp", "a", "b"])).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let p = spec()
            .parse(&sv(&["--workers", "abc", "--mode", "bsp", "c"]))
            .unwrap();
        assert!(p.get_usize("workers").is_err());
    }

    #[test]
    fn opt_usize_treats_empty_as_unset() {
        let spec = ArgSpec::new("prog", "t").opt("threads", "", "pool size");
        let p = spec.parse(&sv(&[])).unwrap();
        assert_eq!(p.get_opt_usize("threads").unwrap(), None);
        let p = spec.parse(&sv(&["--threads", "6"])).unwrap();
        assert_eq!(p.get_opt_usize("threads").unwrap(), Some(6));
        let p = spec.parse(&sv(&["--threads", "-1"])).unwrap();
        assert!(p.get_opt_usize("threads").is_err());
    }

    #[test]
    fn opt_f64_treats_empty_as_unset() {
        let spec = ArgSpec::new("prog", "t").opt("drop-prob", "", "override");
        let p = spec.parse(&sv(&[])).unwrap();
        assert_eq!(p.get_opt_f64("drop-prob").unwrap(), None);
        let p = spec.parse(&sv(&["--drop-prob", "0.25"])).unwrap();
        assert_eq!(p.get_opt_f64("drop-prob").unwrap(), Some(0.25));
        let p = spec.parse(&sv(&["--drop-prob", "x"])).unwrap();
        assert!(p.get_opt_f64("drop-prob").is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = spec().parse(&sv(&["--help"])).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("USAGE"));
        assert!(msg.contains("--workers"));
    }
}
