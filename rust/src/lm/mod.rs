//! Data-parallel transformer LM training on the hybrid coordinator — the
//! end-to-end demonstration that the paper's barrier is model-agnostic.
//!
//! The `lm_step_<config>` artifact (L2 jax fwd/bwd, AOT-lowered) takes
//! `(tokens, *params)` and returns `(loss, *grads)`.  Rust treats the whole
//! parameter set as one flat `Vec<f32>`; [`LmTask`] knows the per-tensor
//! split from the manifest and re-packs at the PJRT boundary.  Each
//! simulated worker samples its own microbatches from its shard of the
//! synthetic bigram corpus ([`crate::data::corpus`]), so the hybrid
//! coordinator drives *stochastic* data-parallel SGD exactly like a
//! production data-parallel trainer.

pub mod init;
pub mod pool;

pub use pool::LmPool;

use crate::runtime::{ArtifactSet, TensorSpec};
use crate::{Error, Result};

/// Static description of one LM configuration (from the manifest).
#[derive(Clone, Debug)]
pub struct LmTask {
    pub config: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_head: usize,
    pub n_layer: usize,
    pub seq: usize,
    pub batch: usize,
    pub d_ff: usize,
    /// Parameter tensors in artifact input order (tokens excluded).
    pub params: Vec<TensorSpec>,
    pub n_params: usize,
}

impl LmTask {
    /// Read the task description from `lm_step_<config>`'s manifest entry.
    pub fn from_manifest(artifacts: &ArtifactSet, config: &str) -> Result<LmTask> {
        let info = artifacts.info(&format!("lm_step_{config}"))?;
        let params: Vec<TensorSpec> = info.inputs[1..].to_vec();
        let n_params = params.iter().map(|t| t.elements()).sum();
        let meta_n = info.meta_usize("n_params")?;
        if n_params != meta_n {
            return Err(Error::Manifest(format!(
                "lm_step_{config}: manifest n_params {meta_n} != summed {n_params}"
            )));
        }
        Ok(LmTask {
            config: config.to_string(),
            vocab: info.meta_usize("vocab")?,
            d_model: info.meta_usize("d_model")?,
            n_head: info.meta_usize("n_head")?,
            n_layer: info.meta_usize("n_layer")?,
            seq: info.meta_usize("seq")?,
            batch: info.meta_usize("batch")?,
            d_ff: info.meta_usize("d_ff")?,
            params,
            n_params,
        })
    }

    /// Tokens consumed per microbatch (loss positions = batch·seq).
    pub fn tokens_per_batch(&self) -> usize {
        self.batch * self.seq
    }

    /// Byte offsets of each tensor in the flat parameter vector.
    pub fn offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.params.len());
        let mut off = 0;
        for t in &self.params {
            let n = t.elements();
            out.push((off, n));
            off += n;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_task() -> LmTask {
        use crate::runtime::manifest::Dtype;
        let params = vec![
            TensorSpec { name: "embed".into(), shape: vec![16, 4], dtype: Dtype::F32 },
            TensorSpec { name: "pos".into(), shape: vec![8, 4], dtype: Dtype::F32 },
            TensorSpec { name: "lnf_scale".into(), shape: vec![4], dtype: Dtype::F32 },
        ];
        let n_params = 16 * 4 + 8 * 4 + 4;
        LmTask {
            config: "fake".into(),
            vocab: 16,
            d_model: 4,
            n_head: 2,
            n_layer: 0,
            seq: 8,
            batch: 2,
            d_ff: 16,
            params,
            n_params,
        }
    }

    #[test]
    fn offsets_partition_flat_vector() {
        let t = fake_task();
        let offs = t.offsets();
        assert_eq!(offs, vec![(0, 64), (64, 32), (96, 4)]);
        let total: usize = offs.iter().map(|(_, n)| n).sum();
        assert_eq!(total, t.n_params);
    }

    #[test]
    fn tokens_per_batch() {
        assert_eq!(fake_task().tokens_per_batch(), 16);
    }
}
