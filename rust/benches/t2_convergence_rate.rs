//! T2 — convergence speed (paper §3.3: the hybrid iteration is Q-linear,
//! `‖θ_{t+1}−θ*‖² ≤ (1−λη)‖θ_t−θ*‖² + η²C²`).
//!
//! Fits the empirical per-iteration contraction q̂ from `ln‖θ_t−θ*‖` and
//! compares with the theoretical envelope √(1−λη) for several γ and η.
//! The 16 (λ, η, γ) cells run concurrently on the sweep engine
//! (`--threads N` overrides the pool size); each λ's noiseless problem is
//! shared through the cache.
//!
//! Expected shape: q̂ ≤ theory for every γ (partial aggregation does not
//! break Q-linear convergence; smaller γ adds gradient noise, raising the
//! floor, not the rate).

use hybriditer::bench_harness::sweep::{ProblemCache, SweepEngine};
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::optim::OptimizerKind;
use hybriditer::sim;
use hybriditer::straggler::DelayModel;

fn qhat(cache: &ProblemCache, gamma: usize, m: usize, eta: f64, lambda: f64) -> (f64, f64, f64) {
    let mut spec = KrrProblemSpec::small().with_machines(m);
    spec.lambda = lambda;
    spec.noise = 0.0; // noiseless → clean geometric decay to θ*
    let problem = cache.get(&spec);
    let cluster = ClusterSpec {
        workers: m,
        delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
        ..ClusterSpec::default()
    };
    let cfg = RunConfig {
        mode: if gamma == m {
            SyncMode::Bsp
        } else {
            SyncMode::Hybrid { gamma }
        },
        optimizer: OptimizerKind::sgd(eta),
        loss_form: LossForm::krr(lambda),
        eval_every: 1, // need theta_err every iteration for the fit
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(120);
    let mut pool = problem.native_pool();
    let rep = sim::run_virtual(&mut pool, &cluster, &cfg, problem.as_ref()).unwrap();
    let (q, r2) = rep.recorder.qlinear_rate().unwrap();
    (q, r2, rep.final_theta_err().unwrap())
}

fn main() {
    let m = 16;
    let engine = SweepEngine::from_env();
    println!("T2: Q-linear convergence rate — M={m}, noiseless KRR");
    println!("theory (eq. 30): ‖θ_t−θ*‖ contracts by at most sqrt(1−λη) per iteration");
    println!("sweep pool: {} threads\n", engine.threads());

    let mut table = Table::new(
        "T2 empirical contraction vs theory",
        &["lambda", "eta", "gamma", "q_hat", "r2", "q_theory", "ok", "final_err"],
    );
    let mut points: Vec<(f64, f64, usize)> = Vec::new();
    for &(lambda, eta) in &[(0.05f64, 1.0f64), (0.1, 1.0), (0.1, 0.5), (0.2, 0.5)] {
        for &gamma in &[m, m * 3 / 4, m / 2, m / 4] {
            points.push((lambda, eta, gamma));
        }
    }
    let results = engine.run(&points, |cache, &(lambda, eta, gamma)| {
        qhat(cache, gamma, m, eta, lambda)
    });
    for (&(lambda, eta, gamma), &(q, r2, err)) in points.iter().zip(&results) {
        let q_theory = (1.0 - lambda * eta).sqrt();
        table.row(vec![
            f(lambda, 2),
            f(eta, 2),
            gamma.to_string(),
            f(q, 4),
            f(r2, 3),
            f(q_theory, 4),
            if q <= q_theory + 0.01 { "yes".into() } else { "NO".into() },
            format!("{err:.2e}"),
        ]);
    }
    table.print();
    table.save_csv("t2_convergence_rate").unwrap();
    println!(
        "\nReading: q_hat is the fitted per-iteration contraction of ‖θ−θ*‖;\n\
         it must sit at or below the paper's bound sqrt(1−λη) (column ok).\n\
         The bound is loose — the data term adds curvature beyond λ."
    );
}
