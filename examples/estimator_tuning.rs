//! Algorithm-1 tuning console: sweep (α, ξ) and see the γ each pair
//! demands, its abandon rate, and the *measured* gradient error coverage
//! on a real problem — how an operator would pick the accuracy/speed
//! trade-off before a production run.
//!
//!     cargo run --release --example estimator_tuning

use hybriditer::bench_harness::{f, Table};
use hybriditer::coordinator::estimator::{estimate_gamma, estimate_sample_size, EstimatorParams};
use hybriditer::data::{ComputePool, KrrProblem, KrrProblemSpec};
use hybriditer::math::vec_ops;
use hybriditer::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    hybriditer::util::logger::init();
    let spec = KrrProblemSpec::default_config().with_machines(32);
    let problem = KrrProblem::generate(&spec)?;
    let (n, zeta, m) = (spec.total_examples(), spec.zeta, spec.machines);
    println!("N = {n} examples, zeta = {zeta}, M = {m}\n");

    let mut pool = problem.native_pool();

    // Full gradient at a random-but-fixed θ for measuring relative error.
    let mut rng = Pcg64::seeded(7);
    let mut theta = vec![0.0f32; problem.dim()];
    rng.fill_normal(&mut theta, 0.0, 1.0);
    let mut full = vec![0.0f32; problem.dim()];
    let mut grads = Vec::new();
    for w in 0..m {
        let g = pool.grad(w, &theta, 0)?.grad;
        vec_ops::add_assign(&mut full, &g);
        grads.push(g);
    }
    vec_ops::scale(&mut full, 1.0 / m as f32);
    let full_norm = vec_ops::norm2(&full);

    let mut table = Table::new(
        "Algorithm 1 sweep: gamma / abandon rate / measured coverage",
        &["alpha", "xi", "n_examples", "gamma", "abandon_%", "mean_rel_err", "coverage_%"],
    );

    for &alpha in &[0.01, 0.05, 0.10] {
        for &xi in &[0.01, 0.05, 0.10, 0.25] {
            let p = EstimatorParams { alpha, xi };
            let n_est = estimate_sample_size(n, p)?;
            let gamma = estimate_gamma(n, zeta, m, p)?;

            // Measure: random γ-subsets of workers, relative gradient error.
            let trials = 300;
            let mut hits = 0;
            let mut rel_sum = 0.0;
            let mut sub = vec![0.0f32; problem.dim()];
            for _ in 0..trials {
                let idx = rng.sample_indices(m, gamma);
                sub.fill(0.0);
                for &w in &idx {
                    vec_ops::add_assign(&mut sub, &grads[w]);
                }
                vec_ops::scale(&mut sub, 1.0 / gamma as f32);
                let rel = vec_ops::dist2(&sub, &full) / full_norm;
                rel_sum += rel;
                if rel <= xi {
                    hits += 1;
                }
            }
            table.row(vec![
                f(alpha, 2),
                f(xi, 2),
                f(n_est, 0),
                format!("{gamma}"),
                f(100.0 * (1.0 - gamma as f64 / m as f64), 1),
                format!("{:.4}", rel_sum / trials as f64),
                f(100.0 * hits as f64 / trials as f64, 1),
            ]);
        }
    }
    table.print();
    table.save_csv("example_estimator_tuning")?;
    println!(
        "\nReading: the distribution-free bound (Algorithm 1) is conservative —\n\
         measured coverage should sit at or above the requested confidence\n\
         (1-alpha) whenever gamma isn't clamped at 1."
    );
    Ok(())
}
