//! Fully asynchronous policy over the event engine.
//!
//! Every delivered reply applies immediately (staleness-damped when
//! configured); there are no barriers, so virtual time is simply the event
//! heap's clock.  The unified engine closes two historical gaps:
//!
//! * **Elastic membership** — a scheduled event at iteration `k` lands at
//!   the update-count boundary `k·M` (the sync-iteration equivalent).
//!   Leaves evict the worker (its in-flight reply is discarded); joins
//!   re-admit it with a *fresh* θ snapshot — staleness 0 — and a new
//!   dispatch, and with `rebalance_every > 0` the engine's boundary
//!   handler re-plans shard ownership exactly like the sync policy.
//! * **Duplication** — `dup_prob` now schedules the duplicated reply copy
//!   as its own event.  Every dispatch carries a **version tag** (the
//!   per-worker attempt counter, which also keys the network realization);
//!   an arriving reply applies only if its tag matches the worker's
//!   outstanding dispatch, so duplicate copies — and stragglers from
//!   before a leave/rejoin cycle — are detected and discarded, never
//!   double-applied.
//!
//! With a static cluster the event sequence, RNG streams, and timing
//! arithmetic are unchanged from the pre-refactor `run_async` (the RNG
//! family is still `(0xA51C, 2000)` and arrivals still land at
//! `base + compute + net + tail`).

use crate::cluster::{ClusterSpec, ElasticKind};
use crate::coordinator::convergence::{ConvergenceTracker, RunStatus};
use crate::coordinator::{RunConfig, RunReport, SyncMode};
use crate::data::{ComputePool, GradResult};
use crate::math::vec_ops;
use crate::metrics::{IterRow, Recorder};
use crate::net::{BlockSet, NetSpec, NetStats};
use crate::straggler::{FailureEvent, StragglerProfile};
use crate::trace::{self, TraceEvent, TraceSink};
use crate::Result;

use super::engine::{EngineCore, Event};
use super::{report, EvalHooks};

/// The dispatch side of the async policy: the per-worker attempt counters
/// (version tags), the outstanding-tag table the duplicate detection
/// checks against, the network spec, and the message accounting — bundled
/// so every dispatch site states only what varies (worker, base time,
/// tail, shard list).
struct Dispatcher<'a> {
    profiles: &'a [StragglerProfile],
    net: &'a NetSpec,
    net_ideal: bool,
    seed: u64,
    attempts: Vec<u64>,
    /// Version tag of each worker's outstanding dispatch; only the
    /// matching reply may apply.
    outstanding: Vec<u64>,
    /// The shard list each worker's outstanding dispatch was sent with.
    /// The reply computes *these* shards — like the threaded `Work`
    /// message carrying its list — so a rebalance landing while the
    /// roundtrip is in flight cannot retroactively change what the reply
    /// covers.  Buffers reuse capacity across dispatches.
    shards_given: Vec<Vec<usize>>,
    /// Reply block count (1 = block admission off).
    n_blocks: usize,
    /// Delivered block set of each worker's outstanding dispatch; the fold
    /// zeroes the ranges of blocks the network lost.
    blocks_out: Vec<BlockSet>,
    stats: NetStats,
}

impl Dispatcher<'_> {
    /// Dispatch worker `w`'s next roundtrip over `shards` (its current
    /// assignment, frozen into the dispatch): sample its compute latency
    /// (scaled by the shard count, the sync policy's serial model),
    /// realize the roundtrip's network fate keyed by the worker's attempt
    /// counter — the version tag — and push the arrival (plus any
    /// duplicated copy) onto the engine heap.  A lost roundtrip still pops
    /// (the master "detects" the loss a full traversal later) but carries
    /// `delivers = false`, so the update is discarded and the worker
    /// retries.
    fn dispatch(
        &mut self,
        core: &mut EngineCore,
        sink: &mut dyn TraceSink,
        w: usize,
        base: f64,
        tail: f64,
        shards: &[usize],
    ) {
        self.shards_given[w].clear();
        self.shards_given[w].extend_from_slice(shards);
        // Serial execution of the dispatched shards, dilated by the
        // warm-up ramp while the worker is cold.  A zero-shard dispatch is
        // a control-plane keep-alive (it keeps the worker in the event
        // loop so a later rebalance can reach it): flat base cost, no
        // slow/capacity/warm-up scaling, no delay draw.  Zero-shard
        // dispatches only arise under capacity-weighted apportionment, so
        // the legacy event sequence is untouched.
        let compute = if shards.is_empty() {
            self.profiles[w].base_compute
        } else {
            let per_shard = self.profiles[w].sample_latency(&mut core.delay_rngs[w]);
            per_shard * core.elastic.latency_scale(w) * shards.len() as f64
        };
        let tag = self.attempts[w];
        // Fate events key on the version tag — the same pure realization
        // key the dispatch itself uses below.
        if sink.enabled() {
            trace::emit_roundtrip_fates(sink, self.net, self.seed, w, tag, self.n_blocks, base);
        }
        let (delivers, net_delay, dup_lag) = if self.net_ideal {
            self.stats.sent += 2;
            self.stats.delivered += 2;
            if self.n_blocks > 1 {
                self.stats.count_blocks_ideal(self.n_blocks);
            }
            self.blocks_out[w] = BlockSet::full(self.n_blocks);
            (true, 0.0, None)
        } else {
            let r = self.net.realize(self.seed, w, tag);
            let ok = if self.n_blocks > 1 {
                // Block admission: the reply's blocks realize their fates
                // independently (keyed by the version tag, exactly like the
                // whole-message realization); a below-threshold delivery is
                // loss — the master detects it and the worker retries.
                let blocks = self.net.realize_blocks(
                    self.seed,
                    w,
                    tag,
                    self.n_blocks,
                    r.up_dropped,
                    false,
                );
                self.blocks_out[w] = blocks;
                self.stats
                    .count_roundtrip_blocks(&r, blocks, self.net.admits(blocks), true)
            } else {
                self.stats.count_roundtrip(&r, true)
            };
            let dup = if ok && r.up_duplicated { Some(r.dup_lag) } else { None };
            (ok, r.roundtrip_delay(), dup)
        };
        self.attempts[w] += 1;
        self.outstanding[w] = tag;
        let at = base + compute + net_delay + tail;
        core.heap.push(Event { at, worker: w, iter: tag, duplicate: false, delivers });
        if let Some(lag) = dup_lag {
            let dup = Event { at: at + lag, worker: w, iter: tag, duplicate: true, delivers: true };
            core.heap.push(dup);
        }
    }
}

pub(super) fn run_async(
    pool: &mut dyn ComputePool,
    cluster: &ClusterSpec,
    cfg: &RunConfig,
    hooks: &dyn EvalHooks,
    driver_start: std::time::Instant,
    sink: &mut dyn TraceSink,
    serve: Option<&crate::serve::ServeSpec>,
) -> Result<RunReport> {
    let damping = match cfg.mode {
        SyncMode::Async { damping } => damping,
        _ => unreachable!("run_async requires Async mode"),
    };
    let m = pool.n_workers();
    let dim = pool.dim();
    // Serving engine (None without a [serve] config).  Async has no
    // barrier, so the serve clock advances every m-th applied update —
    // the update-count equivalent of a sync iteration, the same keying
    // the elastic boundaries use (docs/SERVING.md).
    let mut serving = serve.map(crate::serve::ServeEngine::new);
    let profiles = cluster.profiles();

    let mut theta = cfg.init_theta.clone().unwrap_or_else(|| vec![0.0f32; dim]);
    // Engine state on the historical async RNG stream family, with the
    // cluster's capacity model installed (defaults are a bit-for-bit no-op).
    let mut core = EngineCore::new(&profiles, cluster.seed, 0xA51C, 2000);
    core.elastic.configure_capacity(
        cluster.capacity_vec(),
        cluster.warmup_iters,
        cluster.weighted_rebalance,
    );

    // Each worker computes against the θ snapshot it was last handed.
    let mut theta_given: Vec<Vec<f32>> = (0..m).map(|_| theta.clone()).collect();
    let mut version_given = vec![0u64; m];
    let mut version = 0u64;

    let mut dx = Dispatcher {
        profiles: &profiles,
        net: &cluster.net,
        net_ideal: cluster.net.is_ideal(),
        seed: cluster.seed,
        attempts: vec![0u64; m],
        outstanding: vec![0u64; m],
        shards_given: (0..m).map(|_| Vec::new()).collect(),
        n_blocks: cluster.net.n_blocks(dim),
        blocks_out: vec![BlockSet::full(cluster.net.n_blocks(dim)); m],
        stats: NetStats::default(),
    };
    let mut stats_at_row = NetStats::default();
    let mut assignment: Vec<Vec<usize>> = core.elastic.ownership.grouped();

    let mut opt = cfg.optimizer.build();
    let mut tracker = ConvergenceTracker::new(cfg.stop.clone());
    let mut rec = Recorder::new();
    let mut now = 0.0;
    let mut status = RunStatus::Completed;
    let mut staleness_sum = 0.0f64;
    let mut updates = 0u64;
    let mut scaled = vec![0.0f32; dim];
    let mut loss_ema: Option<f64> = None;
    // Reusable gradient slots: the event loop's steady state allocates
    // nothing per applied update (the multi-shard slot only grows under
    // elastic rebalancing).
    let mut grad_slot = GradResult::empty();
    let mut multi_slot = GradResult::empty();
    // Async has no crash/rejoin barrier to recover at, so non-abandon
    // recovery policies are rejected upstream (Coordinator::new and
    // run_virtual_traced); the boundary handler gets a no-op state.
    let mut recovery = crate::recovery::RecoveryState::new(
        crate::recovery::RecoveryConfig::default(),
        m,
    );
    // The iteration-0 boundary precedes the opening dispatches (a leave@0
    // suppresses that worker's first roundtrip); joins at boundary 0 are
    // covered by the opening dispatches themselves.
    if cluster.elastic.at(0).next().is_some() || cluster.rebalance_every > 0 {
        let rebalanced = core.boundary(
            0,
            &cluster.elastic,
            cluster.rebalance_every,
            &mut recovery,
            &mut theta,
            sink,
            0.0,
        )?;
        if rebalanced {
            core.elastic.ownership.grouped_into(&mut assignment);
        }
        if sink.enabled() {
            let owners = core.elastic.ownership.owners();
            trace::emit_boundary(sink, &cluster.elastic, 0, rebalanced, owners, 0.0);
        }
    }
    // Next update-count boundary (in sync-iteration equivalents) whose
    // scheduled events and rebalance cadence are still unprocessed.
    let mut next_boundary = 1u64;
    for w in 0..m {
        if core.evicted[w] {
            continue;
        }
        dx.dispatch(&mut core, sink, w, 0.0, 0.0, &assignment[w]);
    }

    loop {
        // --- boundaries due at this update count ------------------------
        while next_boundary <= updates / m as u64 {
            let b = next_boundary;
            next_boundary += 1;
            let had_events = cluster.elastic.at(b).next().is_some();
            if !had_events && cluster.rebalance_every == 0 {
                continue;
            }
            let rebalanced = core.boundary(
                b,
                &cluster.elastic,
                cluster.rebalance_every,
                &mut recovery,
                &mut theta,
                sink,
                now,
            )?;
            if rebalanced {
                core.elastic.ownership.grouped_into(&mut assignment);
                log::debug!("async boundary {b}: shard ownership rebalanced");
            }
            if sink.enabled() {
                let owners = core.elastic.ownership.owners();
                trace::emit_boundary(sink, &cluster.elastic, b, rebalanced, owners, now);
            }
            // Policy side of a join: hand the re-admitted worker a fresh θ
            // snapshot (staleness 0) and dispatch its next roundtrip.  Its
            // pre-leave in-flight reply, if any, now carries a stale
            // version tag and will be discarded on arrival.
            for ev in cluster.elastic.at(b) {
                if ev.kind == ElasticKind::Join
                    && !core.evicted[ev.worker]
                    && !core.fstates[ev.worker].is_down()
                {
                    theta_given[ev.worker].copy_from_slice(&theta);
                    version_given[ev.worker] = version;
                    let shards = &assignment[ev.worker];
                    dx.dispatch(&mut core, sink, ev.worker, now, cluster.master_overhead, shards);
                }
            }
        }

        // --- next event -------------------------------------------------
        let Some(ev) = core.heap.pop() else { break };
        now = ev.at;
        let w = ev.worker;
        if sink.enabled() && ev.delivers {
            let deliv = TraceEvent::Delivery { duplicate: ev.duplicate };
            sink.emit(ev.iter, w as i64, now, deliv);
        }
        if core.evicted[w] || ev.iter != dx.outstanding[w] {
            // Pre-eviction leftovers, duplicate copies, and pre-rejoin
            // stragglers: the eviction mask / version tag detects them and
            // the update is discarded, never double-applied.
            if ev.delivers {
                core.membership.record_abandoned(w);
            }
            continue;
        }
        if !ev.delivers {
            // The network lost this roundtrip: the update never reaches
            // the master; the worker retries from the same θ.
            dx.dispatch(&mut core, sink, w, now, 0.0, &assignment[w]);
            continue;
        }
        // Failure check at delivery time.
        let fev = core.fstates[w].step(updates, &mut core.fail_rngs[w]);
        core.membership.observe(w, fev);
        if sink.enabled() && matches!(fev, FailureEvent::Crashed) {
            sink.emit(updates, w as i64, now, TraceEvent::Crash);
        }
        match fev {
            FailureEvent::Crashed | FailureEvent::Down => {
                if core.membership.alive() == 0 {
                    status = RunStatus::ClusterDead { iter: updates };
                    break;
                }
                continue; // worker drops out of the loop (no reschedule)
            }
            FailureEvent::TransientDrop => {
                // Result lost; worker retries from the same θ.
                dx.dispatch(&mut core, sink, w, now, 0.0, &assignment[w]);
                core.membership.record_abandoned(w);
                continue;
            }
            FailureEvent::Healthy | FailureEvent::Rejoined => {}
        }

        if dx.shards_given[w].is_empty() {
            // Transient zero-shard dispatch under churn: heartbeat only —
            // but a heartbeat still round-trips through the master, which
            // hands out fresh parameters with it (the threaded master does
            // the same), so the snapshot and version refresh.
            theta_given[w].copy_from_slice(&theta);
            version_given[w] = version;
            dx.dispatch(&mut core, sink, w, now, cluster.master_overhead, &assignment[w]);
            continue;
        }

        // Compute the shards this dispatch was sent with (not the current
        // assignment — a rebalance may have landed while the roundtrip was
        // in flight) at the held θ snapshot.  One shard — the static
        // layout — writes straight into the reusable slot; a multi-shard
        // dispatch folds a plain mean in the canonical order the shared
        // aggregator uses (unit-weight folds, then one 1/k scale), with
        // losses and example counts summing.
        let res: &GradResult = if dx.shards_given[w].len() == 1 {
            let s = dx.shards_given[w][0];
            pool.grad_into(s, &theta_given[w], updates, &mut grad_slot)?;
            &grad_slot
        } else {
            let k = dx.shards_given[w].len();
            multi_slot.grad.resize(dim, 0.0);
            multi_slot.grad.fill(0.0);
            let mut loss_sum = 0.0f64;
            let mut any_loss = false;
            let mut examples = 0usize;
            for &s in dx.shards_given[w].iter() {
                pool.grad_into(s, &theta_given[w], updates, &mut grad_slot)?;
                vec_ops::axpy(1.0, &grad_slot.grad, &mut multi_slot.grad);
                if let Some(ls) = grad_slot.loss_sum {
                    loss_sum += ls;
                    any_loss = true;
                }
                examples += grad_slot.examples;
            }
            vec_ops::scale(&mut multi_slot.grad, (1.0 / k as f64) as f32);
            multi_slot.loss_sum = if any_loss { Some(loss_sum) } else { None };
            multi_slot.examples = examples;
            &multi_slot
        };
        let staleness = version - version_given[w];
        staleness_sum += staleness as f64;
        core.membership.record_contribution(w);

        // Staleness-damped application.
        let weight = if damping > 0.0 {
            (1.0 / (1.0 + staleness as f64)).powf(damping)
        } else {
            1.0
        };
        scaled.copy_from_slice(&res.grad);
        if weight != 1.0 {
            vec_ops::scale(&mut scaled, weight as f32);
        }
        // Block admission: the network delivered only `blocks_out[w]` of
        // this reply — zero the lost ranges so the update touches exactly
        // the coordinates that arrived.  A full set is a no-op, so the
        // legacy (single-block) fold is bit-identical.
        let blocks = dx.blocks_out[w];
        if !blocks.is_full() {
            for b in 0..blocks.len() {
                if !blocks.contains(b) {
                    let (lo, hi) = blocks.range(b, dim);
                    scaled[lo..hi].fill(0.0);
                }
            }
        }
        opt.step(&mut theta, &scaled, updates);
        version += 1;
        updates += 1;
        if updates % m as u64 == 0 {
            if let Some(sv) = serving.as_mut() {
                sv.on_barrier_close(updates / m as u64 - 1, &theta, sink, now);
            }
        }

        // Hand the worker fresh parameters; schedule its next arrival over
        // its *current* assignment.
        theta_given[w].copy_from_slice(&theta);
        version_given[w] = version;
        let res_loss = res.loss_sum;
        let res_examples = res.examples;
        let applied_shards = dx.shards_given[w].len();
        dx.dispatch(&mut core, sink, w, now, cluster.master_overhead, &assignment[w]);

        // Loss estimate: EMA over per-report losses (noisy but cheap).
        if let Some(ls) = res_loss {
            let shard_loss = cfg.loss_form.assemble(ls, res_examples, &theta);
            loss_ema = Some(match loss_ema {
                None => shard_loss,
                Some(prev) => 0.9 * prev + 0.1 * shard_loss,
            });
        }

        // Record every `record_every × m` updates ≈ one sync-iteration.
        let iter_equiv = updates / m.max(1) as u64;
        let grad_norm = vec_ops::norm2(&scaled);
        let loss = loss_ema.unwrap_or(f64::NAN);
        let stop = tracker.observe(updates.saturating_sub(1), loss, grad_norm);
        if updates % (cfg.record_every.max(1) * m as u64) == 0 || stop.is_some() {
            let do_eval = cfg.eval_every > 0 && iter_equiv % cfg.eval_every == 0;
            let (eval_loss, theta_err) = if do_eval || stop.is_some() {
                (hooks.hook_eval_loss(&theta), hooks.hook_theta_err(&theta))
            } else {
                (None, None)
            };
            let dnet = dx.stats.since(&stats_at_row);
            stats_at_row = dx.stats;
            rec.push(IterRow {
                iter: updates,
                time: now,
                loss,
                eval_loss,
                theta_err,
                included: applied_shards,
                abandoned: 0,
                stale: 0,
                dropped: dnet.dropped as usize,
                duplicated: dnet.duplicated as usize,
                blocks: dnet.blocks_delivered as usize,
                stale_blocks: 0,
                alive: core.membership.alive(),
                gamma: None,
                grad_norm,
                recoveries: 0,
                rollback_iters: 0,
            });
        }
        if let Some(s) = stop {
            status = s;
            break;
        }
    }
    if core.heap.is_empty() && core.membership.alive() == 0 && status == RunStatus::Completed {
        status = RunStatus::ClusterDead { iter: updates };
    }
    core.heap.clear();

    let mean_staleness = if updates > 0 {
        Some(staleness_sum / updates as f64)
    } else {
        None
    };
    Ok(report::assemble(
        rec,
        theta,
        status,
        None,
        "async",
        &core,
        dx.stats,
        crate::agg::AggStats::default(),
        0,
        mean_staleness,
        0,
        0,
        driver_start,
        sink.summary(),
        serving.map(crate::serve::ServeEngine::finish),
    ))
}
