//! Pluggable aggregation topologies: how gradient replies travel from the
//! workers to the coordinator's fold.
//!
//! The seed system (and ROADMAP item 2's complaint about it) funnels every
//! reply straight into one coordinator — a *star*.  This module makes that
//! choice a policy: `star` keeps the legacy path bit for bit, `tree`
//! routes replies through interior relay nodes that fold their children's
//! partials before forwarding one combined message, and `ring` runs a
//! reduce-scatter + allgather collective over θ segments (Agarwal et al.,
//! *A Reliable Effective Terascale Linear Learning System*; Yu et al.,
//! *Distributed Learning over Unreliable Networks* — see PAPERS.md).
//!
//! Every interior edge routes through the sending node's link model via
//! [`NetSpec::realize_edge`], so per-hop drops, partitions, and per-worker
//! link overrides compose with the topology — and every hop's fate is
//! **pure** in `(seed, node, iter, round)`.  [`plan`] computes fates (who
//! is lost, which θ segments survive, per-node edge counts, the
//! `agg_fold`/`forward` trace events) from the delivered/dispatched sets
//! alone, never from arrival times, so the virtual and threaded drivers
//! realize identical fates by construction.  Arrival times only shape the
//! *timing* outputs (`at`), which the virtual driver uses and the
//! threaded driver ignores.  See `docs/AGGREGATION.md`.

use crate::net::{BlockSet, NetSpec, MAX_BLOCKS};
use crate::trace::{self, TraceEvent, TraceSink};
use crate::{Error, Result};

/// Which overlay the gradient replies travel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TopologyKind {
    /// Every reply goes straight to the coordinator — the legacy path,
    /// preserved bit for bit.
    #[default]
    Star,
    /// Interior nodes fold up to `fan_in` children's partials and forward
    /// one combined message toward the root.
    Tree,
    /// Reduce-scatter + allgather over θ segments among the delivered
    /// workers; the reduced vector attaches to the coordinator once.
    Ring,
}

impl TopologyKind {
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Star => "star",
            TopologyKind::Tree => "tree",
            TopologyKind::Ring => "ring",
        }
    }

    pub fn parse(text: &str) -> Result<TopologyKind> {
        match text.trim().to_ascii_lowercase().as_str() {
            "star" => Ok(TopologyKind::Star),
            "tree" => Ok(TopologyKind::Tree),
            "ring" => Ok(TopologyKind::Ring),
            other => Err(Error::Config(format!(
                "unknown aggregation topology '{other}' (want star|tree|ring)"
            ))),
        }
    }
}

/// The aggregation-topology policy: which overlay, its shape, and the
/// per-hop cost model.  The default (`star`, zero costs) reproduces the
/// pre-topology system bit for bit.
#[derive(Clone, Debug, PartialEq)]
pub struct AggSpec {
    pub topology: TopologyKind,
    /// Children per interior node (tree only).
    pub fan_in: usize,
    /// Seconds an interior node spends folding **one** full gradient
    /// vector (the bandwidth/β term); the root pays it per message too.
    pub fold_cost: f64,
    /// Fixed per-hop forwarding latency (the α term).
    pub xfer_cost: f64,
}

impl Default for AggSpec {
    fn default() -> Self {
        AggSpec { topology: TopologyKind::Star, fan_in: 8, fold_cost: 0.0, xfer_cost: 0.0 }
    }
}

impl AggSpec {
    pub fn star() -> AggSpec {
        AggSpec::default()
    }

    pub fn tree(fan_in: usize) -> AggSpec {
        AggSpec { topology: TopologyKind::Tree, fan_in, ..AggSpec::default() }
    }

    pub fn ring() -> AggSpec {
        AggSpec { topology: TopologyKind::Ring, ..AggSpec::default() }
    }

    /// Builder: set the per-hop cost model.
    pub fn with_costs(mut self, fold_cost: f64, xfer_cost: f64) -> AggSpec {
        self.fold_cost = fold_cost;
        self.xfer_cost = xfer_cost;
        self
    }

    pub fn is_star(&self) -> bool {
        self.topology == TopologyKind::Star
    }

    /// Root-side post-processing cost per message the coordinator folds.
    /// Zero by default, so the star path's arithmetic is untouched.
    pub fn root_cost(&self) -> f64 {
        self.fold_cost + self.xfer_cost
    }

    pub fn validate(&self, workers: usize, block_size: usize) -> Result<()> {
        if !(self.fold_cost.is_finite() && self.fold_cost >= 0.0)
            || !(self.xfer_cost.is_finite() && self.xfer_cost >= 0.0)
        {
            return Err(Error::Config(format!(
                "agg costs must be finite and >= 0 (fold {}, xfer {})",
                self.fold_cost, self.xfer_cost
            )));
        }
        match self.topology {
            TopologyKind::Star => Ok(()),
            TopologyKind::Tree => {
                if self.fan_in < 2 {
                    return Err(Error::Config(format!(
                        "tree aggregation needs fan_in >= 2, got {}",
                        self.fan_in
                    )));
                }
                if workers == 0 {
                    return Err(Error::Cluster("tree aggregation needs workers".into()));
                }
                Ok(())
            }
            TopologyKind::Ring => {
                if block_size > 0 {
                    return Err(Error::Config(
                        "ring aggregation already segments θ itself; \
                         it composes with [net] block_size = 0 only"
                            .into(),
                    ));
                }
                Ok(())
            }
        }
    }
}

/// Per-node interior-edge accounting lane.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EdgeLane {
    pub node: usize,
    pub sent: u64,
    pub delivered: u64,
    pub dropped: u64,
}

/// Run-level aggregation-overlay accounting, surfaced as `RunReport::agg`.
/// `delivered + dropped == sent` holds per lane by construction — the
/// cross-driver conservation oracle in `tests/property_topology.rs` pins
/// it down.
#[derive(Clone, Debug, PartialEq)]
pub struct AggStats {
    pub topology: &'static str,
    /// Interior (overlay) edges realized — leaf roundtrips are counted by
    /// `NetStats`, not here.
    pub edge_sent: u64,
    pub edge_delivered: u64,
    pub edge_dropped: u64,
    /// Fold operations performed at interior nodes (one per combined tree
    /// message; one per ring collective).
    pub folds: u64,
    /// Delivered leaf contributions lost to an interior-edge drop.
    pub lost_contributions: u64,
    pub per_node: Vec<EdgeLane>,
}

impl Default for AggStats {
    fn default() -> Self {
        AggStats {
            topology: "star",
            edge_sent: 0,
            edge_delivered: 0,
            edge_dropped: 0,
            folds: 0,
            lost_contributions: 0,
            per_node: Vec::new(),
        }
    }
}

impl AggStats {
    fn lane(&mut self, node: usize) -> &mut EdgeLane {
        match self.per_node.iter().position(|l| l.node == node) {
            Some(i) => &mut self.per_node[i],
            None => {
                self.per_node.push(EdgeLane { node, ..EdgeLane::default() });
                // Keep lanes sorted so both drivers report identical
                // vectors regardless of first-touch order.
                self.per_node.sort_unstable_by_key(|l| l.node);
                let i = self.per_node.iter().position(|l| l.node == node).unwrap();
                &mut self.per_node[i]
            }
        }
    }

    fn count(&mut self, node: usize, delivered: bool) {
        self.edge_sent += 1;
        if delivered {
            self.edge_delivered += 1;
        } else {
            self.edge_dropped += 1;
        }
        let lane = self.lane(node);
        lane.sent += 1;
        if delivered {
            lane.delivered += 1;
        } else {
            lane.dropped += 1;
        }
    }
}

/// Reusable per-iteration state for [`plan`] — the same zero-steady-state
/// -allocation discipline as the sync driver's `IterScratch`.
#[derive(Debug, Default)]
pub struct AggScratch {
    /// Input: `(worker, arrival)` of this iteration's delivered primary
    /// replies, any order (sorted in place by worker).
    pub arrivals: Vec<(usize, f64)>,
    /// Output: delivered leaves killed by an interior-edge drop.
    pub killed: Vec<bool>,
    /// Output: adjusted root-arrival time per surviving leaf (virtual
    /// driver only — the threaded driver keeps physical time).
    pub at: Vec<f64>,
    /// Output (ring): surviving θ-segment mask per participant.
    pub masks: Vec<BlockSet>,
    /// Output: number of killed leaves this iteration.
    pub killed_count: usize,
    /// Output: distinct messages the root folds this iteration (drives
    /// the post-hoc root cost).
    pub root_msgs: u32,
    // Tree internals: per-node input lists as intrusive linked lists so
    // relay merges are O(1) and nothing allocates in steady state.
    dispatched: Vec<bool>,
    relay: Vec<bool>,
    head: Vec<i64>,
    tail: Vec<i64>,
    next: Vec<i64>,
    in_max: Vec<f64>,
    in_cnt: Vec<u32>,
}

impl AggScratch {
    pub fn new() -> AggScratch {
        AggScratch::default()
    }

    fn reset(&mut self, workers: usize) {
        self.arrivals.sort_unstable_by(|a, b| a.0.cmp(&b.0));
        self.killed.clear();
        self.killed.resize(workers, false);
        self.at.clear();
        self.at.resize(workers, 0.0);
        self.masks.clear();
        self.masks.resize(workers, BlockSet::full(1));
        self.killed_count = 0;
        self.root_msgs = 0;
        self.dispatched.clear();
        self.dispatched.resize(workers, false);
        self.relay.clear();
        self.relay.resize(workers, false);
        self.head.clear();
        self.head.resize(workers, -1);
        self.tail.clear();
        self.tail.resize(workers, -1);
        self.next.clear();
        self.next.resize(workers, -1);
        self.in_max.clear();
        self.in_max.resize(workers, 0.0);
        self.in_cnt.clear();
        self.in_cnt.resize(workers, 0);
    }
}

/// Tree parent of worker `w`: the first `fan_in` workers hang off the
/// coordinator, node `p`'s children are `(p+1)*fan_in..(p+2)*fan_in`.
/// `parent(w) < w` always, so a single descending-index pass folds every
/// child before its parent.
fn parent(w: usize, fan_in: usize) -> i64 {
    if w < fan_in {
        trace::MASTER
    } else {
        (w / fan_in) as i64 - 1
    }
}

/// Nearest dispatched relay at or above `from` (itself a `parent()`
/// value), or [`trace::MASTER`]: non-dispatched interior nodes are
/// adopted past, exactly the "dead node ⇒ route around it" rule.
fn climb(mut from: i64, fan_in: usize, relay: &[bool]) -> i64 {
    while from >= 0 {
        if relay[from as usize] {
            return from;
        }
        from = parent(from as usize, fan_in);
    }
    trace::MASTER
}

/// The θ blocks ring chunk `c` owns when `n_p` participants share
/// `n_seg` segments (empty when positions outnumber segments).
fn chunk_blocks(c: usize, n_p: usize, n_seg: usize) -> BlockSet {
    let lo = c * n_seg / n_p;
    let hi = (c + 1) * n_seg / n_p;
    let mut set = BlockSet::empty(n_seg);
    for b in lo..hi {
        set = set.with(b);
    }
    set
}

/// Plan one iteration of the aggregation overlay.
///
/// Inputs: the dispatched set (`responders`) and the delivered primary
/// replies (`scratch.arrivals`, `(worker, arrival-time)`; the threaded
/// driver passes `0.0` times).  On return the scratch holds, per worker,
/// whether an interior drop killed its contribution, its adjusted root
/// arrival, and (ring) its surviving segment mask; `stats` accumulates
/// edge accounting and `sink` receives the `agg_fold`/`forward` fate
/// events.  Fates depend only on `(seed, iter)`, the two sets, and the
/// spec — never on times — which is the cross-driver parity contract.
#[allow(clippy::too_many_arguments)]
pub fn plan(
    spec: &AggSpec,
    net: &NetSpec,
    seed: u64,
    iter: u64,
    workers: usize,
    responders: &[usize],
    scratch: &mut AggScratch,
    stats: &mut AggStats,
    sink: &mut dyn TraceSink,
    now: f64,
) {
    stats.topology = spec.topology.name();
    scratch.reset(workers);
    match spec.topology {
        TopologyKind::Star => {
            // The star plan is the identity: every delivered leaf is a
            // root message at its own arrival time.
            for &(w, t) in scratch.arrivals.iter() {
                scratch.at[w] = t;
                scratch.root_msgs += 1;
            }
        }
        TopologyKind::Tree => {
            plan_tree(spec, net, seed, iter, workers, responders, scratch, stats, sink, now)
        }
        TopologyKind::Ring => plan_ring(spec, net, seed, iter, scratch, stats, sink, now),
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_tree(
    spec: &AggSpec,
    net: &NetSpec,
    seed: u64,
    iter: u64,
    workers: usize,
    responders: &[usize],
    scratch: &mut AggScratch,
    stats: &mut AggStats,
    sink: &mut dyn TraceSink,
    now: f64,
) {
    let fan_in = spec.fan_in;
    for &w in responders {
        scratch.dispatched[w] = true;
    }
    // A node relays iff it was dispatched this iteration and owns at
    // least one in-range child — pure in the dispatched set.
    for w in 0..workers {
        scratch.relay[w] = scratch.dispatched[w] && (w + 1) * fan_in < workers;
    }
    // Route every delivered leaf to its first relay (itself if it is
    // one), or straight to the root when no ancestor relays.
    for &(w, t) in scratch.arrivals.iter() {
        let target = if scratch.relay[w] {
            w as i64
        } else {
            climb(parent(w, fan_in), fan_in, &scratch.relay)
        };
        if target < 0 {
            scratch.at[w] = t;
            scratch.root_msgs += 1;
            continue;
        }
        let a = target as usize;
        if scratch.head[a] < 0 {
            scratch.head[a] = w as i64;
        } else {
            scratch.next[scratch.tail[a] as usize] = w as i64;
        }
        scratch.tail[a] = w as i64;
        scratch.next[w] = -1;
        scratch.in_max[a] = scratch.in_max[a].max(t);
        scratch.in_cnt[a] += 1;
    }
    // Descending pass: every child (leaf or relay) has already fed its
    // parent's inputs by the time the parent sends.  One combined
    // message per active relay per iteration ⇒ round key 0.
    for a in (0..workers).rev() {
        if !scratch.relay[a] || scratch.in_cnt[a] == 0 {
            continue;
        }
        let dest = climb(parent(a, fan_in), fan_in, &scratch.relay);
        let depart = scratch.in_max[a] + spec.fold_cost * scratch.in_cnt[a] as f64;
        let e = net.realize_edge(seed, a, iter, 0);
        let delivered = !e.up_dropped;
        stats.folds += 1;
        stats.count(a, delivered);
        if sink.enabled() {
            let fold = TraceEvent::AggFold { children: scratch.in_cnt[a] };
            sink.emit(iter, a as i64, now + depart, fold);
            let fwd = TraceEvent::Forward { to: dest, delivered };
            sink.emit(iter, a as i64, now + depart, fwd);
        }
        if !delivered {
            // The whole folded subtree dies on this edge.
            let mut n = scratch.head[a];
            while n >= 0 {
                scratch.killed[n as usize] = true;
                scratch.killed_count += 1;
                stats.lost_contributions += 1;
                n = scratch.next[n as usize];
            }
            continue;
        }
        let arrival = depart + spec.xfer_cost + e.up_delay;
        if dest < 0 {
            // Combined message lands at the root: every folded leaf
            // arrives, as one message, at the combined arrival time.
            let mut n = scratch.head[a];
            while n >= 0 {
                scratch.at[n as usize] = arrival;
                n = scratch.next[n as usize];
            }
            scratch.root_msgs += 1;
        } else {
            // Merge this subtree's leaf list into the parent relay.
            let b = dest as usize;
            if scratch.head[b] < 0 {
                scratch.head[b] = scratch.head[a];
            } else {
                scratch.next[scratch.tail[b] as usize] = scratch.head[a];
            }
            scratch.tail[b] = scratch.tail[a];
            scratch.in_max[b] = scratch.in_max[b].max(arrival);
            scratch.in_cnt[b] += 1;
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn plan_ring(
    spec: &AggSpec,
    net: &NetSpec,
    seed: u64,
    iter: u64,
    scratch: &mut AggScratch,
    stats: &mut AggStats,
    sink: &mut dyn TraceSink,
    now: f64,
) {
    // Participants are the delivered workers, in worker order — already
    // sorted by `reset()`.  θ splits into one segment per participant
    // (capped at the block-mask width).
    let n_p = scratch.arrivals.len();
    if n_p == 0 {
        return;
    }
    let n_seg = n_p.min(MAX_BLOCKS);
    let mut t_max = 0.0f64;
    for &(w, t) in scratch.arrivals.iter() {
        scratch.masks[w] = BlockSet::full(n_seg);
        t_max = t_max.max(t);
    }
    // Lossy interior edges: reduce-scatter then allgather, hop fates
    // pure in (seed, sender, iter, round).  Ideal nets skip the O(n_p²)
    // realization entirely — nothing can drop.
    if !net.is_ideal() {
        // Reduce-scatter round r: position p forwards the partial sum of
        // chunk (p+n_p-r) mod n_p — contributions of positions p-r..=p —
        // to its successor.  A drop loses exactly that partial: those
        // positions' segments clear, later positions keep accumulating
        // (Yu et al.'s partial-sum loss model).
        for r in 0..n_p.saturating_sub(1) {
            for p in 0..n_p {
                let sender = scratch.arrivals[p].0;
                let e = net.realize_edge(seed, sender, iter, r as u64 + 1);
                let delivered = !e.up_dropped;
                stats.count(sender, delivered);
                if delivered {
                    continue;
                }
                let chunk = (p + n_p - r) % n_p;
                let lost = chunk_blocks(chunk, n_p, n_seg);
                for k in 0..=r {
                    let q = (p + n_p - k) % n_p;
                    let w = scratch.arrivals[q].0;
                    scratch.masks[w] = scratch.masks[w].minus(lost);
                }
                if sink.enabled() {
                    let to = scratch.arrivals[(p + 1) % n_p].0 as i64;
                    let fwd = TraceEvent::Forward { to, delivered: false };
                    sink.emit(iter, sender as i64, now, fwd);
                }
            }
        }
        // Allgather: chunk c completes at position (c+n_p-1) mod n_p and
        // walks to position 0, where the reduced vector attaches to the
        // coordinator.  A dropped hop loses the chunk for everyone.
        for c in 0..n_p {
            let o = (c + n_p - 1) % n_p;
            let hops = (n_p - o) % n_p;
            for h in 0..hops {
                let q = (o + h) % n_p;
                let sender = scratch.arrivals[q].0;
                let round = n_p as u64 + (c as u64) * n_p as u64 + h as u64;
                let e = net.realize_edge(seed, sender, iter, round);
                let delivered = !e.up_dropped;
                stats.count(sender, delivered);
                if delivered {
                    continue;
                }
                let lost = chunk_blocks(c, n_p, n_seg);
                for &(w, _) in scratch.arrivals.iter() {
                    scratch.masks[w] = scratch.masks[w].minus(lost);
                }
                if sink.enabled() {
                    let to = scratch.arrivals[(q + 1) % n_p].0 as i64;
                    let fwd = TraceEvent::Forward { to, delivered: false };
                    sink.emit(iter, sender as i64, now, fwd);
                }
                break;
            }
        }
    }
    // The collective cannot start before the last participant finishes:
    // 2(n_p-1) pipelined hops, each moving 1/n_p of θ.  Realized hop
    // delays model *fates* only; latency rides the α/β cost terms
    // (docs/AGGREGATION.md documents the scope).
    let t_root = t_max + 2.0 * (n_p as f64 - 1.0) * (spec.xfer_cost + spec.fold_cost / n_p as f64);
    stats.folds += 1;
    scratch.root_msgs = 1;
    for &(w, _) in scratch.arrivals.iter() {
        if scratch.masks[w].is_empty() {
            scratch.killed[w] = true;
            scratch.killed_count += 1;
            stats.lost_contributions += 1;
        } else {
            scratch.at[w] = t_root;
        }
    }
    if sink.enabled() {
        let fold = TraceEvent::AggFold { children: n_p as u32 };
        sink.emit(iter, trace::MASTER, now + t_root, fold);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{JournalSink, NoopSink};

    fn all(m: usize) -> Vec<usize> {
        (0..m).collect()
    }

    #[test]
    fn parse_and_validate() {
        assert_eq!(TopologyKind::parse("TREE").unwrap(), TopologyKind::Tree);
        assert!(TopologyKind::parse("mesh").is_err());
        assert!(AggSpec::tree(8).validate(16, 0).is_ok());
        assert!(AggSpec::tree(1).validate(16, 0).is_err());
        assert!(AggSpec::ring().validate(16, 4).is_err(), "ring must reject block admission");
        assert!(AggSpec::star().validate(16, 4).is_ok());
        assert!(AggSpec::star().with_costs(-1.0, 0.0).validate(4, 0).is_err());
    }

    #[test]
    fn tree_parent_is_always_smaller() {
        for fan_in in [2usize, 3, 8] {
            for w in 0..200usize {
                let p = parent(w, fan_in);
                assert!(p < w as i64, "parent({w}, {fan_in}) = {p}");
            }
        }
    }

    #[test]
    fn tree_ideal_routes_everyone_at_subtree_maxima() {
        let spec = AggSpec::tree(2);
        let net = NetSpec::ideal();
        let m = 7usize;
        let mut scratch = AggScratch::new();
        let mut stats = AggStats::default();
        scratch.arrivals = (0..m).map(|w| (w, 0.01 * (w + 1) as f64)).collect();
        plan(&spec, &net, 1, 0, m, &all(m), &mut scratch, &mut stats, &mut NoopSink, 0.0);
        assert_eq!(scratch.killed_count, 0);
        // With zero costs and ideal links, each leaf lands at the max of
        // the subtree it folded into, never later than the global max.
        let global = 0.07;
        for w in 0..m {
            assert!(!scratch.killed[w]);
            assert!(scratch.at[w] <= global + 1e-12, "at[{w}] = {}", scratch.at[w]);
            assert!(scratch.at[w] >= 0.01 * (w + 1) as f64 - 1e-12);
        }
        // Nodes 0 and 1 relay (children 2,3 / 4,5); node 2 relays (6).
        assert_eq!(stats.folds, 3);
        assert_eq!(stats.edge_sent, 3);
        assert_eq!(stats.edge_dropped, 0);
        // Everything ultimately funnels through relays 0 and 1.
        assert_eq!(scratch.root_msgs, 2);
    }

    #[test]
    fn tree_adopts_past_non_dispatched_relays() {
        let spec = AggSpec::tree(2);
        let net = NetSpec::ideal();
        let m = 7usize;
        // Node 2 (relay for 6) is not dispatched: 6 must climb to 0.
        let responders: Vec<usize> = (0..m).filter(|&w| w != 2).collect();
        let mut scratch = AggScratch::new();
        let mut stats = AggStats::default();
        scratch.arrivals = responders.iter().map(|&w| (w, 0.01)).collect();
        plan(&spec, &net, 1, 0, m, &responders, &mut scratch, &mut stats, &mut NoopSink, 0.0);
        assert_eq!(scratch.killed_count, 0);
        assert_eq!(stats.folds, 2, "only relays 0 and 1 fold");
        assert!(!scratch.killed[6]);
    }

    #[test]
    fn tree_interior_drop_kills_the_subtree_purely() {
        let spec = AggSpec::tree(2);
        let net = NetSpec::lossy(0.5);
        let m = 15usize;
        let run = || {
            let mut scratch = AggScratch::new();
            let mut stats = AggStats::default();
            let mut killed = Vec::new();
            for iter in 0..50u64 {
                scratch.arrivals = (0..m).map(|w| (w, 0.01)).collect();
                let sink = &mut NoopSink;
                plan(&spec, &net, 9, iter, m, &all(m), &mut scratch, &mut stats, sink, 0.0);
                killed.push(scratch.killed.clone());
            }
            (killed, stats)
        };
        let (k1, s1) = run();
        let (k2, s2) = run();
        assert_eq!(k1, k2, "interior fates must be pure");
        assert_eq!(s1, s2);
        assert!(s1.edge_dropped > 0, "50% loss never dropped an interior edge");
        assert_eq!(s1.edge_sent, s1.edge_delivered + s1.edge_dropped);
        assert_eq!(
            s1.lost_contributions,
            k1.iter().map(|k| k.iter().filter(|&&x| x).count() as u64).sum::<u64>()
        );
        for lane in &s1.per_node {
            assert_eq!(lane.sent, lane.delivered + lane.dropped);
        }
    }

    #[test]
    fn tree_fates_ignore_arrival_times() {
        // The threaded driver passes zero times; fates must not care.
        let spec = AggSpec::tree(4);
        let net = NetSpec::lossy(0.3);
        let m = 20usize;
        let run = |times: bool| {
            let mut scratch = AggScratch::new();
            let mut stats = AggStats::default();
            let mut sink = JournalSink::new();
            for iter in 0..30u64 {
                scratch.arrivals = (0..m)
                    .map(|w| (w, if times { 0.01 * (w + 1) as f64 } else { 0.0 }))
                    .collect();
                plan(&spec, &net, 5, iter, m, &all(m), &mut scratch, &mut stats, &mut sink, 0.0);
            }
            (stats, sink.fate_jsonl())
        };
        let (s1, f1) = run(true);
        let (s2, f2) = run(false);
        assert_eq!(s1, s2);
        assert_eq!(f1, f2, "fate journal must be time-independent");
    }

    #[test]
    fn ring_ideal_is_full_and_synchronous() {
        let spec = AggSpec::ring().with_costs(0.0, 0.0);
        let net = NetSpec::ideal();
        let m = 5usize;
        let mut scratch = AggScratch::new();
        let mut stats = AggStats::default();
        scratch.arrivals = (0..m).map(|w| (w, 0.01 * (w + 1) as f64)).collect();
        plan(&spec, &net, 1, 0, m, &all(m), &mut scratch, &mut stats, &mut NoopSink, 0.0);
        for w in 0..m {
            assert!(!scratch.killed[w]);
            assert!(scratch.masks[w].is_full());
            assert!((scratch.at[w] - 0.05).abs() < 1e-12, "all land at the global max");
        }
        assert_eq!(scratch.root_msgs, 1);
        assert_eq!(stats.edge_sent, 0, "ideal rings realize no edges");
    }

    #[test]
    fn ring_costs_scale_with_participants() {
        let spec = AggSpec::ring().with_costs(0.0, 1e-3);
        let net = NetSpec::ideal();
        let m = 9usize;
        let mut scratch = AggScratch::new();
        let mut stats = AggStats::default();
        scratch.arrivals = (0..m).map(|w| (w, 0.0)).collect();
        plan(&spec, &net, 1, 0, m, &all(m), &mut scratch, &mut stats, &mut NoopSink, 0.0);
        let want = 2.0 * 8.0 * 1e-3;
        assert!((scratch.at[0] - want).abs() < 1e-12, "at = {}", scratch.at[0]);
    }

    #[test]
    fn ring_drops_clear_segments_conservatively() {
        let spec = AggSpec::ring();
        let net = NetSpec::lossy(0.2);
        let m = 8usize;
        let run = || {
            let mut scratch = AggScratch::new();
            let mut stats = AggStats::default();
            let mut partial = 0usize;
            for iter in 0..40u64 {
                scratch.arrivals = (0..m).map(|w| (w, 0.01)).collect();
                let sink = &mut NoopSink;
                plan(&spec, &net, 3, iter, m, &all(m), &mut scratch, &mut stats, sink, 0.0);
                for w in 0..m {
                    if !scratch.killed[w] && !scratch.masks[w].is_full() {
                        partial += 1;
                    }
                }
            }
            (partial, stats)
        };
        let (p1, s1) = run();
        let (p2, s2) = run();
        assert_eq!(s1, s2, "ring fates must be pure");
        assert_eq!(p1, p2);
        assert!(p1 > 0, "20% loss never produced a partial mask");
        assert!(s1.edge_dropped > 0);
        assert_eq!(s1.edge_sent, s1.edge_delivered + s1.edge_dropped);
        for lane in &s1.per_node {
            assert_eq!(lane.sent, lane.delivered + lane.dropped);
        }
    }

    #[test]
    fn star_plan_is_identity() {
        let spec = AggSpec::star();
        let net = NetSpec::lossy(0.5);
        let m = 4usize;
        let mut scratch = AggScratch::new();
        let mut stats = AggStats::default();
        scratch.arrivals = vec![(2, 0.02), (0, 0.03)];
        plan(&spec, &net, 1, 7, m, &all(m), &mut scratch, &mut stats, &mut NoopSink, 0.0);
        assert_eq!(scratch.killed_count, 0);
        assert_eq!(scratch.at[2], 0.02);
        assert_eq!(scratch.at[0], 0.03);
        assert_eq!(scratch.root_msgs, 2);
        assert_eq!(stats.edge_sent, 0);
    }
}
