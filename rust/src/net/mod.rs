//! Unreliable-network transport layer: lossy, delayed, duplicated, and
//! partitionable coordinator↔worker links.
//!
//! The straggler subsystem ([`crate::straggler`]) perturbs *compute*; this
//! module perturbs *communication*.  Yu et al. (arXiv:1810.07766) show that
//! message loss and delay interact with convergence in ways compute-side
//! faults do not, and Qiao et al. (arXiv:1810.07354) motivate treating a
//! dropped update as a first-class perturbation rather than a crash — so
//! network severity is a sweepable input here, exactly like
//! [`crate::straggler::StragglerProfile`] sweeps compute severity.
//!
//! # Pieces
//!
//! * [`LinkModel`] — one link's personality: per-message latency
//!   distribution, drop probability, duplication probability — with
//!   optional per-direction [`LinkDir`] overrides (slow lossy uplink under
//!   a fast clean downlink);
//! * [`NetSpec`] — the whole cluster's network: a default link, per-worker
//!   overrides (asymmetric topologies), and scripted partition windows
//!   ("workers 3..6 unreachable during iterations 40..60");
//! * [`Transport`] / [`VirtualTransport`] — virtual-time delivery for the
//!   discrete-event simulator: sends schedule delivery events, polls pop
//!   them in arrival order;
//! * [`NetShim`] — the threaded runtime's channel wrapper: the master
//!   consults it before every `Work` broadcast and on every `Grad` receipt;
//! * [`NetStats`] — message-level accounting (sent / delivered / dropped /
//!   duplicated), reported per run and per iteration.
//!
//! # Cross-driver determinism
//!
//! Every message's fate is a **pure function** of
//! `(cluster seed, worker, iteration)` — see [`NetSpec::realize`].  No
//! shared RNG stream is consumed in arrival order, so the virtual simulator
//! and the threaded runtime realize *identical* drops, duplicates, and
//! delays for the same spec and seed, and `tests/parity_drivers.rs` can
//! assert equal delivery counts across drivers.  [`NetSpec::ideal`] (the
//! default) short-circuits all sampling and reproduces the pre-transport
//! behaviour bit for bit.
//!
//! See `docs/NETWORK.md` for a scenario cookbook.

pub mod block;
pub mod link;
pub mod shim;
pub mod spec;
pub mod transport;

pub use block::{BlockLedger, BlockSet, MAX_BLOCKS};
pub use link::{LinkDir, LinkModel, LinkRealization};
pub use shim::{GradFate, NetShim, ThetaLedger, WorkPlan};
pub use spec::{NetSpec, Partition};
pub use transport::{Delivery, Transport, VirtualTransport};

/// Message-level delivery accounting.  Counts individual messages (a
/// `Work` broadcast and its `Grad` reply are two messages); `duplicated`
/// counts extra delivered copies on top of `delivered`.  Invariant:
/// `sent == delivered + dropped`.
///
/// The `blocks_*` counters account **primary-reply gradient blocks** when
/// block admission is active (`NetSpec::block_size > 0` chunking into more
/// than one block); they stay zero otherwise so non-blocking runs report
/// exactly what they always did.  Blocks are counted only once the `Work`
/// broadcast delivers (a worker that never computed dispatched no blocks),
/// and duplicate copies are accounted at message level only.  Invariant:
/// `blocks_sent == blocks_delivered + blocks_dropped`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetStats {
    pub sent: u64,
    pub delivered: u64,
    pub dropped: u64,
    pub duplicated: u64,
    pub blocks_sent: u64,
    pub blocks_delivered: u64,
    pub blocks_dropped: u64,
}

impl NetStats {
    /// Fraction of sent messages that were dropped.
    pub fn drop_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.dropped as f64 / self.sent as f64
        }
    }

    /// Counts accumulated since an `earlier` snapshot (per-iteration deltas
    /// for the recorder).
    pub fn since(&self, earlier: &NetStats) -> NetStats {
        NetStats {
            sent: self.sent - earlier.sent,
            delivered: self.delivered - earlier.delivered,
            dropped: self.dropped - earlier.dropped,
            duplicated: self.duplicated - earlier.duplicated,
            blocks_sent: self.blocks_sent - earlier.blocks_sent,
            blocks_delivered: self.blocks_delivered - earlier.blocks_delivered,
            blocks_dropped: self.blocks_dropped - earlier.blocks_dropped,
        }
    }

    /// Account one Work→Grad roundtrip realization; returns whether the
    /// reply survives to delivery.  `count_dup` lets the sync drivers count
    /// the duplicated reply copy; the async drivers apply at-most-once per
    /// arrival and pass `false`.
    pub fn count_roundtrip(&mut self, r: &LinkRealization, count_dup: bool) -> bool {
        self.sent += 1; // Work
        if r.down_dropped {
            self.dropped += 1;
            return false;
        }
        self.delivered += 1;
        self.sent += 1; // Grad
        if r.up_dropped {
            self.dropped += 1;
            return false;
        }
        self.delivered += 1;
        if count_dup && r.up_duplicated {
            self.duplicated += 1;
        }
        true
    }

    /// Account one roundtrip under **block admission**: the reply chunks
    /// into `blocks.len()` blocks whose realized delivered set is `blocks`,
    /// and `admitted` is the spec's threshold decision
    /// ([`NetSpec::admits`]).  Block counters record what the network
    /// physically realized; a below-threshold reply still counts its
    /// delivered blocks but the *message* counts dropped (the drivers
    /// treat it as loss).  Returns whether the reply surfaces.
    pub fn count_roundtrip_blocks(
        &mut self,
        r: &LinkRealization,
        blocks: BlockSet,
        admitted: bool,
        count_dup: bool,
    ) -> bool {
        self.sent += 1; // Work
        if r.down_dropped {
            self.dropped += 1;
            return false;
        }
        self.delivered += 1;
        self.sent += 1; // Grad
        self.blocks_sent += blocks.len() as u64;
        self.blocks_delivered += blocks.delivered() as u64;
        self.blocks_dropped += (blocks.len() - blocks.delivered()) as u64;
        if !admitted {
            self.dropped += 1;
            return false;
        }
        self.delivered += 1;
        if count_dup && r.up_duplicated {
            self.duplicated += 1;
        }
        true
    }

    /// Ideal-net fast-path block accounting: all `n` blocks of one reply
    /// delivered, no sampling.
    pub fn count_blocks_ideal(&mut self, n: usize) {
        self.blocks_sent += n as u64;
        self.blocks_delivered += n as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_accounting_invariant() {
        let mut s = NetStats::default();
        assert!(s.count_roundtrip(&LinkRealization::ideal(), true));
        assert_eq!(s.sent, 2);
        assert_eq!(s.delivered, 2);
        assert_eq!(s.dropped, 0);

        let mut r = LinkRealization::ideal();
        r.up_dropped = true;
        assert!(!s.count_roundtrip(&r, true));
        assert_eq!(s.sent, 4);
        assert_eq!(s.delivered, 3);
        assert_eq!(s.dropped, 1);

        assert!(!s.count_roundtrip(&LinkRealization::partitioned(), true));
        assert_eq!(s.sent, 5);
        assert_eq!(s.dropped, 2);
        assert_eq!(s.sent, s.delivered + s.dropped);
    }

    #[test]
    fn duplicate_counted_only_when_asked() {
        let mut r = LinkRealization::ideal();
        r.up_duplicated = true;
        let mut s = NetStats::default();
        assert!(s.count_roundtrip(&r, false));
        assert_eq!(s.duplicated, 0);
        assert!(s.count_roundtrip(&r, true));
        assert_eq!(s.duplicated, 1);
    }

    #[test]
    fn since_gives_deltas() {
        let a = NetStats {
            sent: 10,
            delivered: 7,
            dropped: 3,
            duplicated: 1,
            blocks_sent: 8,
            blocks_delivered: 6,
            blocks_dropped: 2,
        };
        let b = NetStats {
            sent: 14,
            delivered: 10,
            dropped: 4,
            duplicated: 1,
            blocks_sent: 16,
            blocks_delivered: 13,
            blocks_dropped: 3,
        };
        let d = b.since(&a);
        assert_eq!(
            d,
            NetStats {
                sent: 4,
                delivered: 3,
                dropped: 1,
                duplicated: 0,
                blocks_sent: 8,
                blocks_delivered: 7,
                blocks_dropped: 1,
            }
        );
    }

    #[test]
    fn drop_rate_handles_empty() {
        assert_eq!(NetStats::default().drop_rate(), 0.0);
        let s = NetStats { sent: 10, delivered: 8, dropped: 2, ..NetStats::default() };
        assert!((s.drop_rate() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn blocked_roundtrip_accounting_invariants() {
        let mut s = NetStats::default();
        // Partial delivery above threshold: message delivered, block split
        // recorded.
        let partial = BlockSet::empty(4).with(0).with(2).with(3);
        assert!(s.count_roundtrip_blocks(&LinkRealization::ideal(), partial, true, true));
        assert_eq!((s.sent, s.delivered, s.dropped), (2, 2, 0));
        assert_eq!((s.blocks_sent, s.blocks_delivered, s.blocks_dropped), (4, 3, 1));

        // Below threshold: blocks still realized, message counts dropped.
        let thin = BlockSet::empty(4).with(1);
        assert!(!s.count_roundtrip_blocks(&LinkRealization::ideal(), thin, false, true));
        assert_eq!((s.sent, s.delivered, s.dropped), (4, 3, 1));
        assert_eq!((s.blocks_sent, s.blocks_delivered, s.blocks_dropped), (8, 4, 4));

        // Down drop: no blocks dispatched at all.
        let mut r = LinkRealization::ideal();
        r.down_dropped = true;
        assert!(!s.count_roundtrip_blocks(&r, BlockSet::full(4), true, true));
        assert_eq!(s.blocks_sent, 8);
        assert_eq!(s.sent, s.delivered + s.dropped);
        assert_eq!(s.blocks_sent, s.blocks_delivered + s.blocks_dropped);

        // Ideal fast path.
        s.count_blocks_ideal(4);
        assert_eq!(s.blocks_sent, 12);
        assert_eq!(s.blocks_delivered, 8);
        assert_eq!(s.blocks_sent, s.blocks_delivered + s.blocks_dropped);
    }
}
