//! GPT-2-style parameter initialization, mirroring
//! `python/compile/transformer.py::init_params` semantics:
//! N(0, 0.02) weights, residual projections (`wo`, `w2`) scaled by
//! `1/sqrt(2·n_layer)`, zero biases, unit LN scales.
//!
//! (Numerically independent of the python init — different RNG — but the
//! same distribution family; the e2e loss trajectories match in shape.)

use crate::lm::LmTask;
use crate::util::rng::Pcg64;

/// Initialize the flat parameter vector for a task.
pub fn init_params(task: &LmTask, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0x1217);
    let mut out = vec![0.0f32; task.n_params];
    let resid_scale = 1.0 / (2.0 * task.n_layer.max(1) as f64).sqrt();
    let mut off = 0usize;
    for spec in &task.params {
        let n = spec.elements();
        let dst = &mut out[off..off + n];
        let base = spec.name.rsplit('.').next().unwrap_or(&spec.name);
        if base.ends_with("_scale") {
            dst.fill(1.0);
        } else if base.ends_with("_bias") || base == "b1" || base == "b2" {
            // zeros (already)
        } else {
            let std = if base == "wo" || base == "w2" {
                0.02 * resid_scale
            } else {
                0.02
            };
            rng.fill_normal(dst, 0.0, std as f32);
        }
        off += n;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Dtype, TensorSpec};

    fn task() -> LmTask {
        let params = vec![
            TensorSpec { name: "embed".into(), shape: vec![32, 8], dtype: Dtype::F32 },
            TensorSpec { name: "layer0.ln1_scale".into(), shape: vec![8], dtype: Dtype::F32 },
            TensorSpec { name: "layer0.ln1_bias".into(), shape: vec![8], dtype: Dtype::F32 },
            TensorSpec { name: "layer0.wo".into(), shape: vec![8, 8], dtype: Dtype::F32 },
            TensorSpec { name: "layer0.b1".into(), shape: vec![8], dtype: Dtype::F32 },
        ];
        let n_params = 32 * 8 + 8 + 8 + 64 + 8;
        LmTask {
            config: "t".into(),
            vocab: 32,
            d_model: 8,
            n_head: 2,
            n_layer: 1,
            seq: 4,
            batch: 2,
            d_ff: 32,
            params,
            n_params,
        }
    }

    #[test]
    fn sections_follow_init_rules() {
        let t = task();
        let p = init_params(&t, 0);
        // embed: nonzero normals
        assert!(p[..256].iter().any(|&v| v != 0.0));
        assert!(p[..256].iter().all(|&v| v.abs() < 0.2));
        // ln1_scale: ones
        assert!(p[256..264].iter().all(|&v| v == 1.0));
        // ln1_bias: zeros
        assert!(p[264..272].iter().all(|&v| v == 0.0));
        // wo: scaled down vs embed
        let wo = &p[272..336];
        let std = (wo.iter().map(|&v| (v as f64).powi(2)).sum::<f64>() / 64.0).sqrt();
        assert!(std < 0.02, "wo std={std}");
        // b1: zeros
        assert!(p[336..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn deterministic() {
        let t = task();
        assert_eq!(init_params(&t, 9), init_params(&t, 9));
        assert_ne!(init_params(&t, 9), init_params(&t, 10));
    }
}
