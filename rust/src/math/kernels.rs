//! Fused, register-tiled KRR gradient kernels — the L3 perf pass.
//!
//! The seed hot path computed one shard gradient as two full sweeps of Φ
//! (`matvec` for the residual, then `matvec_t` for Φᵀr) plus a fresh
//! `Vec` per call.  For the default shard (ζ=2048, l=64) Φ is 512 KiB, so
//! the second sweep re-streams the whole matrix from L2/DRAM, and the
//! per-row residual dot is a single f64 dependency chain the CPU cannot
//! pipeline.
//!
//! [`fused_resid_grad`] makes one pass: rows are processed in tiles of
//! [`ROW_TILE`], each tile's residual dots run as `ROW_TILE` *independent*
//! f64 accumulator chains (register-tiled, so the adds pipeline across
//! rows), and the Φᵀr update happens per-row while the tile is still hot
//! in L1.  The loss sum rides along in the same sweep.
//!
//! **Equivalence contract** (golden-tested in `tests/parity_drivers.rs`):
//! the fused kernel is *bit-identical* to the two-pass reference, not
//! merely close.  Per row, the residual is the same f64 dot fold in the
//! same element order; per gradient coordinate, the f32 accumulation
//! visits rows in the same ascending order; the loss sum folds residuals
//! in the same order.  IEEE arithmetic is deterministic, so reordering
//! *independent* chains across rows changes nothing — only the schedule
//! the CPU sees.  This is why the perf pass cannot move θ trajectories:
//! every driver, test, and bench sees the exact bits the reference
//! produced, just sooner.

use crate::math::vec_ops;

/// Rows per register tile.  Eight f64 accumulators fit one AVX-512 (or two
/// AVX2) vector registers and give the out-of-order core ~8 independent
/// add chains to pipeline; larger tiles spill accumulators to the stack.
pub const ROW_TILE: usize = 8;

/// Reference two-pass kernel (the seed implementation, kept verbatim as
/// the golden baseline): `r = Φθ − y` by [`vec_ops::matvec`], loss sum in
/// row order, then `grad = Φᵀr` by [`vec_ops::matvec_t`].  `resid` is a
/// caller scratch buffer grown as needed; `grad` is fully overwritten.
/// Returns the residual sum of squares.
pub fn reference_resid_grad(
    phi: &[f32],
    rows: usize,
    l: usize,
    theta: &[f32],
    y: &[f32],
    resid: &mut Vec<f32>,
    grad: &mut [f32],
) -> f64 {
    assert_eq!(phi.len(), rows * l);
    assert_eq!(y.len(), rows);
    if resid.len() < rows {
        resid.resize(rows, 0.0);
    }
    let resid = &mut resid[..rows];
    vec_ops::matvec(phi, rows, l, theta, resid);
    let mut ss = 0.0f64;
    for (r, &yi) in resid.iter_mut().zip(y.iter()) {
        *r -= yi;
        ss += (*r as f64) * (*r as f64);
    }
    vec_ops::matvec_t(phi, rows, l, resid, grad);
    ss
}

/// Fused single-pass kernel: computes `grad = Φᵀ(Φθ − y)` and returns the
/// residual sum of squares in one sweep of Φ.  `grad` is fully
/// overwritten; no residual buffer is needed (tile residuals live in
/// registers).  Bit-identical to [`reference_resid_grad`] — see the
/// module docs for why.
pub fn fused_resid_grad(
    phi: &[f32],
    rows: usize,
    l: usize,
    theta: &[f32],
    y: &[f32],
    grad: &mut [f32],
) -> f64 {
    assert_eq!(phi.len(), rows * l);
    assert_eq!(theta.len(), l);
    assert_eq!(y.len(), rows);
    assert_eq!(grad.len(), l);
    grad.fill(0.0);

    let mut ss = 0.0f64;
    let tiles = rows / ROW_TILE;
    for tile in 0..tiles {
        let base = tile * ROW_TILE;
        let block = &phi[base * l..(base + ROW_TILE) * l];

        // Residual dots: ROW_TILE independent f64 chains, each folding its
        // row's elements in ascending j — the exact `vec_ops::dot` order.
        let mut acc = [0.0f64; ROW_TILE];
        for (j, &th) in theta.iter().enumerate() {
            let tj = th as f64;
            for (t, a) in acc.iter_mut().enumerate() {
                *a += block[t * l + j] as f64 * tj;
            }
        }

        // Subtract labels and fold the loss sum in ascending row order.
        let mut r = [0.0f32; ROW_TILE];
        for t in 0..ROW_TILE {
            let ri = acc[t] as f32 - y[base + t];
            r[t] = ri;
            ss += ri as f64 * ri as f64;
        }

        // Φᵀr for the tile: per-row axpy (vectorized across j) while the
        // tile is L1-hot.  Per gradient coordinate the adds still happen
        // in ascending row order, matching `vec_ops::matvec_t`.
        for t in 0..ROW_TILE {
            vec_ops::axpy(r[t], &block[t * l..(t + 1) * l], grad);
        }
    }

    // Tail rows (rows % ROW_TILE), one at a time in the same order.
    for i in (tiles * ROW_TILE)..rows {
        let row = &phi[i * l..(i + 1) * l];
        let ri = vec_ops::dot(row, theta) as f32 - y[i];
        ss += ri as f64 * ri as f64;
        vec_ops::axpy(ri, row, grad);
    }
    ss
}

/// Column-block width of [`blocked_resid_grad`]'s second pass: 64 f32
/// (four cache lines) of gradient accumulator stay L1-resident while
/// every row streams past once.
pub const COL_BLOCK: usize = 64;

/// Two-pass, column-blocked kernel for wide gradients — the shapes where
/// `l` outgrows what the fused kernel's per-row Φᵀr update keeps
/// cache-resident (each row re-touches the whole `l`-wide gradient).
///
/// Pass 1 computes residuals and the loss sum exactly as
/// [`reference_resid_grad`] does.  Pass 2 walks Φᵀr one
/// [`COL_BLOCK`]-wide column stripe at a time: the stripe's accumulator
/// stays hot in L1 while all rows stream past.  Per gradient coordinate
/// the f32 adds still visit rows in ascending order — the same fold as
/// [`vec_ops::matvec_t`] and the fused kernel — so the result is
/// **bit-identical** to both (`blocked_is_bit_identical_to_reference`).
/// `resid` is a caller scratch buffer grown as needed.
pub fn blocked_resid_grad(
    phi: &[f32],
    rows: usize,
    l: usize,
    theta: &[f32],
    y: &[f32],
    resid: &mut Vec<f32>,
    grad: &mut [f32],
) -> f64 {
    assert_eq!(phi.len(), rows * l);
    assert_eq!(theta.len(), l);
    assert_eq!(y.len(), rows);
    assert_eq!(grad.len(), l);
    if resid.len() < rows {
        resid.resize(rows, 0.0);
    }
    let resid = &mut resid[..rows];
    vec_ops::matvec(phi, rows, l, theta, resid);
    let mut ss = 0.0f64;
    for (r, &yi) in resid.iter_mut().zip(y.iter()) {
        *r -= yi;
        ss += (*r as f64) * (*r as f64);
    }
    grad.fill(0.0);
    let mut j0 = 0;
    while j0 < l {
        let j1 = (j0 + COL_BLOCK).min(l);
        let stripe = &mut grad[j0..j1];
        for (i, &ri) in resid.iter().enumerate() {
            vec_ops::axpy(ri, &phi[i * l + j0..i * l + j1], stripe);
        }
        j0 = j1;
    }
    ss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn random_problem(rows: usize, l: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut rng = Pcg64::seeded(seed);
        let mut phi = vec![0.0f32; rows * l];
        rng.fill_normal(&mut phi, 0.0, 0.3);
        let mut y = vec![0.0f32; rows];
        rng.fill_normal(&mut y, 0.0, 1.0);
        let mut theta = vec![0.0f32; l];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        (phi, y, theta)
    }

    #[test]
    fn fused_is_bit_identical_to_reference() {
        // Tiled rows, tail rows, and tiny shapes all round-trip exactly.
        for &(rows, l) in &[(32usize, 8usize), (37, 16), (8, 1), (5, 4), (256, 64)] {
            let (phi, y, theta) = random_problem(rows, l, 7 + rows as u64);
            let mut resid = Vec::new();
            let mut g_ref = vec![0.0f32; l];
            let ss_ref = reference_resid_grad(&phi, rows, l, &theta, &y, &mut resid, &mut g_ref);
            let mut g_fused = vec![0.0f32; l];
            let ss_fused = fused_resid_grad(&phi, rows, l, &theta, &y, &mut g_fused);
            assert_eq!(g_ref, g_fused, "grad bits diverged at rows={rows} l={l}");
            assert_eq!(ss_ref.to_bits(), ss_fused.to_bits(), "loss bits diverged");
        }
    }

    #[test]
    fn blocked_is_bit_identical_to_reference() {
        // Stripe-width multiples, ragged tails, narrow and wide shapes.
        for &(rows, l) in &[(32usize, 8usize), (37, 16), (8, 1), (5, 4), (256, 64), (64, 300)] {
            let (phi, y, theta) = random_problem(rows, l, 13 + l as u64);
            let mut resid = Vec::new();
            let mut g_ref = vec![0.0f32; l];
            let ss_ref = reference_resid_grad(&phi, rows, l, &theta, &y, &mut resid, &mut g_ref);
            let mut resid_b = Vec::new();
            let mut g_blk = vec![0.0f32; l];
            let ss_blk = blocked_resid_grad(&phi, rows, l, &theta, &y, &mut resid_b, &mut g_blk);
            assert_eq!(g_ref, g_blk, "grad bits diverged at rows={rows} l={l}");
            assert_eq!(ss_ref.to_bits(), ss_blk.to_bits(), "loss bits diverged");
        }
    }

    #[test]
    fn fused_matches_manual_small_case() {
        // Φ = [[1, 2], [3, 4]], θ = [1, -1], y = [0, 0]:
        // r = [-1, -1]; Φᵀr = [-4, -6]; ss = 2.
        let phi = vec![1.0, 2.0, 3.0, 4.0];
        let y = vec![0.0, 0.0];
        let theta = vec![1.0, -1.0];
        let mut grad = vec![0.0f32; 2];
        let ss = fused_resid_grad(&phi, 2, 2, &theta, &y, &mut grad);
        assert_eq!(grad, vec![-4.0, -6.0]);
        assert!((ss - 2.0).abs() < 1e-12);
    }

    #[test]
    fn zero_rows_is_zero() {
        let mut grad = vec![1.0f32; 4];
        let ss = fused_resid_grad(&[], 0, 4, &[0.0; 4], &[], &mut grad);
        assert_eq!(ss, 0.0);
        assert_eq!(grad, vec![0.0; 4]);
    }
}
