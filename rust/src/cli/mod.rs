//! Command-line argument parsing (clap is not in the vendor set).

pub mod args;

pub use args::{ArgSpec, Parsed};
