//! The threaded runtime's channel shim.
//!
//! The master consults the shim before every `Work` broadcast and on every
//! `Grad` receipt.  Because a message's fate is a pure function of
//! `(seed, worker, iteration)` ([`NetSpec::realize`]), the shim needs no
//! per-iteration state: a stale reply from three iterations ago re-realizes
//! its own iteration's fate correctly.
//!
//! **Accounting happens at broadcast (plan) time** — the reply's fate is
//! already determined then — so the counts match the virtual driver's
//! exactly even though real replies land on wall-clock.  (The counts
//! assume the addressed worker actually replies; a stochastic thread
//! crash diverges the drivers' counts, just as it already diverges their
//! abandonment totals.)
//!
//! The same purity is what makes the threaded flight recorder honest: the
//! master emits [`crate::trace`] fate events by re-realizing `(seed,
//! worker, iteration)` right before it consults the shim, so the journaled
//! fates cannot disagree with the plans the shim actually executes.

use std::sync::Arc;

use super::block::BlockSet;
use super::link::LinkRealization;
use super::spec::NetSpec;
use super::NetStats;

/// What the master should do with one worker's `Work` broadcast.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WorkPlan {
    /// Downlink dropped (lossy or partitioned): don't send.
    Dropped,
    /// Send; the slave adds `net_delay` to its injected sleep so arrival
    /// timing matches the virtual driver's `down + compute + up` model.
    Deliver { net_delay: f64 },
}

/// Fate of a received `Grad` reply.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GradFate {
    /// The uplink lost it: discard silently.
    Dropped,
    /// Offer it to the barrier; if `duplicate`, offer a second copy too.
    Deliver { duplicate: bool },
}

/// Master-side network shim for the threaded ("real") runtime.
pub struct NetShim {
    spec: NetSpec,
    seed: u64,
    ideal: bool,
    n_blocks: usize,
    stats: NetStats,
}

impl NetShim {
    pub fn new(spec: NetSpec, seed: u64) -> NetShim {
        let ideal = spec.is_ideal();
        NetShim { spec, seed, ideal, n_blocks: 1, stats: NetStats::default() }
    }

    /// Activate block admission, mirroring
    /// [`crate::net::VirtualTransport::set_block_count`].
    pub fn set_block_count(&mut self, n: usize) {
        self.n_blocks = n.max(1);
    }

    pub fn is_ideal(&self) -> bool {
        self.ideal
    }

    /// Does a reply with delivered set `blocks` survive to the barrier?
    /// A single-block reply keeps the legacy binary rule.
    fn admits(&self, blocks: BlockSet) -> bool {
        self.spec.admits(blocks)
    }

    /// The delivered block set of `(worker, msg_iter, duplicate)`'s reply
    /// — the same pure re-realization the virtual transport performs, so
    /// the master folds identical masks.
    pub fn blocks_for(&self, worker: usize, msg_iter: u64, duplicate: bool) -> BlockSet {
        if self.ideal || self.n_blocks <= 1 {
            return BlockSet::full(self.n_blocks);
        }
        let r = self.spec.realize(self.seed, worker, msg_iter);
        self.spec
            .realize_blocks(self.seed, worker, msg_iter, self.n_blocks, r.up_dropped, duplicate)
    }

    /// Plan worker `worker`'s iteration-`iter` broadcast, accounting both
    /// the `Work` message and the (already-determined) fate of its reply.
    /// The second return says whether the reply will reach the barrier.
    pub fn plan(&mut self, worker: usize, iter: u64) -> (WorkPlan, bool) {
        let r = if self.ideal {
            LinkRealization::ideal()
        } else {
            self.spec.realize(self.seed, worker, iter)
        };
        let delivers = if self.ideal {
            let d = self.stats.count_roundtrip(&r, true);
            if self.n_blocks > 1 {
                self.stats.count_blocks_ideal(self.n_blocks);
            }
            d
        } else if self.n_blocks <= 1 {
            self.stats.count_roundtrip(&r, true)
        } else {
            let blocks = self.spec.realize_blocks(
                self.seed,
                worker,
                iter,
                self.n_blocks,
                r.up_dropped,
                false,
            );
            self.stats
                .count_roundtrip_blocks(&r, blocks, self.admits(blocks), true)
        };
        if r.down_dropped {
            return (WorkPlan::Dropped, false);
        }
        let net_delay = if delivers { r.roundtrip_delay() } else { r.down_delay };
        (WorkPlan::Deliver { net_delay }, delivers)
    }

    /// Whether worker `worker`'s iteration-`iter` reply survives the
    /// network.  Pure re-realization — no accounting.
    pub fn reply_expected(&self, worker: usize, iter: u64) -> bool {
        if self.ideal {
            return true;
        }
        let r = self.spec.realize(self.seed, worker, iter);
        if self.n_blocks <= 1 {
            return r.delivers();
        }
        !r.down_dropped && self.admits(self.blocks_for(worker, iter, false))
    }

    /// Fate of a received `Grad` for `(worker, msg_iter)`.  Pure
    /// re-realization, so stale replies from earlier iterations resolve
    /// against their own iteration's fates.  No accounting: [`NetShim::plan`]
    /// already counted this reply.  Under block admission the reply
    /// survives on its delivered set ([`NetShim::blocks_for`]) passing the
    /// admission threshold — a reply that lost block 0 (the legacy whole
    /// message) can still deliver its surviving tail blocks.
    pub fn grad_fate(&self, worker: usize, msg_iter: u64) -> GradFate {
        if self.ideal {
            return GradFate::Deliver { duplicate: false };
        }
        let r = self.spec.realize(self.seed, worker, msg_iter);
        if self.n_blocks <= 1 {
            return if r.delivers() {
                GradFate::Deliver { duplicate: r.up_duplicated }
            } else {
                GradFate::Dropped
            };
        }
        if r.down_dropped || !self.admits(self.blocks_for(worker, msg_iter, false)) {
            GradFate::Dropped
        } else {
            GradFate::Deliver { duplicate: r.up_duplicated }
        }
    }

    pub fn stats(&self) -> NetStats {
        self.stats
    }
}

/// Per-worker θ snapshots the async master holds for retransmission.
///
/// The virtual async driver's loss recovery has the *worker* retry from
/// the θ it already holds — the master does not refresh parameters, so the
/// eventual reply's staleness counts from the original hand-off.  The
/// threaded master used to resend a fresh θ instead, silently reducing
/// staleness and diverging the drivers' async stale counts; it now holds
/// each dispatch's snapshot here and retransmits exactly that.
#[derive(Debug, Default)]
pub struct ThetaLedger {
    slots: Vec<Option<Arc<Vec<f32>>>>,
}

impl ThetaLedger {
    pub fn new(workers: usize) -> ThetaLedger {
        ThetaLedger { slots: vec![None; workers] }
    }

    /// Record the snapshot handed to worker `w` with its latest dispatch.
    pub fn hold(&mut self, w: usize, theta: &Arc<Vec<f32>>) {
        self.slots[w] = Some(Arc::clone(theta));
    }

    /// The snapshot worker `w` is currently computing on, for a
    /// retransmission that must not refresh parameters.
    pub fn held(&self, w: usize) -> Option<Arc<Vec<f32>>> {
        self.slots[w].clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_shim_always_delivers() {
        let mut shim = NetShim::new(NetSpec::ideal(), 1);
        for iter in 0..10 {
            let (plan, delivers) = shim.plan(0, iter);
            assert_eq!(plan, WorkPlan::Deliver { net_delay: 0.0 });
            assert!(delivers);
            assert_eq!(shim.grad_fate(0, iter), GradFate::Deliver { duplicate: false });
        }
        assert_eq!(shim.stats().sent, 20);
        assert_eq!(shim.stats().delivered, 20);
    }

    #[test]
    fn plan_and_fate_agree_with_realization() {
        let spec = NetSpec::lossy(0.4);
        let mut shim = NetShim::new(spec.clone(), 17);
        for iter in 0..200 {
            let r = spec.realize(17, 0, iter);
            let (plan, delivers) = shim.plan(0, iter);
            assert_eq!(delivers, r.delivers());
            assert_eq!(matches!(plan, WorkPlan::Dropped), r.down_dropped);
            assert_eq!(shim.reply_expected(0, iter), r.delivers());
            // The fate of the reply (if the slave sends one).
            match shim.grad_fate(0, iter) {
                GradFate::Dropped => assert!(!r.delivers()),
                GradFate::Deliver { duplicate } => {
                    assert!(r.delivers());
                    assert_eq!(duplicate, r.up_duplicated);
                }
            }
        }
        let s = shim.stats();
        assert_eq!(s.sent, s.delivered + s.dropped);
        assert!(s.dropped > 0);
    }

    #[test]
    fn shim_counts_match_virtual_transport() {
        use crate::net::transport::{Transport, VirtualTransport};
        let spec = NetSpec {
            default_link: crate::net::LinkModel {
                drop_prob: 0.25,
                dup_prob: 0.2,
                dup_lag: 0.001,
                ..crate::net::LinkModel::ideal()
            },
            ..NetSpec::ideal()
        };
        let seed = 23;
        let mut shim = NetShim::new(spec.clone(), seed);
        let mut virt = VirtualTransport::new(spec, seed);
        for iter in 0..100 {
            for w in 0..4 {
                shim.plan(w, iter);
                virt.send_roundtrip(w, iter, 0.01);
            }
            while virt.poll().is_some() {}
        }
        assert_eq!(shim.stats(), virt.stats());
    }

    #[test]
    fn blocked_shim_matches_virtual_transport_counts_and_masks() {
        use crate::net::transport::{Transport, VirtualTransport};
        let spec = NetSpec {
            default_link: crate::net::LinkModel {
                drop_prob: 0.3,
                dup_prob: 0.2,
                dup_lag: 0.001,
                ..crate::net::LinkModel::ideal()
            },
            block_size: 2,
            min_block_frac: 0.25,
            ..NetSpec::ideal()
        };
        let seed = 31;
        let n = spec.n_blocks(16);
        let mut shim = NetShim::new(spec.clone(), seed);
        shim.set_block_count(n);
        let mut virt = VirtualTransport::new(spec.clone(), seed);
        virt.set_block_count(n);
        for iter in 0..200 {
            for w in 0..4 {
                let (_, shim_delivers) = shim.plan(w, iter);
                virt.send_roundtrip(w, iter, 0.01);
                // The shim's pre-commitment must agree with whether the
                // reply actually surfaces (and with its own receipt-side
                // classification).
                assert_eq!(shim_delivers, shim.reply_expected(w, iter));
                assert_eq!(
                    shim_delivers,
                    !matches!(shim.grad_fate(w, iter), GradFate::Dropped)
                );
            }
            while let Some(d) = virt.poll() {
                // Shim and transport realize the same delivered sets.
                assert_eq!(d.blocks, shim.blocks_for(d.worker, d.iter, d.duplicate));
                assert!(!d.blocks.is_empty());
            }
        }
        let s = shim.stats();
        assert_eq!(s, virt.stats());
        assert_eq!(s.blocks_sent, s.blocks_delivered + s.blocks_dropped);
        assert!(s.blocks_dropped > 0);
    }

    #[test]
    fn theta_ledger_holds_latest_snapshot() {
        let mut ledger = ThetaLedger::new(2);
        assert!(ledger.held(0).is_none());
        let a = Arc::new(vec![1.0f32, 2.0]);
        ledger.hold(0, &a);
        let got = ledger.held(0).unwrap();
        assert!(Arc::ptr_eq(&got, &a));
        let b = Arc::new(vec![3.0f32]);
        ledger.hold(0, &b);
        assert!(Arc::ptr_eq(&ledger.held(0).unwrap(), &b));
        assert!(ledger.held(1).is_none());
    }
}
