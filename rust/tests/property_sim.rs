//! Property tests for the discrete-event engine (`sim::engine`).
//!
//! The engine's determinism contract: everything a run produces — the
//! event pop order, every admission decision, every recorded row, θ — is
//! a **pure function of the seed** (plus the specs), under ideal *and*
//! non-ideal networks.  The lockstep driver got this for free from its
//! per-iteration structure; the event engine must keep it now that
//! stragglers carry state (heap entries) across iteration windows.

use hybriditer::cluster::{ClusterSpec, ElasticSchedule};
use hybriditer::coordinator::{LossForm, RunConfig, RunReport, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::net::{LinkDir, LinkModel, NetSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;
use hybriditer::util::proptest::check;
use hybriditer::util::rng::Pcg64;

fn quick_problem(machines: usize, seed: u64) -> KrrProblem {
    let spec = KrrProblemSpec {
        config: "prop-sim".into(),
        d: 3,
        l: 8,
        zeta: 32,
        machines,
        noise: 0.05,
        lambda: 0.01,
        bandwidth: 1.0,
        eval_rows: 16,
        seed,
    };
    KrrProblem::generate(&spec).unwrap()
}

fn run_once(p: &KrrProblem, cluster: &ClusterSpec, cfg: &RunConfig) -> RunReport {
    let mut pool = p.native_pool();
    sim::run_virtual(&mut pool, cluster, cfg, &NoEval).unwrap()
}

/// Bitwise comparison of everything two runs record.
fn reports_identical(a: &RunReport, b: &RunReport) -> Result<(), String> {
    if a.theta != b.theta {
        return Err("theta bits diverged".into());
    }
    if a.recorder.len() != b.recorder.len() {
        return Err(format!("row counts {} vs {}", a.recorder.len(), b.recorder.len()));
    }
    for (ra, rb) in a.recorder.rows().iter().zip(b.recorder.rows()) {
        if ra.iter != rb.iter
            || ra.time.to_bits() != rb.time.to_bits()
            || ra.loss.to_bits() != rb.loss.to_bits()
            || ra.included != rb.included
            || ra.abandoned != rb.abandoned
            || ra.stale != rb.stale
            || ra.dropped != rb.dropped
            || ra.duplicated != rb.duplicated
            || ra.blocks != rb.blocks
            || ra.alive != rb.alive
        {
            return Err(format!("row for iter {} diverged", ra.iter));
        }
    }
    if a.total_contributions != b.total_contributions
        || a.total_abandoned != b.total_abandoned
        || a.crashes != b.crashes
        || a.rejoins != b.rejoins
        || a.rebalances != b.rebalances
        || a.net != b.net
        || a.stale_blocks != b.stale_blocks
    {
        return Err("run totals diverged".into());
    }
    Ok(())
}

fn draw_cfg(rng: &mut Pcg64, m: usize) -> RunConfig {
    let gamma = 1 + rng.below(m as u64) as usize;
    RunConfig {
        mode: SyncMode::Hybrid { gamma },
        optimizer: OptimizerKind::sgd(0.5),
        loss_form: LossForm::krr(0.01),
        eval_every: 0,
        record_every: 1,
        ..RunConfig::default()
    }
    .with_iters(40 + rng.below(40))
}

#[test]
fn prop_ideal_net_run_is_pure_function_of_seed() {
    check("ideal_event_order_seed_pure", 12, |rng| {
        let m = 3 + rng.below(6) as usize;
        let p = quick_problem(m, rng.next_u64());
        let mut cluster = ClusterSpec {
            workers: m,
            delay: DelayModel::LogNormal { mu: -5.0, sigma: 1.0 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        };
        if rng.next_f64() < 0.5 && m >= 3 {
            // Elastic churn must not break purity either.
            cluster = cluster
                .with_elastic(ElasticSchedule::crash_and_rejoin(&[m - 1], 5, 15), 1);
        }
        let cfg = draw_cfg(rng, m);
        let a = run_once(&p, &cluster, &cfg);
        let b = run_once(&p, &cluster, &cfg);
        reports_identical(&a, &b)?;

        // A different cluster seed must actually change the trajectory
        // (otherwise "pure function of the seed" is vacuous).
        let mut other = cluster.clone();
        other.seed = cluster.seed.wrapping_add(1);
        let c = run_once(&p, &other, &cfg);
        if reports_identical(&a, &c).is_ok() && a.total_abandoned > 0 {
            return Err("different seed reproduced the identical run".into());
        }
        Ok(())
    });
}

#[test]
fn prop_carry_mode_run_is_pure_function_of_seed() {
    // The cross-iteration reordering path: a lossy spec with an asymmetric
    // slow uplink keeps events alive across windows — determinism must
    // survive the carry/rebase machinery.
    check("carry_event_order_seed_pure", 10, |rng| {
        let m = 3 + rng.below(5) as usize;
        let p = quick_problem(m, rng.next_u64());
        let slow_up = LinkModel {
            drop_prob: rng.uniform(0.0, 0.3),
            up: Some(LinkDir {
                latency: DelayModel::Constant { secs: rng.uniform(0.01, 0.08) },
                drop_prob: rng.uniform(0.0, 0.3),
            }),
            ..LinkModel::ideal()
        };
        let net = NetSpec {
            default_link: LinkModel::lossy(rng.uniform(0.0, 0.2)),
            // Half the cases chunk replies into blocks (dim 8 → 3 blocks):
            // determinism must survive the partial-admission machinery too.
            block_size: if rng.next_f64() < 0.5 { 3 } else { 0 },
            ..NetSpec::ideal()
        }
        .with_override(m - 1, slow_up);
        let cluster = ClusterSpec {
            workers: m,
            base_compute: 0.005,
            delay: DelayModel::Uniform { lo: 0.0, hi: 0.002 },
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        }
        .with_net(net);
        let cfg = draw_cfg(rng, m);
        let a = run_once(&p, &cluster, &cfg);
        let b = run_once(&p, &cluster, &cfg);
        reports_identical(&a, &b)
    });
}

#[test]
fn prop_block_conservation_across_drivers() {
    // Block conservation under lossy sweeps, in *both* drivers: every
    // block the network dispatched is either delivered or dropped
    // (`blocks_sent == blocks_delivered + blocks_dropped`), stale-admitted
    // blocks never exceed what was dispatched, and the per-row delivered
    // counts never overrun the run total (rows can undercount only by the
    // tail the final partial window discards).
    use hybriditer::coordinator::Coordinator;
    use hybriditer::worker::NativeKrrFactory;
    check("block_conservation", 6, |rng| {
        let m = 4 + rng.below(3) as usize;
        let p = quick_problem(m, rng.next_u64());
        let net = NetSpec {
            default_link: LinkModel {
                drop_prob: rng.uniform(0.05, 0.4),
                dup_prob: rng.uniform(0.0, 0.4),
                dup_lag: 0.0005,
                ..LinkModel::ideal()
            },
            // dim 8 → 2–8 blocks per reply.
            block_size: 1 + rng.below(4) as usize,
            min_block_frac: if rng.next_f64() < 0.5 { 0.0 } else { 0.5 },
            ..NetSpec::ideal()
        };
        let cluster = ClusterSpec {
            workers: m,
            base_compute: 0.002,
            slow_nodes: (1..m).map(|w| (w, 1.0 + w as f64)).collect(),
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        }
        .with_net(net);
        let gamma = 1 + rng.below(m as u64) as usize;
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma },
            optimizer: OptimizerKind::sgd(0.5),
            loss_form: LossForm::krr(0.01),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(25);
        let virt = run_once(&p, &cluster, &cfg);
        let coord = Coordinator::new(cluster.clone(), cfg.clone())
            .map_err(|e| e.to_string())?;
        let factory = NativeKrrFactory::for_problem(&p);
        let real = coord.run_real(&factory, &NoEval).map_err(|e| e.to_string())?;
        for (name, rep) in [("virtual", &virt), ("real", &real)] {
            let n = &rep.net;
            if n.blocks_sent == 0 {
                return Err(format!("{name}: blocking never engaged ({n:?})"));
            }
            if n.blocks_sent != n.blocks_delivered + n.blocks_dropped {
                return Err(format!("{name}: block conservation broken ({n:?})"));
            }
            if rep.stale_blocks > n.blocks_sent {
                return Err(format!(
                    "{name}: stale-admitted {} blocks out of {} dispatched",
                    rep.stale_blocks, n.blocks_sent
                ));
            }
            let row_blocks: u64 =
                rep.recorder.rows().iter().map(|r| r.blocks as u64).sum();
            if row_blocks > n.blocks_delivered {
                return Err(format!(
                    "{name}: rows claim {row_blocks} delivered blocks, run total {}",
                    n.blocks_delivered
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stale_admissions_conserve_accounting() {
    // Every reply is exactly one of: admitted, abandoned/stale-accounted,
    // network-dropped, or still in flight at the end (discarded, like the
    // threaded master's shutdown).  With record_every = 1 the rows see
    // every completed window, so the run-level totals must reconcile.
    check("stale_conservation", 10, |rng| {
        let m = 4 + rng.below(4) as usize;
        let p = quick_problem(m, rng.next_u64());
        let slow_up = LinkModel {
            up: Some(LinkDir {
                latency: DelayModel::Constant { secs: rng.uniform(0.02, 0.06) },
                drop_prob: 0.0,
            }),
            ..LinkModel::ideal()
        };
        let cluster = ClusterSpec {
            workers: m,
            base_compute: 0.005,
            seed: rng.next_u64(),
            ..ClusterSpec::default()
        }
        .with_net(NetSpec::ideal().with_override(m - 1, slow_up));
        let gamma = 1 + rng.below((m - 1) as u64) as usize;
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma },
            optimizer: OptimizerKind::sgd(0.5),
            loss_form: LossForm::krr(0.01),
            eval_every: 0,
            record_every: 1,
            ..RunConfig::default()
        }
        .with_iters(60);
        let rep = run_once(&p, &cluster, &cfg);
        let row_abandoned: usize = rep.recorder.rows().iter().map(|r| r.abandoned).sum();
        let row_stale: usize = rep.recorder.rows().iter().map(|r| r.stale).sum();
        if rep.total_abandoned != (row_abandoned + row_stale) as u64 {
            return Err(format!(
                "totals {} != rows abandoned {row_abandoned} + stale {row_stale}",
                rep.total_abandoned
            ));
        }
        // γ < m with a chronically slow uplink: the slow worker's replies
        // must actually go stale (the reordering feature under test).
        if gamma < m && row_stale == 0 && rep.net.dropped == 0 {
            return Err("slow uplink produced no stale admissions".into());
        }
        Ok(())
    });
}
