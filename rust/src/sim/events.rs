//! Event taxonomy of the discrete-event engine.
//!
//! One event type covers both policies: a [`Event`] is a `Grad` reply (or
//! the duplicated copy of one, or — async only — the detection point of a
//! lost roundtrip) reaching the coordinator at a virtual time.  Scheduled
//! elastic membership changes and shard rebalances are *boundary* events:
//! they are keyed by iteration (sync) or update count (async), not by
//! virtual time, and are handled by
//! [`crate::sim::engine::EngineCore::boundary`] rather than the heap.
//!
//! Ordering is total and deterministic: `(at, worker, duplicate, iter)`
//! ascending.  The first three components reproduce the transport's
//! delivery order exactly (a primary precedes its own duplicate, equal
//! times order by worker index), so under an ideal [`crate::net::NetSpec`]
//! the engine pops events in the same sequence the pre-refactor lockstep
//! driver polled them — the bit-for-bit guarantee.  The trailing `iter`
//! component only matters when a carried-over straggler from an earlier
//! iteration collides exactly with a fresh reply, which requires a
//! non-ideal spec.

use std::cmp::Ordering;

/// One reply event on the engine's virtual-time heap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Event {
    /// Virtual arrival time.  The sync policy keys events *relative to the
    /// current iteration window* (carried stragglers are rebased at each
    /// boundary — see [`crate::sim::engine::EventHeap::rebase`]); the
    /// async policy uses absolute virtual time (it has no windows).
    pub at: f64,
    /// The replying worker.
    pub worker: usize,
    /// What the reply answers: the iteration whose `Work` produced it
    /// (sync), or the dispatch's version tag (async) — the engine's
    /// duplicate/stale detection compares this against the worker's
    /// outstanding tag.
    pub iter: u64,
    /// True for the extra copy of a duplicated reply.
    pub duplicate: bool,
    /// False when the network lost the roundtrip.  The async policy models
    /// the master's loss-detection point as an event (the worker retries
    /// from the θ it holds); the sync policy never schedules lost replies.
    pub delivers: bool,
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Latencies are finite (the spec validates its distributions), so
        // the partial_cmp fallback to Equal is never load-bearing.
        self.at
            .partial_cmp(&other.at)
            .unwrap_or(Ordering::Equal)
            .then(self.worker.cmp(&other.worker))
            .then(self.duplicate.cmp(&other.duplicate))
            .then(self.iter.cmp(&other.iter))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(at: f64, worker: usize, iter: u64, duplicate: bool) -> Event {
        Event { at, worker, iter, duplicate, delivers: true }
    }

    #[test]
    fn orders_by_time_then_worker_then_duplicate() {
        let mut evs = vec![
            ev(0.02, 0, 5, false),
            ev(0.01, 1, 5, false),
            ev(0.01, 0, 5, true),
            ev(0.01, 0, 5, false),
        ];
        evs.sort();
        assert_eq!(evs[0], ev(0.01, 0, 5, false));
        assert_eq!(evs[1], ev(0.01, 0, 5, true));
        assert_eq!(evs[2], ev(0.01, 1, 5, false));
        assert_eq!(evs[3], ev(0.02, 0, 5, false));
    }

    #[test]
    fn carried_straggler_ties_break_oldest_first() {
        // A carried reply from iteration 3 colliding exactly with a fresh
        // reply from iteration 4 pops oldest-first — deterministic, so the
        // same seed always yields the same admission sequence.
        let mut evs = vec![ev(0.01, 2, 4, false), ev(0.01, 2, 3, false)];
        evs.sort();
        assert_eq!(evs[0].iter, 3);
        assert_eq!(evs[1].iter, 4);
    }
}
