"""L1 Pallas kernel: random-Fourier-feature map (the paper's ``K[x]``).

The paper treats ``K`` as an abstract kernel feature map.  We instantiate it
with random Fourier features for the RBF kernel (Rahimi & Recht 2007):

    phi(x) = cos(x @ W + b) * sqrt(2/l)

with ``W ~ N(0, 1/sigma^2)`` and ``b ~ U[0, 2pi)`` drawn once and shared by
all machines, so ``E[phi(x)^T phi(x')] = exp(-||x-x'||^2 / 2 sigma^2)``.

Tiling: rows (examples) stream through VMEM ``BLOCK_M`` at a time; ``W``
(d x l) and ``b`` stay resident.  The matmul hits the MXU, the ``cos`` and
scale fuse into the same block visit (single HBM round-trip per row tile).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 256


def _rbf_kernel(x_ref, w_ref, b_ref, o_ref, *, scale: float):
    z = (
        jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...]
    )
    o_ref[...] = jnp.cos(z) * scale


def rbf_features(x, w, b, *, block_m: int = DEFAULT_BLOCK_M):
    """Pallas random-Fourier feature map.

    Args:
      x: (m, d) float32 inputs.
      w: (d, l) float32 projection (shared across the cluster).
      b: (l,) float32 phases.
      block_m: rows per grid step; auto-shrunk to divide m.

    Returns:
      (m, l) float32 features phi with E[phi phi^T] = RBF kernel.
    """
    m, d = x.shape
    l = w.shape[1]
    if m % block_m != 0:
        bm = min(block_m, m)
        while m % bm != 0:
            bm -= 1
        block_m = bm
    grid = (m // block_m,)
    import math

    scale = math.sqrt(2.0 / l)

    import functools

    kernel = functools.partial(_rbf_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d, l), lambda i: (0, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, l), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, l), jnp.float32),
        interpret=True,
    )(x, w, b.reshape(1, l))
