//! # hybriditer
//!
//! Reproduction of *"A Hybrid Solution to improve Iteration Efficiency in
//! the Distributed Learning"* (Wang, Wang & Zhao, 2014) as a three-layer
//! rust + JAX + Pallas system.
//!
//! The paper's idea: in master/slave iterative learning, the master waits
//! only for the **first `γ` of `M`** slave gradients each iteration and
//! abandons the stragglers' results, with `γ` chosen by sampling statistics
//! (Algorithm 1) so the partial gradient stays within relative error `ξ`
//! of the full gradient with confidence `1 − α`.
//!
//! Layer map (see `DESIGN.md`):
//! * **L3 (this crate)** — the coordination contribution: partial
//!   synchronization barrier, BSP/ASYNC/HYBRID modes, straggler & fault
//!   injection, the Algorithm-1 estimator, optimizers, metrics.
//! * **L2 (python/compile)** — jax programs (KRR gradient/loss, decoder-only
//!   LM step) AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels)** — pallas kernels called by L2.
//!
//! Python never runs on the training path: [`runtime`] loads the HLO
//! artifacts through PJRT and every gradient is computed by an AOT
//! executable (or by the pure-rust mirror in [`data::native`], used for
//! tests and XLA-free benches).
//!
//! ## Quick start
//!
//! ```no_run
//! use hybriditer::prelude::*;
//!
//! let spec = KrrProblemSpec::default_config().with_machines(8);
//! let problem = KrrProblem::generate(&spec).unwrap();
//! let cluster = ClusterSpec { workers: 8, ..ClusterSpec::default() };
//! let mut cfg = RunConfig::default();
//! cfg.mode = SyncMode::Hybrid { gamma: 6 };
//! let mut pool = problem.native_pool();
//! let report = sim::run_virtual(&mut pool, &cluster, &cfg, &problem).unwrap();
//! println!("final loss = {}", report.final_loss());
//! ```

pub mod agg;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod lm;
pub mod math;
pub mod metrics;
pub mod net;
pub mod optim;
pub mod recovery;
pub mod runner;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod straggler;
pub mod trace;
pub mod util;
pub mod worker;

/// Library-wide error type (hand-rolled; `thiserror` is not in the offline
/// vendor set).
#[derive(Debug)]
pub enum Error {
    Xla(xla::Error),
    Io(std::io::Error),
    Config(String),
    Manifest(String),
    Cluster(String),
    Shape(String),
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::Cluster(msg) => write!(f, "cluster error: {msg}"),
            Error::Shape(msg) => write!(f, "shape mismatch: {msg}"),
            Error::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Error {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::Io(e)
    }
}

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Convenience re-exports for examples and benches.
pub mod prelude {
    pub use crate::agg::{AggSpec, TopologyKind};
    pub use crate::cluster::{ClusterSpec, TimingMode};
    pub use crate::coordinator::estimator::{estimate_gamma, EstimatorParams};
    pub use crate::coordinator::modes::SyncMode;
    pub use crate::coordinator::{Coordinator, RunConfig, RunReport};
    pub use crate::data::{KrrProblem, KrrProblemSpec};
    pub use crate::metrics::Recorder;
    pub use crate::net::{LinkModel, NetSpec, NetStats};
    pub use crate::optim::OptimizerKind;
    pub use crate::runner::{Driver, Runner};
    pub use crate::runtime::{ArtifactSet, Engine};
    pub use crate::serve::{AdmissionPolicy, ServeSpec, ServeStats};
    pub use crate::sim;
    pub use crate::straggler::{DelayModel, FailureModel, StragglerProfile};
    pub use crate::util::rng::Pcg64;
    pub use crate::Error;
    pub use crate::Result;
}
