//! `hybriditer` CLI — leader entrypoint.
//!
//! Subcommands:
//!   train <config.toml>   run an experiment from a TOML config
//!   estimate              Algorithm-1 γ estimation for given (N, ζ, M, α, ξ)
//!   inspect               list AOT artifacts and their shapes
//!
//! Examples live in `examples/` (cargo run --example ...).

use hybriditer::cli::ArgSpec;
use hybriditer::cluster::TimingMode;
use hybriditer::config::schema::{Backend, ExperimentConfig, ProblemKind};
use hybriditer::coordinator::estimator::{estimate_gamma, estimate_sample_size, EstimatorParams};
use hybriditer::data::KrrProblem;
use hybriditer::metrics::csv;
use hybriditer::prelude::*;
use hybriditer::runtime::{ArtifactSet, Engine};
use hybriditer::util::logger;
use hybriditer::worker::{NativeKrrFactory, XlaKrrFactory};

fn main() {
    logger::init();
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let sub = if args.is_empty() {
        usage_and_exit()
    } else {
        args.remove(0)
    };
    let code = match sub.as_str() {
        "train" => cmd_train(&args),
        "estimate" => cmd_estimate(&args),
        "inspect" => cmd_inspect(&args),
        "--help" | "-h" | "help" => {
            usage_and_exit();
        }
        other => {
            eprintln!("unknown subcommand '{other}'\n");
            usage_and_exit();
        }
    };
    std::process::exit(code);
}

fn usage_and_exit() -> ! {
    eprintln!(
        "hybriditer — hybrid partial-synchronization distributed learning\n\n\
         USAGE:\n  hybriditer train <config.toml> [--csv out.csv]\n  \
         hybriditer estimate [--n N] [--zeta Z] [--machines M] [--alpha A] [--xi X]\n  \
         hybriditer inspect [--artifacts DIR]\n"
    );
    std::process::exit(2);
}

fn cmd_train(argv: &[String]) -> i32 {
    let spec = ArgSpec::new("hybriditer train", "run an experiment from a TOML config")
        .positional("config", "experiment TOML file")
        .opt("csv", "", "write the loss curve CSV here (overrides config)")
        .opt(
            "join-schedule",
            "",
            "elastic membership trace, e.g. 2:leave@30,2:join@50 (overrides config)",
        )
        .opt(
            "rebalance-every",
            "",
            "rebalance shards every k iterations, 0 disables (overrides config)",
        )
        .opt(
            "warmup-iters",
            "",
            "rejoin warm-up ramp length in iterations, 0 = instant (overrides config)",
        )
        .opt(
            "capacities",
            "",
            "per-worker relative capacities, e.g. 8:0.25,9:0.5 (overrides config)",
        )
        .opt(
            "drop-prob",
            "",
            "per-message network loss probability on every link (overrides config)",
        )
        .opt(
            "net-partitions",
            "",
            "scripted partitions, e.g. 3-5@40..60;0@10..20 (overrides config)",
        )
        .opt(
            "up-drop-prob",
            "",
            "uplink (Grad) loss probability on every link (overrides config)",
        )
        .opt(
            "down-drop-prob",
            "",
            "downlink (Work) loss probability on every link (overrides config)",
        )
        .opt(
            "block-size",
            "",
            "gradient block size in f32s, 0 = whole-reply fate (overrides config)",
        )
        .opt(
            "min-block-frac",
            "",
            "admission threshold: drop replies below this block fraction (overrides config)",
        )
        .opt(
            "agg-topology",
            "",
            "aggregation topology: star | tree | ring (overrides config)",
        )
        .opt(
            "agg-fan-in",
            "",
            "children per interior tree node (overrides config)",
        )
        .opt(
            "agg-fold-cost",
            "",
            "seconds to fold one full gradient vector at an interior node (overrides config)",
        )
        .opt(
            "agg-xfer-cost",
            "",
            "fixed per-hop forwarding latency in seconds (overrides config)",
        )
        .opt(
            "threads",
            "",
            "sweep/worker pool size (default: [bench] threads, else available parallelism)",
        )
        .opt(
            "recovery-policy",
            "",
            "crash recovery policy: abandon | rebalance | partial-recovery | \
             checkpoint-restore (overrides config)",
        )
        .opt(
            "checkpoint-every",
            "",
            "checkpoint-restore snapshot cadence in iterations (overrides config)",
        )
        .opt(
            "trace-out",
            "",
            "write the flight-recorder journal (JSONL) here (overrides config)",
        )
        .opt(
            "trace-chrome",
            "",
            "write the Chrome trace-event export here (overrides config)",
        )
        .opt(
            "arrival-rate",
            "",
            "serving offered load in requests/s; creates a [serve] section if absent",
        )
        .opt(
            "slo-p99-ms",
            "",
            "serving read p99 SLO in milliseconds (overrides config)",
        )
        .opt(
            "admission",
            "",
            "serving admission policy: open | shed | queue (overrides config)",
        );
    let parsed = match spec.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match run_train(&parsed) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("train failed: {e}");
            1
        }
    }
}

fn run_train(parsed: &hybriditer::cli::Parsed) -> hybriditer::Result<()> {
    let config_path = parsed.positional(0);
    let csv_override = parsed.get("csv");
    let join_schedule = parsed.get("join-schedule");
    let rebalance_every = parsed.get("rebalance-every");
    let net_partitions = parsed.get("net-partitions");
    let mut cfg = ExperimentConfig::load(std::path::Path::new(config_path))?;
    if !join_schedule.is_empty() {
        let sched = hybriditer::cluster::ElasticSchedule::parse(join_schedule)?;
        sched.validate(cfg.cluster.workers)?;
        cfg.cluster.elastic = sched;
    }
    if !rebalance_every.is_empty() {
        cfg.cluster.rebalance_every = rebalance_every.parse().map_err(|_| {
            hybriditer::Error::Config(format!(
                "--rebalance-every: expected integer, got '{rebalance_every}'"
            ))
        })?;
    }
    if let Some(k) = parsed.get_opt_usize("warmup-iters")? {
        cfg.cluster.warmup_iters = k as u64;
    }
    let capacities = parsed.get("capacities");
    if !capacities.is_empty() {
        // Worker-range/positivity checks happen in validate_elastic on
        // every run path, so the override only needs to parse here.
        cfg.cluster.capacities =
            hybriditer::cluster::ClusterSpec::parse_capacities(capacities)?;
    }
    if let Some(p) = parsed.get_opt_f64("drop-prob")? {
        // "Every link" includes per-worker overrides (e.g. a slow_link
        // clone of the config-time default), not just the default model.
        cfg.cluster.net.default_link.drop_prob = p;
        for (_, link) in &mut cfg.cluster.net.overrides {
            link.drop_prob = p;
        }
    }
    if !net_partitions.is_empty() {
        cfg.cluster.net.partitions =
            hybriditer::net::NetSpec::parse_partitions(net_partitions)?;
    }
    // Per-direction overrides: force one direction's loss rate on every
    // link, keeping that direction's configured latency.
    let mut set_dir = |up: bool, p: f64| {
        let mut apply = |link: &mut hybriditer::net::LinkModel| {
            let (lat, _) = if up { link.up_dir() } else { link.down_dir() };
            let dir = hybriditer::net::LinkDir { latency: lat.clone(), drop_prob: p };
            if up {
                link.up = Some(dir);
            } else {
                link.down = Some(dir);
            }
        };
        apply(&mut cfg.cluster.net.default_link);
        for (_, link) in &mut cfg.cluster.net.overrides {
            apply(link);
        }
    };
    if let Some(p) = parsed.get_opt_f64("up-drop-prob")? {
        set_dir(true, p);
    }
    if let Some(p) = parsed.get_opt_f64("down-drop-prob")? {
        set_dir(false, p);
    }
    if let Some(b) = parsed.get_opt_usize("block-size")? {
        cfg.cluster.net.block_size = b;
    }
    if let Some(f) = parsed.get_opt_f64("min-block-frac")? {
        cfg.cluster.net.min_block_frac = f;
    }
    cfg.cluster.net.validate(cfg.cluster.workers)?;
    let agg_topology = parsed.get("agg-topology");
    if !agg_topology.is_empty() {
        cfg.cluster.agg.topology = hybriditer::agg::TopologyKind::parse(agg_topology)?;
    }
    if let Some(f) = parsed.get_opt_usize("agg-fan-in")? {
        cfg.cluster.agg.fan_in = f;
    }
    if let Some(c) = parsed.get_opt_f64("agg-fold-cost")? {
        cfg.cluster.agg.fold_cost = c;
    }
    if let Some(c) = parsed.get_opt_f64("agg-xfer-cost")? {
        cfg.cluster.agg.xfer_cost = c;
    }
    cfg.cluster.agg.validate(cfg.cluster.workers, cfg.cluster.net.block_size)?;
    let recovery_policy = parsed.get("recovery-policy");
    if !recovery_policy.is_empty() {
        cfg.run.recovery.policy =
            hybriditer::recovery::RecoveryPolicy::parse(recovery_policy)?;
    }
    if let Some(k) = parsed.get_opt_usize("checkpoint-every")? {
        cfg.run.recovery.checkpoint_every = k as u64;
    }
    cfg.run.recovery.validate()?;
    // Serving overrides: any --serve flag creates the [serve] section
    // when the config omits it, so serving can be switched on from the
    // CLI alone.  Serving only takes effect through Runner below.
    let mut serve = cfg.serve.clone();
    if let Some(r) = parsed.get_opt_f64("arrival-rate")? {
        serve.get_or_insert_with(ServeSpec::default).arrival_rate = r;
    }
    if let Some(s) = parsed.get_opt_f64("slo-p99-ms")? {
        serve.get_or_insert_with(ServeSpec::default).read_slo_ms = s;
    }
    let admission = parsed.get("admission");
    if !admission.is_empty() {
        serve.get_or_insert_with(ServeSpec::default).admission =
            AdmissionPolicy::parse(admission)?;
    }
    if let Some(sv) = &serve {
        sv.validate()?;
    }
    // Pool-size resolution: --threads beats [bench] threads beats auto.
    let threads = match parsed.get_opt_usize("threads")? {
        Some(n) => n,
        None => cfg.bench_threads,
    };
    if threads > 0 {
        hybriditer::util::pool::set_default_threads(threads);
        log::info!("worker/sweep pool size: {threads}");
    }
    log::info!(
        "experiment: {:?} mode={} workers={} timing={:?} backend={:?}",
        cfg.problem_kind,
        cfg.run.mode.name(),
        cfg.cluster.workers,
        cfg.timing,
        cfg.backend
    );

    // Flight recorder: either --trace-* flag beats the [trace] section;
    // any configured export attaches a JournalSink to the run.
    let trace_out = if !parsed.get("trace-out").is_empty() {
        Some(parsed.get("trace-out").to_string())
    } else {
        cfg.trace_out.clone()
    };
    let trace_chrome = if !parsed.get("trace-chrome").is_empty() {
        Some(parsed.get("trace-chrome").to_string())
    } else {
        cfg.trace_chrome.clone()
    };
    let mut journal = hybriditer::trace::JournalSink::new();
    let mut noop = hybriditer::trace::NoopSink;
    let tracing = trace_out.is_some() || trace_chrome.is_some();
    let sink: &mut dyn hybriditer::trace::TraceSink =
        if tracing { &mut journal } else { &mut noop };

    // Every path below funnels through the unified Runner; a serve spec
    // (config or CLI) rides along regardless of driver or backend.
    fn with_serve<'a>(r: Runner<'a>, serve: &Option<ServeSpec>) -> Runner<'a> {
        match serve {
            Some(sv) => r.serve(sv.clone()),
            None => r,
        }
    }

    let report = match (&cfg.problem_kind, cfg.timing) {
        (ProblemKind::Krr, TimingMode::Virtual) => {
            let problem = KrrProblem::generate(&cfg.krr)?;
            match cfg.backend {
                Backend::Native => {
                    let mut pool = problem.native_pool();
                    let r = Runner::new(&cfg.cluster, &cfg.run)
                        .driver(Driver::Virtual)
                        .pool(&mut pool)
                        .hooks(&problem)
                        .trace(sink);
                    with_serve(r, &serve).run()?
                }
                Backend::Xla => {
                    let artifacts = ArtifactSet::discover()?;
                    let engine = Engine::cpu()?;
                    let mut pool = hybriditer::worker::compute::XlaKrrPool::new(
                        &artifacts,
                        &engine,
                        &problem.spec.config,
                        &problem.shards,
                        problem.spec.lambda as f32,
                    )?;
                    let r = Runner::new(&cfg.cluster, &cfg.run)
                        .driver(Driver::Virtual)
                        .pool(&mut pool)
                        .hooks(&problem)
                        .trace(sink);
                    with_serve(r, &serve).run()?
                }
            }
        }
        (ProblemKind::Krr, TimingMode::Real) => {
            let problem = KrrProblem::generate(&cfg.krr)?;
            match cfg.backend {
                Backend::Native => {
                    let factory = NativeKrrFactory::for_problem(&problem);
                    let r = Runner::new(&cfg.cluster, &cfg.run)
                        .driver(Driver::Threaded)
                        .factory(&factory)
                        .hooks(&problem)
                        .trace(sink);
                    with_serve(r, &serve).run()?
                }
                Backend::Xla => {
                    let artifacts = ArtifactSet::discover()?;
                    let factory = XlaKrrFactory::new(
                        &artifacts,
                        &problem.spec.config,
                        problem.shards.clone(),
                        problem.spec.lambda as f32,
                    )?;
                    let r = Runner::new(&cfg.cluster, &cfg.run)
                        .driver(Driver::Threaded)
                        .factory(&factory)
                        .hooks(&problem)
                        .trace(sink);
                    with_serve(r, &serve).run()?
                }
            }
        }
        (ProblemKind::Lm { config }, _) => {
            // LM training always runs the virtual driver (one engine).
            let artifacts = ArtifactSet::discover()?;
            let engine = Engine::cpu()?;
            let mut pool = hybriditer::lm::LmPool::new(
                &artifacts,
                &engine,
                config,
                cfg.cluster.workers,
                4,
                cfg.krr.seed,
            )?;
            let mut run = cfg.run.clone();
            run.init_theta = Some(hybriditer::lm::init::init_params(pool.task(), cfg.krr.seed));
            let r = Runner::new(&cfg.cluster, &run)
                .driver(Driver::Virtual)
                .pool(&mut pool)
                .trace(sink);
            with_serve(r, &serve).run()?
        }
    };

    println!("{}", report.summary());
    if let Some(ts) = &report.trace {
        print!("{}", ts.render());
    }
    if let Some(path) = &trace_out {
        journal.write_jsonl(std::path::Path::new(path))?;
        log::info!("trace journal -> {path}");
    }
    if let Some(path) = &trace_chrome {
        journal.write_chrome(std::path::Path::new(path))?;
        log::info!("chrome trace -> {path}");
    }
    let out = if !csv_override.is_empty() {
        Some(csv_override.to_string())
    } else {
        cfg.out_csv.clone()
    };
    if let Some(path) = out {
        csv::write_recorder(&report.recorder, std::path::Path::new(&path))?;
        log::info!("loss curve -> {path}");
    }
    Ok(())
}

fn cmd_estimate(argv: &[String]) -> i32 {
    let spec = ArgSpec::new("hybriditer estimate", "Algorithm-1 sample/machine estimation")
        .opt("n", "32768", "total examples N")
        .opt("zeta", "2048", "examples per machine ζ")
        .opt("machines", "16", "machines M")
        .opt("alpha", "0.05", "significance α (confidence 1-α)")
        .opt("xi", "0.05", "relative error ξ");
    let p = match spec.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let go = || -> hybriditer::Result<()> {
        let n = p.get_usize("n")?;
        let zeta = p.get_usize("zeta")?;
        let m = p.get_usize("machines")?;
        let params = EstimatorParams {
            alpha: p.get_f64("alpha")?,
            xi: p.get_f64("xi")?,
        };
        let sample = estimate_sample_size(n, params)?;
        let gamma = estimate_gamma(n, zeta, m, params)?;
        println!("u_(alpha/2)      = {:.6}", params.u_half_alpha());
        println!("sample size n    = {sample:.1} examples");
        println!("machines gamma   = {gamma} of {m}  (zeta = {zeta})");
        println!("abandon rate     = {:.1}%", 100.0 * (1.0 - gamma as f64 / m as f64));
        Ok(())
    };
    match go() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("estimate failed: {e}");
            1
        }
    }
}

fn cmd_inspect(argv: &[String]) -> i32 {
    let spec = ArgSpec::new("hybriditer inspect", "list AOT artifacts")
        .opt("artifacts", "", "artifact directory (default: discover)");
    let p = match spec.parse(argv) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let go = || -> hybriditer::Result<()> {
        let set = if p.get("artifacts").is_empty() {
            ArtifactSet::discover()?
        } else {
            ArtifactSet::open(p.get("artifacts"))?
        };
        println!(
            "artifacts at {} (jax {}):",
            set.dir().display(),
            set.manifest().jax_version
        );
        for (name, info) in set.manifest().iter() {
            let ins: Vec<String> = info
                .inputs
                .iter()
                .map(|t| format!("{}{:?}", t.name, t.shape))
                .collect();
            println!(
                "  {name:42} {:2} in / {:2} out   [{}]",
                info.inputs.len(),
                info.outputs.len(),
                ins.join(", ")
            );
        }
        Ok(())
    };
    match go() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("inspect failed: {e}");
            1
        }
    }
}
