//! Typed experiment schema: TOML [`Value`] → problem/cluster/run configs.
//!
//! An experiment file looks like:
//!
//! ```toml
//! [problem]
//! kind = "krr"          # or "lm"
//! config = "default"    # AOT artifact config name
//! machines = 16
//! noise = 0.1
//! lambda = 0.01
//! seed = 42
//!
//! [mode]
//! kind = "hybrid"       # bsp | hybrid | hybrid-auto | hybrid-adaptive | async
//! gamma = 12
//! alpha = 0.05          # hybrid-auto / hybrid-adaptive
//! xi = 0.05
//!
//! [straggler]
//! delay = "lognormal"   # none|constant|uniform|lognormal|pareto|bimodal|exponential
//! mu = -4.0
//! sigma = 1.0
//! base_compute = 0.01
//! slow_nodes = 2
//! slow_factor = 8.0
//! capacities = "8:0.25,9:0.5"   # per-worker relative hardware capacity
//! crash_prob = 0.0
//! transient_prob = 0.0
//! rejoin_after = 0      # 0 = never
//!
//! [elastic]
//! schedule = "2:leave@30,2:join@50"   # scripted membership trace
//! rebalance_every = 1                 # 0 disables shard rebalancing
//! warmup_iters = 8                    # rejoin warm-up ramp (0 = instant)
//! weighted_rebalance = true           # capacity-weighted apportionment
//!
//! [net]
//! drop_prob = 0.05      # per-message loss on every link (both directions)
//! dup_prob = 0.0        # per-reply duplication probability
//! dup_lag = 0.001       # duplicate copy lag, seconds
//! delay = "none"        # link latency: same kinds as straggler.delay
//! up_drop_prob = 0.2    # uplink (Grad) loss override, per direction
//! down_drop_prob = 0.0  # downlink (Work) loss override
//! up_delay_secs = 0.03  # constant uplink one-way latency override
//! down_delay_secs = 0.0 # constant downlink one-way latency override
//! partitions = "3-5@40..60"           # scripted partition windows
//! slow_link = 3         # one worker behind a chronically slow link...
//! slow_link_secs = 0.05 # ...with this constant one-way latency
//! salt = 0              # extra seed salt for the per-message streams
//! block_size = 0        # gradient block size in f32s (0 = whole-reply)
//! min_block_frac = 0.0  # drop replies delivering below this block fraction
//!
//! [agg]
//! topology = "star"     # star | tree | ring (aggregation overlay)
//! fan_in = 8            # children per interior tree node
//! fold_cost = 0.0       # seconds to fold one full gradient vector
//! xfer_cost = 0.0       # fixed per-hop forwarding latency, seconds
//!
//! [optimizer]
//! kind = "sgd"          # sgd | momentum | nesterov | adam | lbfgs | cg
//! eta = 0.5
//! decay = 0.0
//!
//! [bench]
//! threads = 0           # sweep worker pool size (0 = available parallelism)
//!
//! [recovery]
//! policy = "abandon"    # abandon | rebalance | partial-recovery | checkpoint-restore
//! checkpoint_every = 25 # snapshot cadence (checkpoint-restore only)
//!
//! [run]
//! iters = 500
//! eval_every = 10
//! record_every = 1
//! timing = "virtual"    # virtual | real
//! backend = "xla"       # xla | native
//! seed = 1
//!
//! [trace]
//! out = "run.trace.jsonl"          # flight-recorder journal (JSONL)
//! chrome = "run.trace.chrome.json" # Chrome trace-event export (Perfetto)
//!
//! [serve]
//! arrival_rate = 800.0  # offered requests per serve-clock second
//! window_ms = 10.0      # serve-clock ms per completed iteration
//! read_slo_ms = 50.0    # p99 SLO for theta reads
//! update_slo_ms = 500.0 # p99 SLO for update requests
//! admission = "shed"    # open | shed | queue
//! queue_slack = 8.0     # "queue" sheds beyond slack x SLO
//! servers = 2           # parallel read servers
//! service_ms = 1.0      # base read service time
//! hot_service_ms = 0.2  # cache-hot key service time
//! update_frac = 0.2     # fraction of arrivals that are updates
//! batch_size = 32       # update requests folded per iteration
//! n_keys = 64           # Zipf key-space size
//! hot_keys = 4          # most-popular keys served from cache
//! zipf_s = 1.1          # Zipf exponent
//! diurnal_amplitude = 0.0             # rate x (1 + A sin(2 pi t/period))
//! diurnal_period_s = 60.0
//! bursts = "4@2..3;2@10..12"          # factor@start..end, serve seconds
//! seed = 7              # serve RNG family seed
//! ```
//!
//! The `[serve]` section enables online serving mode (`docs/SERVING.md`);
//! it only takes effect through [`crate::runner::Runner`] — the legacy
//! entry points ignore it by construction.

use crate::agg::{AggSpec, TopologyKind};
use crate::cluster::{ClusterSpec, ElasticSchedule, TimingMode};
use crate::coordinator::{AggregatorKind, LossForm, RunConfig, StopRule, SyncMode};
use crate::data::KrrProblemSpec;
use crate::net::{LinkDir, LinkModel, NetSpec};
use crate::optim::{EtaSchedule, OptimizerKind};
use crate::straggler::{DelayModel, FailureModel};
use crate::{Error, Result};

use super::value::Value;

/// What computes the gradients.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// AOT PJRT artifacts (the production path).
    Xla,
    /// Pure-rust mirror (tests / simulation-heavy benches).
    Native,
}

/// Which workload to run.
#[derive(Clone, Debug, PartialEq)]
pub enum ProblemKind {
    Krr,
    Lm { config: String },
}

/// A fully parsed experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub problem_kind: ProblemKind,
    pub krr: KrrProblemSpec,
    pub cluster: ClusterSpec,
    pub run: RunConfig,
    pub timing: TimingMode,
    pub backend: Backend,
    pub out_csv: Option<String>,
    /// `[trace] out`: write the flight-recorder journal (JSONL) here.
    /// Setting either trace path attaches a [`crate::trace::JournalSink`]
    /// to the run (see `docs/OBSERVABILITY.md`).
    pub trace_out: Option<String>,
    /// `[trace] chrome`: write the Chrome trace-event export here.
    pub trace_chrome: Option<String>,
    /// `[bench] threads`: sweep/worker pool size for parallel sweeps
    /// (0 = auto: available parallelism).  Applied process-wide via
    /// [`crate::util::pool::set_default_threads`].
    pub bench_threads: usize,
    /// `[serve]`: online serving mode (see `docs/SERVING.md`).  `None`
    /// when the section is absent; only honoured when the run goes
    /// through [`crate::runner::Runner`].
    pub serve: Option<crate::serve::ServeSpec>,
}

impl ExperimentConfig {
    /// Parse from a TOML document.
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        Self::from_value(&super::toml::parse(text)?)
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<ExperimentConfig> {
        Self::from_value(&super::toml::load(path)?)
    }

    pub fn from_value(v: &Value) -> Result<ExperimentConfig> {
        // --- [problem] -------------------------------------------------
        let pkind = v.opt_str("problem.kind", "krr");
        let config = v.opt_str("problem.config", "default").to_string();
        let machines = v.opt_usize("problem.machines", 8);
        let mut krr = match config.as_str() {
            "small" => KrrProblemSpec::small(),
            "default" => KrrProblemSpec::default_config(),
            "wide" => KrrProblemSpec::wide(),
            other if pkind == "krr" => {
                return Err(Error::Config(format!("unknown krr config '{other}'")))
            }
            _ => KrrProblemSpec::default_config(),
        };
        krr.machines = machines;
        krr.noise = v.opt_f64("problem.noise", krr.noise);
        krr.lambda = v.opt_f64("problem.lambda", krr.lambda);
        krr.seed = v.opt_u64("problem.seed", krr.seed);
        let problem_kind = match pkind {
            "krr" => ProblemKind::Krr,
            "lm" => ProblemKind::Lm {
                config: v.opt_str("problem.config", "lm_tiny").to_string(),
            },
            other => return Err(Error::Config(format!("unknown problem kind '{other}'"))),
        };

        // --- [mode] ----------------------------------------------------
        let mode = parse_mode(v, machines)?;

        // --- [straggler] -> ClusterSpec ---------------------------------
        let delay_kind = v.opt_str("straggler.delay", "none");
        let sub = v
            .get("straggler")
            .cloned()
            .unwrap_or_else(Value::empty_table);
        let delay = DelayModel::from_kind(delay_kind, &sub)?;
        let rejoin = v.opt_u64("straggler.rejoin_after", 0);
        let failure = FailureModel {
            crash_prob: v.opt_f64("straggler.crash_prob", 0.0),
            transient_prob: v.opt_f64("straggler.transient_prob", 0.0),
            rejoin_after: if rejoin > 0 { Some(rejoin) } else { None },
        };
        let slow_n = v.opt_usize("straggler.slow_nodes", 0);
        let slow_factor = v.opt_f64("straggler.slow_factor", 4.0);
        let capacities =
            ClusterSpec::parse_capacities(v.opt_str("straggler.capacities", ""))?;
        for &(w, _) in &capacities {
            if w >= machines {
                return Err(Error::Config(format!(
                    "capacity entry names worker {w} but cluster has {machines}"
                )));
            }
        }

        // --- [elastic] ---------------------------------------------------
        let elastic = ElasticSchedule::parse(v.opt_str("elastic.schedule", ""))?;
        elastic.validate(machines)?;
        let rebalance_every = v.opt_u64("elastic.rebalance_every", 0);
        let warmup_iters = v.opt_u64("elastic.warmup_iters", 0);
        let weighted_rebalance = v.opt_bool("elastic.weighted_rebalance", true);

        // --- [net] -------------------------------------------------------
        let net_sub = v.get("net").cloned().unwrap_or_else(Value::empty_table);
        // Per-direction asymmetry: `up_*`/`down_*` keys override the
        // symmetric link for one direction only (up = Grad replies,
        // down = Work broadcasts).  Absent keys inherit the symmetric
        // fields, so a config without them is bitwise-identical to the
        // pre-asymmetry parse.
        let dir_override = |prefix: &str, base: &LinkModel| -> Result<Option<LinkDir>> {
            let drop_key = format!("net.{prefix}_drop_prob");
            let delay_key = format!("net.{prefix}_delay_secs");
            let drop = v.get(&drop_key).and_then(Value::as_f64);
            let delay = v.get(&delay_key).and_then(Value::as_f64);
            if drop.is_none() && delay.is_none() {
                return Ok(None);
            }
            Ok(Some(LinkDir {
                latency: match delay {
                    Some(secs) => DelayModel::Constant { secs },
                    None => base.latency.clone(),
                },
                drop_prob: drop.unwrap_or(base.drop_prob),
            }))
        };
        let mut default_link = LinkModel {
            latency: DelayModel::from_kind(v.opt_str("net.delay", "none"), &net_sub)?,
            drop_prob: v.opt_f64("net.drop_prob", 0.0),
            dup_prob: v.opt_f64("net.dup_prob", 0.0),
            dup_lag: v.opt_f64("net.dup_lag", 0.001),
            ..LinkModel::ideal()
        };
        default_link.up = dir_override("up", &default_link)?;
        default_link.down = dir_override("down", &default_link)?;
        let mut overrides: Vec<(usize, LinkModel)> = Vec::new();
        if let Some(w) = v.get("net.slow_link").and_then(Value::as_usize) {
            // The chronically slow link's constant latency governs *both*
            // directions (it would otherwise be masked by a per-direction
            // latency inherited from the default link), while each
            // direction keeps its effective configured loss rate.
            let slow_latency = DelayModel::Constant {
                secs: v.opt_f64("net.slow_link_secs", 0.05),
            };
            overrides.push((
                w,
                LinkModel {
                    latency: slow_latency.clone(),
                    up: Some(LinkDir {
                        latency: slow_latency.clone(),
                        drop_prob: default_link.up_dir().1,
                    }),
                    down: Some(LinkDir {
                        latency: slow_latency,
                        drop_prob: default_link.down_dir().1,
                    }),
                    ..default_link.clone()
                },
            ));
        }
        let net = NetSpec {
            default_link,
            overrides,
            partitions: NetSpec::parse_partitions(v.opt_str("net.partitions", ""))?,
            salt: v.opt_u64("net.salt", 0),
            block_size: v.opt_usize("net.block_size", 0),
            min_block_frac: v.opt_f64("net.min_block_frac", 0.0),
        };
        net.validate(machines)?;

        // --- [agg] -------------------------------------------------------
        let agg = AggSpec {
            topology: TopologyKind::parse(v.opt_str("agg.topology", "star"))?,
            fan_in: v.opt_usize("agg.fan_in", 8),
            fold_cost: v.opt_f64("agg.fold_cost", 0.0),
            xfer_cost: v.opt_f64("agg.xfer_cost", 0.0),
        };
        agg.validate(machines, net.block_size)?;

        let cluster = ClusterSpec {
            workers: machines,
            base_compute: v.opt_f64("straggler.base_compute", 0.01),
            delay,
            slow_nodes: vec![],
            capacities,
            warmup_iters,
            weighted_rebalance,
            failure,
            failure_only: v
                .get("straggler.failure_only")
                .and_then(Value::as_array)
                .map(|a| a.iter().filter_map(Value::as_usize).collect())
                .unwrap_or_default(),
            master_overhead: v.opt_f64("straggler.master_overhead", 0.0005),
            elastic,
            rebalance_every,
            net,
            agg,
            seed: v.opt_u64("straggler.seed", 0x5eed),
        }
        .with_slow_tail(slow_n.min(machines), slow_factor);

        // --- [optimizer] -------------------------------------------------
        let optimizer = parse_optimizer(v)?;

        // --- [run] -------------------------------------------------------
        let run = RunConfig {
            mode,
            optimizer,
            aggregator: match v.opt_str("run.aggregator", "mean") {
                "mean" => AggregatorKind::Mean,
                "example-weighted" => AggregatorKind::ExampleWeighted,
                "staleness-damped" => AggregatorKind::StalenessDamped {
                    rho: v.opt_f64("run.rho", 0.5),
                },
                other => return Err(Error::Config(format!("unknown aggregator '{other}'"))),
            },
            stop: StopRule {
                max_iters: v.opt_u64("run.iters", 500),
                loss_tol: v.opt_f64("run.loss_tol", 0.0),
                patience: v.opt_u64("run.patience", 20),
                grad_tol: v.opt_f64("run.grad_tol", 0.0),
            },
            loss_form: if matches!(problem_kind, ProblemKind::Krr) {
                LossForm::krr(krr.lambda)
            } else {
                LossForm::plain()
            },
            bsp_recovery: crate::coordinator::BspRecovery::Retry {
                detect_timeout: v.opt_f64("run.bsp_detect_timeout", 0.05),
            },
            eval_every: v.opt_u64("run.eval_every", 10),
            record_every: v.opt_u64("run.record_every", 1),
            init_theta: None,
            seed: v.opt_u64("run.seed", 1),
            recovery: crate::recovery::RecoveryConfig {
                policy: crate::recovery::RecoveryPolicy::parse(
                    v.opt_str("recovery.policy", "abandon"),
                )?,
                checkpoint_every: v.opt_u64("recovery.checkpoint_every", 25),
            },
        };
        run.recovery.validate()?;

        let timing = match v.opt_str("run.timing", "virtual") {
            "virtual" => TimingMode::Virtual,
            "real" => TimingMode::Real,
            other => return Err(Error::Config(format!("unknown timing '{other}'"))),
        };
        let backend = match v.opt_str("run.backend", "xla") {
            "xla" => Backend::Xla,
            "native" => Backend::Native,
            other => return Err(Error::Config(format!("unknown backend '{other}'"))),
        };

        Ok(ExperimentConfig {
            problem_kind,
            krr,
            cluster,
            run,
            timing,
            backend,
            out_csv: v.get("run.out_csv").and_then(Value::as_str).map(String::from),
            trace_out: v.get("trace.out").and_then(Value::as_str).map(String::from),
            trace_chrome: v.get("trace.chrome").and_then(Value::as_str).map(String::from),
            bench_threads: v.opt_usize("bench.threads", 0),
            serve: if v.get("serve").is_some() {
                Some(crate::serve::ServeSpec::from_value(v)?)
            } else {
                None
            },
        })
    }
}

fn parse_mode(v: &Value, machines: usize) -> Result<SyncMode> {
    Ok(match v.opt_str("mode.kind", "hybrid") {
        "bsp" => SyncMode::Bsp,
        "hybrid" => SyncMode::Hybrid {
            gamma: v.opt_usize("mode.gamma", machines.max(2) * 3 / 4),
        },
        "hybrid-auto" => SyncMode::HybridAuto {
            alpha: v.opt_f64("mode.alpha", 0.05),
            xi: v.opt_f64("mode.xi", 0.05),
        },
        "hybrid-adaptive" => SyncMode::HybridAdaptive {
            alpha: v.opt_f64("mode.alpha", 0.05),
            xi: v.opt_f64("mode.xi", 0.05),
            window: v.opt_u64("mode.window", 20),
        },
        "async" => SyncMode::Async {
            damping: v.opt_f64("mode.damping", 0.0),
        },
        other => return Err(Error::Config(format!("unknown mode '{other}'"))),
    })
}

fn parse_optimizer(v: &Value) -> Result<OptimizerKind> {
    let eta = v.opt_f64("optimizer.eta", 0.5);
    let decay = v.opt_f64("optimizer.decay", 0.0);
    let sched = EtaSchedule { eta0: eta, decay };
    Ok(match v.opt_str("optimizer.kind", "sgd") {
        "sgd" => OptimizerKind::Sgd { eta: sched },
        "momentum" => OptimizerKind::Momentum {
            eta: sched,
            mu: v.opt_f64("optimizer.mu", 0.9),
            nesterov: false,
        },
        "nesterov" => OptimizerKind::Momentum {
            eta: sched,
            mu: v.opt_f64("optimizer.mu", 0.9),
            nesterov: true,
        },
        "adam" => OptimizerKind::Adam {
            eta,
            beta1: v.opt_f64("optimizer.beta1", 0.9),
            beta2: v.opt_f64("optimizer.beta2", 0.999),
            eps: v.opt_f64("optimizer.eps", 1e-8),
        },
        "lbfgs" => OptimizerKind::Lbfgs {
            eta,
            history: v.opt_usize("optimizer.history", 10),
        },
        "cg" => OptimizerKind::Cg {
            eta,
            restart: v.opt_usize("optimizer.restart", 20),
        },
        other => return Err(Error::Config(format!("unknown optimizer '{other}'"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document_parses() {
        let cfg = ExperimentConfig::from_toml(
            r#"
[problem]
kind = "krr"
config = "small"
machines = 12
lambda = 0.02

[mode]
kind = "hybrid"
gamma = 9

[straggler]
delay = "lognormal"
mu = -4.0
sigma = 1.5
slow_nodes = 2
slow_factor = 6.0
crash_prob = 0.01

[optimizer]
kind = "momentum"
eta = 0.3
mu = 0.95

[run]
iters = 123
timing = "virtual"
backend = "native"
"#,
        )
        .unwrap();
        assert_eq!(cfg.krr.machines, 12);
        assert_eq!(cfg.krr.lambda, 0.02);
        assert_eq!(cfg.run.mode, SyncMode::Hybrid { gamma: 9 });
        assert_eq!(cfg.cluster.slow_nodes.len(), 2);
        assert_eq!(cfg.cluster.failure.crash_prob, 0.01);
        assert_eq!(cfg.run.stop.max_iters, 123);
        assert_eq!(cfg.backend, Backend::Native);
        assert_eq!(
            cfg.run.optimizer,
            OptimizerKind::Momentum {
                eta: EtaSchedule { eta0: 0.3, decay: 0.0 },
                mu: 0.95,
                nesterov: false
            }
        );
    }

    #[test]
    fn defaults_fill_in() {
        let cfg = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert_eq!(cfg.krr.machines, 4);
        assert!(matches!(cfg.run.mode, SyncMode::Hybrid { .. }));
        assert_eq!(cfg.timing, TimingMode::Virtual);
        assert_eq!(cfg.bench_threads, 0);
    }

    #[test]
    fn bench_threads_parses() {
        let cfg = ExperimentConfig::from_toml("[bench]\nthreads = 6").unwrap();
        assert_eq!(cfg.bench_threads, 6);
    }

    #[test]
    fn trace_section_parses_and_defaults_off() {
        let cfg = ExperimentConfig::from_toml(
            "[trace]\nout = \"t.jsonl\"\nchrome = \"t.chrome.json\"",
        )
        .unwrap();
        assert_eq!(cfg.trace_out.as_deref(), Some("t.jsonl"));
        assert_eq!(cfg.trace_chrome.as_deref(), Some("t.chrome.json"));
        let off = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert!(off.trace_out.is_none() && off.trace_chrome.is_none());
    }

    #[test]
    fn serve_section_parses_and_defaults() {
        use crate::serve::AdmissionPolicy;
        let cfg = ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[serve]\narrival_rate = 1200\nadmission = \"queue\"\nbursts = \"4@2..3\"",
        )
        .unwrap();
        let sv = cfg.serve.expect("serve section present");
        assert_eq!(sv.arrival_rate, 1200.0);
        assert_eq!(sv.admission, AdmissionPolicy::Queue);
        assert_eq!(sv.bursts.len(), 1);
        assert_eq!(sv.bursts[0].factor, 4.0);
        // Unset keys fall back to the ServeSpec defaults.
        let d = crate::serve::ServeSpec::default();
        assert_eq!(sv.window_ms, d.window_ms);
        assert_eq!(sv.batch_size, d.batch_size);
        let off = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert!(off.serve.is_none());
        assert!(ExperimentConfig::from_toml("[serve]\nadmission = \"coinflip\"").is_err());
        assert!(ExperimentConfig::from_toml("[serve]\nupdate_frac = 1.5").is_err());
    }

    #[test]
    fn rejects_unknown_enum_values() {
        assert!(ExperimentConfig::from_toml("[mode]\nkind = \"warp\"").is_err());
        assert!(ExperimentConfig::from_toml("[optimizer]\nkind = \"qp\"").is_err());
        assert!(ExperimentConfig::from_toml("[run]\ntiming = \"half\"").is_err());
        assert!(ExperimentConfig::from_toml("[problem]\nkind = \"svm\"").is_err());
        assert!(ExperimentConfig::from_toml("[recovery]\npolicy = \"wormhole\"").is_err());
        assert!(ExperimentConfig::from_toml("[agg]\ntopology = \"mesh\"").is_err());
    }

    #[test]
    fn agg_section_parses_and_defaults() {
        use crate::agg::TopologyKind;
        let cfg = ExperimentConfig::from_toml(
            "[problem]\nmachines = 16\n\n[agg]\ntopology = \"tree\"\nfan_in = 4\nfold_cost = 0.0002\nxfer_cost = 0.00001",
        )
        .unwrap();
        assert_eq!(cfg.cluster.agg.topology, TopologyKind::Tree);
        assert_eq!(cfg.cluster.agg.fan_in, 4);
        assert_eq!(cfg.cluster.agg.fold_cost, 0.0002);
        assert_eq!(cfg.cluster.agg.xfer_cost, 0.00001);
        let off = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert!(off.cluster.agg.is_star());
        assert_eq!(off.cluster.agg.fan_in, 8);
        // A tree must fan in at least two children per interior node.
        assert!(
            ExperimentConfig::from_toml("[agg]\ntopology = \"tree\"\nfan_in = 1").is_err()
        );
        // Ring segments the gradient itself; block admission is incompatible.
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\nblock_size = 32\n\n[agg]\ntopology = \"ring\"",
        )
        .is_err());
    }

    #[test]
    fn recovery_section_parses_and_defaults() {
        use crate::recovery::RecoveryPolicy;
        let cfg = ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[recovery]\npolicy = \"checkpoint-restore\"\ncheckpoint_every = 10",
        )
        .unwrap();
        assert_eq!(cfg.run.recovery.policy, RecoveryPolicy::CheckpointRestore);
        assert_eq!(cfg.run.recovery.checkpoint_every, 10);
        let off = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert_eq!(off.run.recovery.policy, RecoveryPolicy::Abandon);
        assert_eq!(off.run.recovery.checkpoint_every, 25);
        // checkpoint-restore with a zero cadence cannot snapshot at all.
        assert!(ExperimentConfig::from_toml(
            "[recovery]\npolicy = \"checkpoint-restore\"\ncheckpoint_every = 0",
        )
        .is_err());
    }

    #[test]
    fn elastic_section_parses() {
        use crate::cluster::ElasticKind;
        let cfg = ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[elastic]\nschedule = \"1:leave@10,1:join@20\"\nrebalance_every = 5",
        )
        .unwrap();
        assert_eq!(cfg.cluster.rebalance_every, 5);
        let evs = cfg.cluster.elastic.events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].worker, 1);
        assert_eq!(evs[0].kind, ElasticKind::Leave);
        assert_eq!(evs[1].iter, 20);
    }

    #[test]
    fn elastic_section_rejects_out_of_range_worker() {
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[elastic]\nschedule = \"4:leave@10\"",
        )
        .is_err());
    }

    #[test]
    fn elastic_defaults_to_static() {
        let cfg = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert!(cfg.cluster.elastic.is_empty());
        assert_eq!(cfg.cluster.rebalance_every, 0);
        assert_eq!(cfg.cluster.warmup_iters, 0);
        assert!(cfg.cluster.weighted_rebalance);
        assert!(cfg.cluster.capacities.is_empty());
    }

    #[test]
    fn capacity_section_parses() {
        let cfg = ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[straggler]\ncapacities = \"2:0.25,3:0.5\"\n\n\
             [elastic]\nwarmup_iters = 8\nweighted_rebalance = false",
        )
        .unwrap();
        assert_eq!(cfg.cluster.capacities, vec![(2, 0.25), (3, 0.5)]);
        assert_eq!(cfg.cluster.warmup_iters, 8);
        assert!(!cfg.cluster.weighted_rebalance);
        assert_eq!(cfg.cluster.capacity_vec(), vec![1.0, 1.0, 0.25, 0.5]);
    }

    #[test]
    fn capacity_section_rejects_bad_entries() {
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[straggler]\ncapacities = \"4:0.5\"",
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[straggler]\ncapacities = \"1:0\"",
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[straggler]\ncapacities = \"bogus\"",
        )
        .is_err());
    }

    #[test]
    fn net_section_parses() {
        let cfg = ExperimentConfig::from_toml(
            r#"
[problem]
machines = 8

[net]
drop_prob = 0.1
dup_prob = 0.05
delay = "constant"
secs = 0.002
partitions = "3-5@40..60;0@10..20"
slow_link = 7
slow_link_secs = 0.03
salt = 9
"#,
        )
        .unwrap();
        let net = &cfg.cluster.net;
        assert!(!net.is_ideal());
        assert_eq!(net.default_link.drop_prob, 0.1);
        assert_eq!(net.default_link.dup_prob, 0.05);
        assert_eq!(
            net.default_link.latency,
            crate::straggler::DelayModel::Constant { secs: 0.002 }
        );
        assert_eq!(net.partitions.len(), 2);
        assert_eq!(net.partitions[0].workers, vec![3, 4, 5]);
        assert_eq!(net.overrides.len(), 1);
        assert_eq!(net.overrides[0].0, 7);
        assert_eq!(
            net.overrides[0].1.latency,
            crate::straggler::DelayModel::Constant { secs: 0.03 }
        );
        // The override inherits the default link's loss behaviour.
        assert_eq!(net.overrides[0].1.drop_prob, 0.1);
        assert_eq!(net.salt, 9);
    }

    #[test]
    fn net_per_direction_overrides_parse() {
        let cfg = ExperimentConfig::from_toml(
            r#"
[problem]
machines = 4

[net]
drop_prob = 0.1
up_drop_prob = 0.3
up_delay_secs = 0.04
"#,
        )
        .unwrap();
        let link = &cfg.cluster.net.default_link;
        // Uplink overridden, downlink inherits the symmetric fields.
        let (up_lat, up_drop) = link.up_dir();
        assert_eq!(up_drop, 0.3);
        assert_eq!(
            *up_lat,
            crate::straggler::DelayModel::Constant { secs: 0.04 }
        );
        let (down_lat, down_drop) = link.down_dir();
        assert_eq!(down_drop, 0.1);
        assert_eq!(*down_lat, crate::straggler::DelayModel::None);
        assert!(link.down.is_none());
        // Out-of-range per-direction probability is rejected.
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\nup_drop_prob = 1.5",
        )
        .is_err());
    }

    #[test]
    fn net_defaults_to_ideal() {
        let cfg = ExperimentConfig::from_toml("[problem]\nmachines = 4").unwrap();
        assert!(cfg.cluster.net.is_ideal());
        assert_eq!(cfg.cluster.net.block_size, 0);
        assert_eq!(cfg.cluster.net.min_block_frac, 0.0);
    }

    #[test]
    fn net_block_admission_parses() {
        let cfg = ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\ndrop_prob = 0.1\nblock_size = 32\nmin_block_frac = 0.25",
        )
        .unwrap();
        assert_eq!(cfg.cluster.net.block_size, 32);
        assert_eq!(cfg.cluster.net.min_block_frac, 0.25);
        // Blocking alone does not perturb the ideal-net fast path.
        let ideal = ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\nblock_size = 32",
        )
        .unwrap();
        assert!(ideal.cluster.net.is_ideal());
        // min_block_frac is a probability-like fraction.
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\nmin_block_frac = 1.5",
        )
        .is_err());
    }

    #[test]
    fn net_section_rejects_bad_values() {
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\ndrop_prob = 1.5",
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\npartitions = \"9@1..5\"",
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[problem]\nmachines = 4\n\n[net]\npartitions = \"bogus\"",
        )
        .is_err());
    }

    #[test]
    fn lm_problem_kind() {
        let cfg =
            ExperimentConfig::from_toml("[problem]\nkind = \"lm\"\nconfig = \"lm_tiny\"").unwrap();
        assert_eq!(cfg.problem_kind, ProblemKind::Lm { config: "lm_tiny".into() });
        assert_eq!(cfg.run.loss_form, LossForm::plain());
    }
}
