//! Elastic cluster demo: workers leave mid-run, rejoin later, and the
//! coordinator rebalances shards onto the live set at iteration
//! boundaries — so no shard's rows stop contributing and the aggregate
//! stays unbiased under churn.
//!
//! Three policies on the same scripted churn trace (2 of 8 workers leave
//! at iteration 60 and rejoin at 140):
//!
//! * `static`            — no churn (reference);
//! * `churn-orphaned`    — the seed behaviour: leavers' shards go dark;
//! * `churn-rebalanced`  — survivors adopt the orphaned shards, load
//!                         levels back when the leavers return.
//!
//!     cargo run --release --example elastic_cluster

use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::{ClusterSpec, ElasticSchedule};
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::{KrrProblem, KrrProblemSpec};
use hybriditer::optim::OptimizerKind;
use hybriditer::sim;
use hybriditer::straggler::DelayModel;

fn main() -> anyhow::Result<()> {
    hybriditer::util::logger::init();
    let m = 8;
    let (leave_at, rejoin_at, iters) = (60u64, 140u64, 300u64);
    let spec = KrrProblemSpec::small().with_machines(m);
    let problem = KrrProblem::generate(&spec)?;
    let churn = ElasticSchedule::crash_and_rejoin(&[m - 2, m - 1], leave_at, rejoin_at);

    let mut table = Table::new(
        format!("elastic cluster: 2/{m} leave@{leave_at} join@{rejoin_at}, gamma=6"),
        &[
            "policy",
            "virt_secs",
            "final_loss",
            "theta_err",
            "shards/iter@outage",
            "rebalances",
        ],
    );

    for (name, elastic, rebalance_every) in [
        ("static", ElasticSchedule::default(), 0u64),
        ("churn-orphaned", churn.clone(), 0),
        ("churn-rebalanced", churn.clone(), 1),
    ] {
        // A stochastic delay rotates which γ workers close each barrier,
        // so over time every shard contributes (no systematic abandonment).
        let cluster = ClusterSpec {
            workers: m,
            base_compute: 0.01,
            delay: DelayModel::Uniform { lo: 0.0, hi: 0.01 },
            seed: 7,
            ..ClusterSpec::default()
        }
        .with_elastic(elastic, rebalance_every);
        let cfg = RunConfig {
            mode: SyncMode::Hybrid { gamma: 6 },
            optimizer: OptimizerKind::sgd(1.0),
            loss_form: LossForm::krr(spec.lambda),
            eval_every: 20,
            ..RunConfig::default()
        }
        .with_iters(iters);

        let mut pool = problem.native_pool();
        let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &problem)?;
        println!("{}", rep.summary());

        // Mean shards aggregated per iteration during the outage window.
        let outage: Vec<usize> = rep
            .recorder
            .rows()
            .iter()
            .filter(|r| (leave_at..rejoin_at).contains(&r.iter))
            .map(|r| r.included)
            .collect();
        let mean_included = if outage.is_empty() {
            m as f64
        } else {
            outage.iter().sum::<usize>() as f64 / outage.len() as f64
        };

        table.row(vec![
            name.to_string(),
            f(rep.total_time(), 2),
            format!("{:.6}", rep.final_loss()),
            rep.final_theta_err()
                .map(|e| format!("{e:.3e}"))
                .unwrap_or_else(|| "-".into()),
            f(mean_included, 1),
            rep.rebalances.to_string(),
        ]);
    }
    table.print();
    table.save_csv("example_elastic_cluster")?;
    println!(
        "\nReading: without rebalancing the two leavers' shards vanish from\n\
         the aggregate for the whole outage (shards/iter drops), biasing the\n\
         reachable optimum; with rebalancing the survivors adopt those shards\n\
         at the next iteration boundary, every row keeps contributing, and\n\
         the run matches the static reference's final accuracy."
    );
    Ok(())
}
