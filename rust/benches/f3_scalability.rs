//! F3 — scalability: per-iteration time vs cluster size M for the three
//! barrier policies under a fixed per-worker latency distribution.
//!
//! Expected shape: BSP's iteration time grows like the expected *maximum*
//! of M lognormals (≈ log M growth) while hybrid γ=¾M tracks the ¾-order
//! statistic (flat-ish), so the gap widens with M — the paper's "scalable
//! platforms" motivation.  Async throughput scales linearly but each
//! update uses one shard only.
//!
//! The M-points run concurrently on the sweep engine (`--threads N`
//! overrides the pool size); each point is seed-determined, so the table
//! matches a serial run exactly.

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::optim::OptimizerKind;
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;

fn main() {
    let iters = 120u64;
    let engine = SweepEngine::from_env();
    println!("F3: iteration-time scalability — lognormal(mu=-4, sigma=1), {iters} iters");
    println!("sweep pool: {} threads\n", engine.threads());

    let mut table = Table::new(
        "F3 mean time per iteration vs M",
        &["M", "gamma", "bsp_ms", "hybrid_ms", "async_ms_per_update_x_M", "bsp/hybrid"],
    );
    let ms = [2usize, 4, 8, 16, 32, 64];
    let rows = engine.run(&ms, |cache, &m| {
        let spec = KrrProblemSpec {
            machines: m,
            ..KrrProblemSpec::small()
        };
        let problem = cache.get(&spec);
        let cluster = ClusterSpec {
            workers: m,
            base_compute: 0.01,
            delay: DelayModel::LogNormal { mu: -4.0, sigma: 1.0 },
            ..ClusterSpec::default()
        };
        let gamma = (m * 3 / 4).max(1);
        let per_iter = |mode: SyncMode, n_iters: u64| -> f64 {
            let cfg = RunConfig {
                mode,
                optimizer: OptimizerKind::sgd(1.0),
                loss_form: LossForm::krr(spec.lambda),
                eval_every: 0,
                record_every: 1,
                ..RunConfig::default()
            }
            .with_iters(n_iters);
            let mut pool = problem.native_pool();
            let rep = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval).unwrap();
            rep.total_time() / n_iters as f64 * 1e3
        };
        let bsp = per_iter(SyncMode::Bsp, iters);
        let hyb = per_iter(SyncMode::Hybrid { gamma }, iters);
        let asy = per_iter(SyncMode::Async { damping: 0.0 }, iters * m as u64) * m as f64;
        (gamma, bsp, hyb, asy)
    });
    for (&m, &(gamma, bsp, hyb, asy)) in ms.iter().zip(&rows) {
        table.row(vec![
            m.to_string(),
            gamma.to_string(),
            f(bsp, 2),
            f(hyb, 2),
            f(asy, 2),
            f(bsp / hyb, 2),
        ]);
    }
    table.print();
    table.save_csv("f3_scalability").unwrap();
    println!(
        "\nReading: BSP tracks the max of M lognormal latencies (grows with\n\
         log M); hybrid tracks the gamma-th order statistic (≈flat), so the\n\
         bsp/hybrid ratio widens with cluster size."
    );
}
