//! Pure-rust mirror of the L1/L2 compute: the same math as the pallas
//! kernel (`(1/ζ)Φᵀ(Φθ−y) + λθ`), used
//!
//! * as the reference the XLA path is integration-tested against,
//! * by benches that sweep thousands of virtual iterations where PJRT
//!   dispatch overhead would dominate the thing being measured (straggler
//!   policy behaviour, not kernel speed).
//!
//! The production path runs the fused single-pass kernel
//! ([`crate::math::kernels::fused_resid_grad`]); the seed's two-pass
//! implementation survives as [`krr_shard_grad_reference`], the golden
//! baseline the fused kernel is equivalence-tested against (the two are
//! bit-identical by construction — see `math/kernels.rs`).

use crate::data::shard::Shard;
use crate::data::{ComputePool, GradResult};
use crate::math::kernels;
use crate::Result;

/// Finish a raw `Φᵀ(Φθ−y)` accumulation into the KRR gradient:
/// `g ← g/ζ + λθ` — shared by the fused and reference paths so the final
/// elementwise ops are literally the same code.
#[inline]
fn finish_grad(grad: &mut [f32], theta: &[f32], lambda: f32, rows: usize) {
    let inv = 1.0 / rows as f32;
    for (g, &t) in grad.iter_mut().zip(theta.iter()) {
        *g = *g * inv + lambda * t;
    }
}

/// Gradient width at and above which [`krr_shard_grad_into`] switches
/// from the fused single-pass kernel to the column-blocked two-pass one
/// ([`kernels::blocked_resid_grad`]): past this point the fused kernel's
/// per-row Φᵀr update re-streams an `l`-wide gradient that no longer
/// stays cache-resident, and the blocked stripes win (the `wide` config,
/// l = 256, sits exactly at the threshold).  All three kernels are
/// bit-identical, so the switch can never move a θ trajectory.
pub const WIDE_L_THRESHOLD: usize = 256;

/// One shard's KRR gradient/loss, written into a caller-owned
/// [`GradResult`] (`g = Φᵀ(Φθ−y)/ζ + λθ`).  Shared by the native pool,
/// the threaded runtime's per-worker compute, and (through
/// [`ComputePool::grad_into`]) the virtual driver's scratch arena.
/// Narrow shards run the fused single-pass kernel; shards at or past
/// [`WIDE_L_THRESHOLD`] run the column-blocked kernel, whose residual
/// pass borrows `resid` (grown once, reused across calls).
pub fn krr_shard_grad_into(
    s: &Shard,
    lambda: f32,
    theta: &[f32],
    resid: &mut Vec<f32>,
    out: &mut GradResult,
) {
    let (rows, l) = (s.rows, s.l);
    debug_assert_eq!(theta.len(), l);
    out.grad.resize(l, 0.0);
    let ss = if l >= WIDE_L_THRESHOLD {
        kernels::blocked_resid_grad(&s.phi, rows, l, theta, &s.y, resid, &mut out.grad)
    } else {
        kernels::fused_resid_grad(&s.phi, rows, l, theta, &s.y, &mut out.grad)
    };
    finish_grad(&mut out.grad, theta, lambda, rows);
    out.loss_sum = Some(ss);
    out.examples = rows;
}

/// The seed's two-pass gradient (matvec + matvec_t), kept as the golden
/// reference implementation.  `resid` is a scratch buffer grown as needed.
pub fn krr_shard_grad_reference(
    s: &Shard,
    lambda: f32,
    theta: &[f32],
    resid: &mut Vec<f32>,
    out: &mut GradResult,
) {
    let (rows, l) = (s.rows, s.l);
    debug_assert_eq!(theta.len(), l);
    out.grad.resize(l, 0.0);
    let ss = kernels::reference_resid_grad(&s.phi, rows, l, theta, &s.y, resid, &mut out.grad);
    finish_grad(&mut out.grad, theta, lambda, rows);
    out.loss_sum = Some(ss);
    out.examples = rows;
}

/// Native KRR gradient pool over per-worker shards.
pub struct NativeKrrPool {
    shards: Vec<Shard>,
    lambda: f32,
    /// Run the two-pass reference kernel instead of the fused one (golden
    /// equivalence tests only).
    reference: bool,
    /// Scratch residual buffer for the reference and column-blocked paths.
    resid: Vec<f32>,
}

impl NativeKrrPool {
    pub fn new(shards: Vec<Shard>, lambda: f32) -> NativeKrrPool {
        NativeKrrPool {
            shards,
            lambda,
            reference: false,
            resid: Vec::new(),
        }
    }

    /// A pool running the seed's two-pass reference kernel — the "before"
    /// implementation the fused path is bit-equivalence-tested against.
    pub fn reference(shards: Vec<Shard>, lambda: f32) -> NativeKrrPool {
        NativeKrrPool {
            reference: true,
            ..NativeKrrPool::new(shards, lambda)
        }
    }

    pub fn lambda(&self) -> f32 {
        self.lambda
    }
}

impl ComputePool for NativeKrrPool {
    fn dim(&self) -> usize {
        self.shards.first().map(|s| s.l).unwrap_or(0)
    }

    fn n_workers(&self) -> usize {
        self.shards.len()
    }

    fn shard_examples(&self, w: usize) -> usize {
        self.shards[w].rows
    }

    fn grad_into(
        &mut self,
        w: usize,
        theta: &[f32],
        _iter: u64,
        out: &mut GradResult,
    ) -> Result<()> {
        let s = &self.shards[w];
        if self.reference {
            krr_shard_grad_reference(s, self.lambda, theta, &mut self.resid, out);
        } else {
            krr_shard_grad_into(s, self.lambda, theta, &mut self.resid, out);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{KrrProblem, KrrProblemSpec};
    use crate::math::vec_ops;
    use crate::util::rng::Pcg64;

    fn tiny() -> KrrProblem {
        let spec = KrrProblemSpec {
            config: "test".into(),
            d: 4,
            l: 8,
            zeta: 32,
            machines: 4,
            noise: 0.05,
            lambda: 0.05,
            bandwidth: 1.0,
            eval_rows: 64,
            seed: 3,
        };
        KrrProblem::generate(&spec).unwrap()
    }

    #[test]
    fn zero_gradient_at_shardwise_optimum() {
        // The mean of all shard gradients at θ* must vanish (first-order
        // optimality of eq. 2 over the full training set).
        let p = tiny();
        let mut pool = p.native_pool();
        let m = pool.n_workers();
        let mut mean = vec![0.0f32; p.dim()];
        for w in 0..m {
            let g = pool.grad(w, &p.theta_star, 0).unwrap();
            vec_ops::add_assign(&mut mean, &g.grad);
        }
        vec_ops::scale(&mut mean, 1.0 / m as f32);
        assert!(
            vec_ops::norm2(&mean) < 1e-4,
            "grad at optimum = {}",
            vec_ops::norm2(&mean)
        );
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let p = tiny();
        let mut pool = p.native_pool();
        let mut rng = Pcg64::seeded(5);
        let mut theta = vec![0.0f32; p.dim()];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        let g = pool.grad(0, &theta, 0).unwrap().grad;

        let s = &p.shards[0];
        let f = |t: &[f32]| crate::data::synth::objective(t, &s.phi, &s.y, s.l, p.spec.lambda);
        let eps = 1e-3f32;
        for coord in [0, p.dim() / 2, p.dim() - 1] {
            let mut tp = theta.clone();
            tp[coord] += eps;
            let mut tm = theta.clone();
            tm[coord] -= eps;
            let fd = (f(&tp) - f(&tm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[coord] as f64).abs() < 2e-3,
                "coord {coord}: fd {fd} vs g {}",
                g[coord]
            );
        }
    }

    #[test]
    fn loss_sum_matches_direct() {
        let p = tiny();
        let mut pool = p.native_pool();
        let g = pool.grad(1, &p.theta_true, 0).unwrap();
        let s = &p.shards[1];
        let direct = crate::data::synth::sumsq_residual(&p.theta_true, &s.phi, &s.y, s.l);
        assert!((g.loss_sum.unwrap() - direct).abs() < 1e-6);
        assert_eq!(g.examples, 32);
    }

    #[test]
    fn fused_pool_matches_reference_pool_exactly() {
        let p = tiny();
        let mut fused = p.native_pool();
        let mut reference = p.reference_pool();
        let mut rng = Pcg64::seeded(11);
        let mut theta = vec![0.0f32; p.dim()];
        rng.fill_normal(&mut theta, 0.0, 1.0);
        for w in 0..fused.n_workers() {
            let gf = fused.grad(w, &theta, 0).unwrap();
            let gr = reference.grad(w, &theta, 0).unwrap();
            assert_eq!(gf.grad, gr.grad, "worker {w} grad bits diverged");
            assert_eq!(
                gf.loss_sum.unwrap().to_bits(),
                gr.loss_sum.unwrap().to_bits(),
                "worker {w} loss bits diverged"
            );
        }
    }

    #[test]
    fn grad_into_reuses_buffer_without_allocating_growth() {
        let p = tiny();
        let mut pool = p.native_pool();
        let mut out = GradResult::empty();
        pool.grad_into(0, &p.theta_true, 0, &mut out).unwrap();
        let cap = out.grad.capacity();
        let first = out.grad.clone();
        pool.grad_into(0, &p.theta_true, 1, &mut out).unwrap();
        assert_eq!(out.grad, first);
        assert_eq!(out.grad.capacity(), cap, "reuse must not reallocate");
    }

    #[test]
    fn full_gd_converges_to_theta_star() {
        // Plain full-batch GD with all shards must approach θ* — sanity
        // that data, gradient, and solver agree with each other.
        let p = tiny();
        let mut pool = p.native_pool();
        let m = pool.n_workers();
        let mut theta = vec![0.0f32; p.dim()];
        let mut mean = vec![0.0f32; p.dim()];
        for it in 0..400 {
            mean.fill(0.0);
            for w in 0..m {
                let g = pool.grad(w, &theta, it).unwrap();
                vec_ops::add_assign(&mut mean, &g.grad);
            }
            vec_ops::scale(&mut mean, 1.0 / m as f32);
            vec_ops::axpy(-1.5, &mean, &mut theta);
        }
        let err = p.theta_err(&theta);
        assert!(err < 1e-3, "theta_err={err}");
    }
}
