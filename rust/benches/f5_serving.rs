//! F5 (serving) — online serving under offered load: staleness and read
//! latency vs load multiplier, and the saturation knee.
//!
//! The serve queue model is deterministic (docs/SERVING.md): each
//! completed iteration closes one `window_ms` serve-clock window in
//! which `servers` drain `window_ms` of read work each.  At the default
//! spec (2 servers, 1 ms cold / 0.2 ms cache-hot service, Zipf-skewed
//! keys) the read capacity is ~20 ms of service per 10 ms window, so an
//! open-admission sweep over load multipliers crosses saturation
//! between 2x and 3x the 1600 req/s base rate — read backlog then grows
//! without bound and p99 blows through the 50 ms SLO.  The **knee** is
//! the first load whose open-admission read p99 exceeds the SLO; the
//! shed half re-runs the same loads with SLO-aware admission and shows
//! p99 staying bounded while the shed fraction absorbs the overload.
//!
//! Emits `results/BENCH_f5_serving.json`; CI uploads it and gates on
//! `saturation_knee_load` (>20% regression fails).

use hybriditer::bench_harness::sweep::SweepEngine;
use hybriditer::bench_harness::{f, Table};
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::data::KrrProblemSpec;
use hybriditer::optim::OptimizerKind;
use hybriditer::prelude::{AdmissionPolicy, Driver, Runner, ServeSpec, ServeStats};

const ITERS: u64 = 300;
const BASE_RATE: f64 = 1600.0;
const LOADS: [f64; 8] = [0.25, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

fn run_one(
    problem: &hybriditer::data::KrrProblem,
    load: f64,
    admission: AdmissionPolicy,
) -> ServeStats {
    let cluster = ClusterSpec {
        workers: 4,
        base_compute: 0.01,
        seed: 11,
        ..ClusterSpec::default()
    };
    let cfg = RunConfig {
        mode: SyncMode::Bsp,
        optimizer: OptimizerKind::sgd(0.8),
        loss_form: LossForm::krr(problem.spec.lambda),
        eval_every: 0,
        ..RunConfig::default()
    }
    .with_iters(ITERS);
    let spec = ServeSpec {
        arrival_rate: BASE_RATE * load,
        admission,
        ..ServeSpec::default()
    };
    let mut pool = problem.native_pool();
    let rep = Runner::new(&cluster, &cfg)
        .driver(Driver::Virtual)
        .pool(&mut pool)
        .serve(spec)
        .run()
        .unwrap();
    assert!(rep.status.is_healthy(), "load={load}: {:?}", rep.status);
    rep.serve.expect("serving run must carry ServeStats")
}

fn main() {
    let engine = SweepEngine::from_env();
    println!(
        "F5 serving: read p99 and staleness vs offered load \
         (base {BASE_RATE} req/s, {ITERS} windows)"
    );
    println!("sweep pool: {} threads\n", engine.threads());
    let spec = KrrProblemSpec { machines: 4, ..KrrProblemSpec::small() };

    let swept = engine.run(&LOADS, |cache, &load| {
        let problem = cache.get(&spec);
        let open = run_one(&problem, load, AdmissionPolicy::Open);
        let shed = run_one(&problem, load, AdmissionPolicy::Shed);
        (open, shed)
    });

    let slo = ServeSpec::default().read_slo_ms;
    let mut table = Table::new(
        "F5 serving: open vs shed admission per load multiplier",
        &["load", "offered", "open_p99_ms", "open_stale_p99", "shed_pct", "shed_p99_ms"],
    );
    let mut knee: Option<f64> = None;
    let mut p99_at_knee = f64::NAN;
    for (&load, (open, shed)) in LOADS.iter().zip(&swept) {
        if knee.is_none() && open.read_p99_ms > slo {
            knee = Some(load);
            p99_at_knee = open.read_p99_ms;
        }
        table.row(vec![
            f(load, 2),
            open.offered.to_string(),
            f(open.read_p99_ms, 2),
            f(open.staleness_p99, 2),
            f(100.0 * shed.shed_rate(), 1),
            f(shed.read_p99_ms, 2),
        ]);
    }
    table.print();

    let (open_max, shed_max) = swept.last().expect("non-empty sweep");
    let open_rows: Vec<String> = LOADS
        .iter()
        .zip(&swept)
        .map(|(&load, (o, _))| {
            format!(
                "    {{\"load\": {load}, \"offered\": {}, \"admitted\": {}, \
                 \"read_p50_ms\": {:.4}, \"read_p99_ms\": {:.4}, \"update_p99_ms\": {:.4}, \
                 \"staleness_mean\": {:.4}, \"staleness_p99\": {:.4}, \"digest\": {}}}",
                o.offered,
                o.admitted,
                o.read_p50_ms,
                o.read_p99_ms,
                o.update_p99_ms,
                o.staleness_mean,
                o.staleness_p99,
                o.seq_digest
            )
        })
        .collect();
    let shed_rows: Vec<String> = LOADS
        .iter()
        .zip(&swept)
        .map(|(&load, (_, s))| {
            format!(
                "    {{\"load\": {load}, \"offered\": {}, \"shed_pct\": {:.3}, \
                 \"read_p99_ms\": {:.4}, \"staleness_p99\": {:.4}}}",
                s.offered,
                100.0 * s.shed_rate(),
                s.read_p99_ms,
                s.staleness_p99
            )
        })
        .collect();
    let knee_json = knee.map(|l| l.to_string()).unwrap_or_else(|| "null".to_string());
    let p99_at_knee_json = if p99_at_knee.is_finite() {
        format!("{p99_at_knee:.4}")
    } else {
        "null".to_string()
    };
    let json = format!(
        "{{\n  \"bench\": \"f5_serving\",\n  \"iters\": {ITERS},\n  \
         \"base_rate\": {BASE_RATE},\n  \"read_slo_ms\": {slo},\n  \"headline\": {{\n    \
         \"saturation_knee_load\": {knee_json},\n    \
         \"read_p99_at_knee_ms\": {p99_at_knee_json},\n    \
         \"staleness_p99_at_max_load\": {:.4},\n    \
         \"shed_pct_at_max_load\": {:.3}\n  }},\n  \
         \"open\": [\n{}\n  ],\n  \"shed\": [\n{}\n  ]\n}}\n",
        open_max.staleness_p99,
        100.0 * shed_max.shed_rate(),
        open_rows.join(",\n"),
        shed_rows.join(",\n")
    );
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/BENCH_f5_serving.json", json).unwrap();
    match knee {
        Some(l) => println!(
            "\nheadline: open-admission read p99 breaks the {slo} ms SLO at load {l} \
             (p99 {p99_at_knee:.1} ms); shed at max load keeps p99 {:.1} ms",
            shed_max.read_p99_ms
        ),
        None => println!("\nheadline: no saturation knee up to load {}", LOADS[LOADS.len() - 1]),
    }
    println!("trajectory point -> results/BENCH_f5_serving.json");
}
