//! END-TO-END DRIVER: pretrain a decoder-only transformer LM with the
//! hybrid data-parallel coordinator — every layer composing:
//!
//!   L1 pallas-authored kernels → L2 jax transformer fwd/bwd (AOT `lm_step`
//!   artifact) → PJRT runtime → L3 hybrid γ-of-M coordinator with straggler
//!   injection → Adam master.
//!
//! Trains on a synthetic bigram corpus whose conditional entropy is known
//! exactly, so the loss curve has a computable floor; logs the curve and
//! records the run for EXPERIMENTS.md.
//!
//!     cargo run --release --example lm_pretrain -- [--config lm_small]
//!         [--workers 4] [--gamma 3] [--steps 300] [--eta 1e-3]

use hybriditer::cli::ArgSpec;
use hybriditer::cluster::ClusterSpec;
use hybriditer::coordinator::{LossForm, RunConfig, SyncMode};
use hybriditer::lm::{init::init_params, LmPool};
use hybriditer::metrics::csv;
use hybriditer::optim::OptimizerKind;
use hybriditer::runtime::{ArtifactSet, Engine};
use hybriditer::sim::{self, NoEval};
use hybriditer::straggler::DelayModel;

fn main() -> anyhow::Result<()> {
    hybriditer::util::logger::init();
    let args = ArgSpec::new("lm_pretrain", "end-to-end hybrid data-parallel LM pretraining")
        .opt("config", "lm_small", "LM artifact config (lm_tiny | lm_small | lm_medium)")
        .opt("workers", "4", "data-parallel workers M")
        .opt("gamma", "3", "hybrid barrier gamma (0 = BSP)")
        .opt("steps", "300", "training steps")
        .opt("eta", "0.001", "adam learning rate")
        .opt("seed", "1234", "seed")
        .opt("save", "", "write a final checkpoint here (e.g. results/lm.ckpt)")
        .opt("resume", "", "warm-start parameters from a checkpoint")
        .parse_or_exit();
    let config = args.get("config").to_string();
    let m = args.get_usize("workers")?;
    let gamma = args.get_usize("gamma")?;
    let steps = args.get_u64("steps")?;
    let eta = args.get_f64("eta")?;
    let seed = args.get_u64("seed")?;

    let artifacts = ArtifactSet::discover()?;
    let engine = Engine::cpu()?;
    let t0 = std::time::Instant::now();
    let mut pool = LmPool::new(&artifacts, &engine, &config, m, 4, seed)?;
    let task = pool.task().clone();
    println!(
        "model: {} — vocab={} d_model={} layers={} heads={} seq={} batch={}  ({:.2}M params)",
        task.config,
        task.vocab,
        task.d_model,
        task.n_layer,
        task.n_head,
        task.seq,
        task.batch,
        task.n_params as f64 / 1e6
    );
    println!(
        "corpus: synthetic bigram chain, entropy floor = {:.4} nats (uniform = {:.4})",
        pool.loss_floor(),
        (task.vocab as f64).ln()
    );
    println!("compiled lm_step artifact in {:.2}s", t0.elapsed().as_secs_f64());

    // Cluster with mild stragglers so the hybrid barrier has work to do.
    let cluster = ClusterSpec {
        workers: m,
        base_compute: 0.05,
        delay: DelayModel::LogNormal { mu: -3.5, sigma: 0.8 },
        seed,
        ..ClusterSpec::default()
    };
    let mode = if gamma == 0 {
        SyncMode::Bsp
    } else {
        SyncMode::Hybrid { gamma: gamma.min(m) }
    };
    let init = if args.get("resume").is_empty() {
        init_params(&task, seed)
    } else {
        let ckpt =
            hybriditer::data::Checkpoint::load(std::path::Path::new(args.get("resume")))?;
        anyhow::ensure!(
            ckpt.theta.len() == task.n_params,
            "checkpoint has {} params, model wants {}",
            ckpt.theta.len(),
            task.n_params
        );
        println!("resumed from {} (iter {})", args.get("resume"), ckpt.iter);
        ckpt.theta
    };
    let cfg = RunConfig {
        mode,
        optimizer: OptimizerKind::Adam { eta, beta1: 0.9, beta2: 0.999, eps: 1e-8 },
        loss_form: LossForm::plain(),
        eval_every: 0,
        record_every: 1,
        init_theta: Some(init),
        seed,
        ..RunConfig::default()
    }
    .with_iters(steps);

    println!(
        "training: mode={} M={m} steps={steps} adam eta={eta}\n",
        cfg.mode.name()
    );
    let train0 = std::time::Instant::now();
    let report = sim::run_virtual(&mut pool, &cluster, &cfg, &NoEval)?;
    let wall = train0.elapsed().as_secs_f64();

    // Loss curve (every ~step/20 rows).
    println!("step     vtime(s)   train_loss   grad_norm");
    let rows = report.recorder.rows();
    let stride = (rows.len() / 20).max(1);
    for r in rows.iter().step_by(stride) {
        println!(
            "{:>5} {:>10.2} {:>12.4} {:>11.4}",
            r.iter, r.time, r.loss, r.grad_norm
        );
    }
    if let Some(last) = rows.last() {
        if (rows.len() - 1) % stride != 0 {
            println!(
                "{:>5} {:>10.2} {:>12.4} {:>11.4}",
                last.iter, last.time, last.loss, last.grad_norm
            );
        }
    }

    let first = rows.first().map(|r| r.loss).unwrap_or(f64::NAN);
    let last = report.final_loss();
    println!("\n{}", report.summary());
    println!(
        "loss: {first:.4} -> {last:.4}  (uniform {:.4}, bigram floor {:.4})",
        (task.vocab as f64).ln(),
        pool.loss_floor()
    );
    println!(
        "wall-clock: {wall:.1}s driver, {:.1} steps/s, abandon rate {:.1}%",
        steps as f64 / wall,
        report.abandon_rate() * 100.0
    );
    let path = std::path::Path::new("results/lm_pretrain_loss_curve.csv");
    csv::write_recorder(&report.recorder, path)?;
    println!("loss curve -> {}", path.display());
    if !args.get("save").is_empty() {
        use hybriditer::config::Value;
        let ckpt = hybriditer::data::Checkpoint::new(report.theta.clone(), steps)
            .with_meta("config", Value::Str(config.clone()))
            .with_meta("final_loss", Value::Float(last))
            .with_meta("mode", Value::Str(cfg.mode.name().into()));
        ckpt.save(std::path::Path::new(args.get("save")))?;
        println!("checkpoint -> {}", args.get("save"));
    }
    Ok(())
}
