//! Online serving mode: an open-loop request stream driving mini-batch
//! updates while readers consume θ under p99 latency SLOs.
//!
//! Three pieces (see `docs/SERVING.md`):
//!
//! * an **arrival process** on the serve clock — diurnal rate curve,
//!   hot-key Zipf skew, scripted bursts — where every arrival's fate is a
//!   pure function of `(seed, tick)`: each window draws from a fresh
//!   [`Pcg64`] streamed by its tick, so neither driver's RNGs are
//!   perturbed and both realize bit-identical sequences;
//! * an **admission controller** that sheds or queues requests per class
//!   against the read/update p99 SLOs, over a deterministic backlog-work
//!   queue model;
//! * a **read path** over double-buffered θ snapshots ([`ThetaCell`]):
//!   the training loop publishes at barrier close, readers get
//!   epoch-tagged `Arc` views, and steady-state reads are zero-alloc
//!   (`tests/alloc_regression.rs`).
//!
//! The engine is stepped once per *completed* training iteration
//! ([`ServeEngine::on_barrier_close`]), keyed on the iteration index —
//! never on driver time — so the virtual and threaded drivers realize the
//! same serving history for the same `(seed, schedule)`
//! (`tests/property_serve.rs`). Serving is only reachable through
//! [`crate::runner::Runner`]; with no `[serve]` config every legacy entry
//! point is bit-for-bit unchanged.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::config::value::Value;
use crate::metrics::histogram::Histogram;
use crate::trace::{TraceEvent, TraceSink, MASTER};
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Stream salt separating the serve clock's RNG family from every other
/// consumer of the cluster seed.
const SERVE_STREAM: u64 = 0x5E21;

/// FNV-1a offset basis / prime for the window-sequence digest.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// What the admission controller does when a request's predicted latency
/// would bust its class SLO.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything; latencies grow without bound past saturation.
    /// This is the policy the f5 bench uses to locate the knee.
    Open,
    /// Shed any request whose *predicted* latency exceeds its class SLO.
    Shed,
    /// Allow queueing up to `queue_slack` × the class SLO, then shed.
    Queue,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "open" | "none" => Ok(AdmissionPolicy::Open),
            "shed" => Ok(AdmissionPolicy::Shed),
            "queue" => Ok(AdmissionPolicy::Queue),
            other => Err(Error::Config(format!(
                "unknown admission policy '{other}' (expected open|shed|queue)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AdmissionPolicy::Open => "open",
            AdmissionPolicy::Shed => "shed",
            AdmissionPolicy::Queue => "queue",
        }
    }
}

/// A scripted burst: offered rate is multiplied by `factor` while the
/// serve clock is in `[start_s, end_s)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Burst {
    pub start_s: f64,
    pub end_s: f64,
    pub factor: f64,
}

/// Full description of a serving workload. Parsed from the `[serve]`
/// config section; only [`crate::runner::Runner`] accepts one.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeSpec {
    /// Mean offered request rate (requests per serve-clock second).
    pub arrival_rate: f64,
    /// Serve-clock milliseconds that elapse per completed training
    /// iteration. The serve clock is *counted*, never measured: it
    /// advances exactly one window per barrier close in both drivers.
    pub window_ms: f64,
    /// p99 SLO for θ reads, milliseconds.
    pub read_slo_ms: f64,
    /// p99 SLO for update (training-example) requests, milliseconds.
    pub update_slo_ms: f64,
    pub admission: AdmissionPolicy,
    /// `Queue` sheds beyond `queue_slack` × the class SLO.
    pub queue_slack: f64,
    /// Parallel read servers draining the read queue.
    pub servers: usize,
    /// Base read service time, milliseconds.
    pub service_ms: f64,
    /// Service time for cache-hot keys, milliseconds.
    pub hot_service_ms: f64,
    /// Fraction of arrivals that are update requests (the rest read θ).
    pub update_frac: f64,
    /// Update requests folded into one mini-batch per iteration.
    pub batch_size: usize,
    /// Key-space size for the Zipf popularity draw.
    pub n_keys: usize,
    /// The `hot_keys` most popular keys are served from cache.
    pub hot_keys: usize,
    /// Zipf exponent (popularity of rank k ∝ 1/k^s).
    pub zipf_s: f64,
    /// Diurnal modulation amplitude in `[0, 1)`: rate is scaled by
    /// `1 + A·sin(2πt/period)`.
    pub diurnal_amplitude: f64,
    pub diurnal_period_s: f64,
    pub bursts: Vec<Burst>,
    /// Seed of the serve RNG family (independent of the cluster seed).
    pub seed: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            arrival_rate: 800.0,
            window_ms: 10.0,
            read_slo_ms: 50.0,
            update_slo_ms: 500.0,
            admission: AdmissionPolicy::Shed,
            queue_slack: 8.0,
            servers: 2,
            service_ms: 1.0,
            hot_service_ms: 0.2,
            update_frac: 0.2,
            batch_size: 32,
            n_keys: 64,
            hot_keys: 4,
            zipf_s: 1.1,
            diurnal_amplitude: 0.0,
            diurnal_period_s: 60.0,
            bursts: Vec::new(),
            seed: 7,
        }
    }
}

impl ServeSpec {
    /// Parse the `[serve]` section of an experiment config. Keys default
    /// to [`ServeSpec::default`]; `bursts` is a `;`-separated script of
    /// `factor@start..end` entries in serve-clock seconds.
    pub fn from_value(v: &Value) -> Result<ServeSpec> {
        let d = ServeSpec::default();
        let spec = ServeSpec {
            arrival_rate: v.opt_f64("serve.arrival_rate", d.arrival_rate),
            window_ms: v.opt_f64("serve.window_ms", d.window_ms),
            read_slo_ms: v.opt_f64("serve.read_slo_ms", d.read_slo_ms),
            update_slo_ms: v.opt_f64("serve.update_slo_ms", d.update_slo_ms),
            admission: AdmissionPolicy::parse(v.opt_str("serve.admission", d.admission.name()))?,
            queue_slack: v.opt_f64("serve.queue_slack", d.queue_slack),
            servers: v.opt_usize("serve.servers", d.servers),
            service_ms: v.opt_f64("serve.service_ms", d.service_ms),
            hot_service_ms: v.opt_f64("serve.hot_service_ms", d.hot_service_ms),
            update_frac: v.opt_f64("serve.update_frac", d.update_frac),
            batch_size: v.opt_usize("serve.batch_size", d.batch_size),
            n_keys: v.opt_usize("serve.n_keys", d.n_keys),
            hot_keys: v.opt_usize("serve.hot_keys", d.hot_keys),
            zipf_s: v.opt_f64("serve.zipf_s", d.zipf_s),
            diurnal_amplitude: v.opt_f64("serve.diurnal_amplitude", d.diurnal_amplitude),
            diurnal_period_s: v.opt_f64("serve.diurnal_period_s", d.diurnal_period_s),
            bursts: parse_bursts(v.opt_str("serve.bursts", ""))?,
            seed: v.opt_u64("serve.seed", d.seed),
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        let bad = |msg: String| Err(Error::Config(format!("[serve] {msg}")));
        let pos = |x: f64| x.is_finite() && x > 0.0;
        if !self.arrival_rate.is_finite() || self.arrival_rate < 0.0 {
            return bad(format!("arrival_rate {} must be finite and >= 0", self.arrival_rate));
        }
        if !pos(self.window_ms) {
            return bad(format!("window_ms {} must be > 0", self.window_ms));
        }
        if !pos(self.read_slo_ms) || !pos(self.update_slo_ms) {
            return bad("read_slo_ms and update_slo_ms must be > 0".to_string());
        }
        if !self.queue_slack.is_finite() || self.queue_slack < 1.0 {
            return bad(format!("queue_slack {} must be >= 1", self.queue_slack));
        }
        if self.servers == 0 {
            return bad("servers must be >= 1".to_string());
        }
        if !pos(self.service_ms) || !pos(self.hot_service_ms) {
            return bad("service_ms and hot_service_ms must be > 0".to_string());
        }
        if !(0.0..=1.0).contains(&self.update_frac) {
            return bad(format!("update_frac {} must be in [0, 1]", self.update_frac));
        }
        if self.batch_size == 0 {
            return bad("batch_size must be >= 1".to_string());
        }
        if self.n_keys == 0 || self.hot_keys > self.n_keys {
            return bad(format!(
                "need 1 <= hot_keys ({}) <= n_keys ({})",
                self.hot_keys, self.n_keys
            ));
        }
        if !pos(self.zipf_s) {
            return bad(format!("zipf_s {} must be > 0", self.zipf_s));
        }
        if !(0.0..1.0).contains(&self.diurnal_amplitude) {
            return bad(format!(
                "diurnal_amplitude {} must be in [0, 1)",
                self.diurnal_amplitude
            ));
        }
        if !pos(self.diurnal_period_s) {
            return bad(format!("diurnal_period_s {} must be > 0", self.diurnal_period_s));
        }
        for b in &self.bursts {
            if b.end_s <= b.start_s || !pos(b.factor) {
                return bad(format!(
                    "burst {}@{}..{} needs start < end and factor > 0",
                    b.factor, b.start_s, b.end_s
                ));
            }
        }
        Ok(())
    }
}

/// Parse a burst script: `;`-separated `factor@start..end` entries, e.g.
/// `"4@2..3;2.5@10..12"`. Empty input is an empty script.
pub fn parse_bursts(s: &str) -> Result<Vec<Burst>> {
    let mut out = Vec::new();
    for part in s.split(';').map(str::trim).filter(|p| !p.is_empty()) {
        let err = || Error::Config(format!("bad burst '{part}' (expected factor@start..end)"));
        let (factor, span) = part.split_once('@').ok_or_else(err)?;
        let (start, end) = span.split_once("..").ok_or_else(err)?;
        out.push(Burst {
            factor: factor.trim().parse().map_err(|_| err())?,
            start_s: start.trim().parse().map_err(|_| err())?,
            end_s: end.trim().parse().map_err(|_| err())?,
        });
    }
    Ok(out)
}

/// Double-buffered θ snapshot cell: the serving read path.
///
/// Writers publish at barrier close into the *inactive* slot and flip;
/// readers clone an `Arc` of the active slot under a short lock. The
/// contract (`docs/SERVING.md`):
///
/// * **never torn** — a slot is rewritten in place only when
///   `Arc::get_mut` proves no reader holds it; otherwise a fresh buffer
///   is swapped in and the held snapshot stays intact;
/// * **at most one epoch stale** — `read()` returns the latest published
///   epoch; a snapshot held across a concurrent publish is exactly one
///   epoch behind until re-read;
/// * **zero-alloc steady state** — once readers drop their views between
///   publishes, both `read` and `publish` touch no allocator
///   (`tests/alloc_regression.rs`).
pub struct ThetaCell {
    inner: Mutex<CellInner>,
}

struct CellInner {
    slots: [Arc<Vec<f32>>; 2],
    active: usize,
    epoch: u64,
}

impl ThetaCell {
    /// A cell holding zeroed snapshots of `dim` coefficients at epoch 0.
    pub fn new(dim: usize) -> Self {
        ThetaCell {
            inner: Mutex::new(CellInner {
                slots: [Arc::new(vec![0.0; dim]), Arc::new(vec![0.0; dim])],
                active: 0,
                epoch: 0,
            }),
        }
    }

    /// Publish a new snapshot tagged `epoch`, flipping the active slot.
    pub fn publish(&self, theta: &[f32], epoch: u64) {
        let mut g = self.inner.lock().unwrap();
        let next = g.active ^ 1;
        match Arc::get_mut(&mut g.slots[next]) {
            Some(buf) if buf.len() == theta.len() => buf.copy_from_slice(theta),
            _ => g.slots[next] = Arc::new(theta.to_vec()),
        }
        g.active = next;
        g.epoch = epoch;
    }

    /// The latest published snapshot and its epoch tag. The returned
    /// `Arc` keeps the snapshot alive and immutable for as long as the
    /// reader holds it.
    pub fn read(&self) -> (u64, Arc<Vec<f32>>) {
        let g = self.inner.lock().unwrap();
        (g.epoch, Arc::clone(&g.slots[g.active]))
    }

    /// The latest published epoch.
    pub fn epoch(&self) -> u64 {
        self.inner.lock().unwrap().epoch
    }
}

/// Serving-side rollup carried in [`crate::coordinator::RunReport`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeStats {
    /// Serve windows stepped (= completed training iterations).
    pub windows: u64,
    /// Total arrivals offered by the open-loop process.
    pub offered: u64,
    /// Read requests admitted and served.
    pub admitted: u64,
    /// Requests shed by admission control (both classes).
    pub shed: u64,
    /// Update requests admitted into the batch queue.
    pub update_requests: u64,
    /// Mini-batches folded into training iterations.
    pub batches: u64,
    /// Update requests consumed by those batches.
    pub batched_updates: u64,
    /// Update requests still queued when the run ended.
    pub queue_final: u64,
    pub read_p50_ms: f64,
    pub read_p99_ms: f64,
    pub update_p50_ms: f64,
    pub update_p99_ms: f64,
    /// θ staleness observed by admitted reads, in iteration-windows:
    /// epoch lag of the snapshot plus the unfolded update backlog.
    pub staleness_mean: f64,
    pub staleness_p99: f64,
    /// Snapshots published through the [`ThetaCell`].
    pub theta_epochs: u64,
    /// FNV-1a digest of the per-window `(offered, admitted, shed,
    /// enqueued, drained)` sequence — the cross-driver bit-identity
    /// witness used by `tests/property_serve.rs`.
    pub seq_digest: u64,
}

impl ServeStats {
    /// Fraction of offered arrivals shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.shed as f64 / self.offered as f64
        }
    }
}

/// The serving engine: arrival process + admission controller + queue
/// model + [`ThetaCell`] publisher, stepped once per completed training
/// iteration by whichever driver owns the run.
pub struct ServeEngine {
    spec: ServeSpec,
    /// Cumulative Zipf popularity over key ranks (last entry 1.0).
    zipf_cdf: Vec<f64>,
    cell: ThetaCell,
    tick: u64,
    /// Outstanding read work across all servers, serve-milliseconds.
    read_backlog_ms: f64,
    /// Queued update requests as `(arrival_tick, count)` runs.
    update_queue: VecDeque<(u64, u64)>,
    queued_updates: u64,
    read_hist: Histogram,
    update_hist: Histogram,
    stale_hist: Histogram,
    stale_sum: f64,
    stale_n: u64,
    offered: u64,
    admitted: u64,
    shed: u64,
    update_requests: u64,
    batches: u64,
    batched_updates: u64,
    digest: u64,
}

impl ServeEngine {
    pub fn new(spec: &ServeSpec) -> Self {
        let mut zipf_cdf = Vec::with_capacity(spec.n_keys);
        let mut acc = 0.0;
        for k in 1..=spec.n_keys {
            acc += 1.0 / (k as f64).powf(spec.zipf_s);
            zipf_cdf.push(acc);
        }
        let total = acc;
        for w in &mut zipf_cdf {
            *w /= total;
        }
        ServeEngine {
            spec: spec.clone(),
            zipf_cdf,
            cell: ThetaCell::new(0),
            tick: 0,
            read_backlog_ms: 0.0,
            update_queue: VecDeque::new(),
            queued_updates: 0,
            read_hist: Histogram::new(1e-2, 1e7, 200),
            update_hist: Histogram::new(1e-2, 1e7, 200),
            stale_hist: Histogram::new(1e-3, 1e5, 160),
            stale_sum: 0.0,
            stale_n: 0,
            offered: 0,
            admitted: 0,
            shed: 0,
            update_requests: 0,
            batches: 0,
            batched_updates: 0,
            digest: FNV_OFFSET,
        }
    }

    /// The serving read path, exposed for tests and embedders.
    pub fn cell(&self) -> &ThetaCell {
        &self.cell
    }

    /// Offered rate at serve-clock second `t`: diurnal sinusoid times
    /// any active scripted burst.
    fn rate_at(&self, t_s: f64) -> f64 {
        let phase = 2.0 * std::f64::consts::PI * t_s / self.spec.diurnal_period_s;
        let mut rate = self.spec.arrival_rate * (1.0 + self.spec.diurnal_amplitude * phase.sin());
        for b in &self.spec.bursts {
            if t_s >= b.start_s && t_s < b.end_s {
                rate *= b.factor;
            }
        }
        rate.max(0.0)
    }

    /// Zipf key rank in `0..n_keys` (rank 0 most popular).
    fn draw_key(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.zipf_cdf.partition_point(|&c| c < u)
    }

    fn mix(&mut self, x: u64) {
        self.digest = (self.digest ^ x).wrapping_mul(FNV_PRIME);
    }

    /// Step one serve window at the close of training iteration `iter`.
    ///
    /// Everything in here is keyed on `(spec.seed, tick)` and the
    /// iteration index; `now` is the driver clock and is used **only**
    /// for trace timestamps, so the realized sequence is identical in
    /// virtual and wall time. Burned windows (no barrier close) never
    /// step the engine — the serve clock advances with *completed*
    /// iterations, which is what makes the sequence comparable across
    /// drivers.
    pub fn on_barrier_close(
        &mut self,
        iter: u64,
        theta: &[f32],
        sink: &mut dyn TraceSink,
        now: f64,
    ) {
        let tick = self.tick;
        self.tick += 1;
        let spec = &self.spec;
        let window_s = spec.window_ms / 1000.0;
        let servers = spec.servers as f64;

        // One window of read service capacity drains first.
        self.read_backlog_ms = (self.read_backlog_ms - spec.window_ms * servers).max(0.0);

        // Every fate this window is pure in (seed, tick): a fresh RNG
        // streamed by the tick, no shared state consumed.
        let mut rng = Pcg64::new(spec.seed ^ SERVE_STREAM, tick);
        let lambda = self.rate_at(tick as f64 * window_s) * window_s;
        let n = poisson(&mut rng, lambda);

        let mut w_admitted = 0u64;
        let mut w_shed = 0u64;
        let mut w_enqueued = 0u64;
        for _ in 0..n {
            let is_update = rng.next_f64() < spec.update_frac;
            let key = self.draw_key(&mut rng);
            if is_update {
                // Predicted wait: backlog windows ahead of this request,
                // plus the window that folds it.
                let predicted =
                    (self.queued_updates as f64 / spec.batch_size as f64 + 1.0) * spec.window_ms;
                if admit(spec.admission, predicted, spec.update_slo_ms, spec.queue_slack) {
                    match self.update_queue.back_mut() {
                        Some((t, c)) if *t == tick => *c += 1,
                        _ => self.update_queue.push_back((tick, 1)),
                    }
                    self.queued_updates += 1;
                    w_enqueued += 1;
                } else {
                    w_shed += 1;
                }
            } else {
                let service = if key < spec.hot_keys {
                    spec.hot_service_ms
                } else {
                    spec.service_ms
                };
                let predicted = self.read_backlog_ms / servers + service;
                if admit(spec.admission, predicted, spec.read_slo_ms, spec.queue_slack) {
                    // The actual read path: an epoch-tagged snapshot view.
                    let (epoch, snap) = self.cell.read();
                    debug_assert!(tick == 0 || !snap.is_empty());
                    drop(snap);
                    let lag = iter.saturating_sub(epoch) as f64;
                    let stale = lag + self.queued_updates as f64 / spec.batch_size as f64;
                    self.stale_hist.record(stale);
                    self.stale_sum += stale;
                    self.stale_n += 1;
                    self.read_hist.record(predicted);
                    self.read_backlog_ms += service;
                    w_admitted += 1;
                } else {
                    w_shed += 1;
                }
            }
        }

        // One mini-batch of queued update requests folds per iteration.
        let mut drained = 0u64;
        while drained < spec.batch_size as u64 {
            let Some((arrived, count)) = self.update_queue.front_mut() else {
                break;
            };
            let take = (*count).min(spec.batch_size as u64 - drained);
            let wait_ms = (tick - *arrived + 1) as f64 * spec.window_ms;
            for _ in 0..take {
                self.update_hist.record(wait_ms);
            }
            *count -= take;
            drained += take;
            if *count == 0 {
                self.update_queue.pop_front();
            }
        }
        self.queued_updates -= drained;
        if drained > 0 {
            self.batches += 1;
            self.batched_updates += drained;
        }

        self.offered += n;
        self.admitted += w_admitted;
        self.shed += w_shed;
        self.update_requests += w_enqueued;

        // θ published after the window's reads: readers of window t see
        // the epoch closed at t-1, exactly one barrier behind.
        self.cell.publish(theta, iter + 1);

        self.mix(tick);
        self.mix(n);
        self.mix(w_admitted);
        self.mix(w_shed);
        self.mix(w_enqueued);
        self.mix(drained);

        if sink.enabled() {
            sink.emit(
                iter,
                MASTER,
                now,
                TraceEvent::ServeWindow {
                    offered: n,
                    admitted: w_admitted,
                    shed: w_shed,
                    queue: self.queued_updates,
                },
            );
            sink.emit(iter, MASTER, now, TraceEvent::ThetaPublish { epoch: iter + 1 });
        }
    }

    /// Fold the engine into its report rollup.
    pub fn finish(self) -> ServeStats {
        let q = |h: &Histogram, p: f64| if h.count() == 0 { 0.0 } else { h.quantile(p) };
        ServeStats {
            windows: self.tick,
            offered: self.offered,
            admitted: self.admitted,
            shed: self.shed,
            update_requests: self.update_requests,
            batches: self.batches,
            batched_updates: self.batched_updates,
            queue_final: self.queued_updates,
            read_p50_ms: q(&self.read_hist, 0.5),
            read_p99_ms: q(&self.read_hist, 0.99),
            update_p50_ms: q(&self.update_hist, 0.5),
            update_p99_ms: q(&self.update_hist, 0.99),
            staleness_mean: if self.stale_n == 0 {
                0.0
            } else {
                self.stale_sum / self.stale_n as f64
            },
            staleness_p99: q(&self.stale_hist, 0.99),
            theta_epochs: self.tick,
            seq_digest: self.digest,
        }
    }
}

fn admit(policy: AdmissionPolicy, predicted_ms: f64, slo_ms: f64, slack: f64) -> bool {
    match policy {
        AdmissionPolicy::Open => true,
        AdmissionPolicy::Shed => predicted_ms <= slo_ms,
        AdmissionPolicy::Queue => predicted_ms <= slo_ms * slack,
    }
}

/// Deterministic Poisson draw: Knuth inversion for small λ, a rounded
/// normal approximation past it (both consume `rng` deterministically).
fn poisson(rng: &mut Pcg64, lambda: f64) -> u64 {
    if lambda <= 0.0 {
        return 0;
    }
    if lambda < 64.0 {
        let limit = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.next_f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }
    let draw = lambda + lambda.sqrt() * rng.normal();
    draw.round().max(0.0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NoopSink;

    fn step_all(mut engine: ServeEngine, iters: u64, dim: usize) -> ServeStats {
        let theta = vec![0.5f32; dim];
        let mut sink = NoopSink;
        for iter in 0..iters {
            engine.on_barrier_close(iter, &theta, &mut sink, iter as f64);
        }
        engine.finish()
    }

    #[test]
    fn default_spec_validates() {
        ServeSpec::default().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_fields() {
        let d = ServeSpec::default();
        let bad = [
            ServeSpec { update_frac: 1.5, ..d.clone() },
            ServeSpec { hot_keys: d.n_keys + 1, ..d.clone() },
            ServeSpec { queue_slack: 0.5, ..d.clone() },
            ServeSpec { window_ms: 0.0, ..d.clone() },
            ServeSpec { bursts: vec![Burst { start_s: 3.0, end_s: 2.0, factor: 2.0 }], ..d },
        ];
        for spec in bad {
            assert!(spec.validate().is_err(), "{spec:?} should fail validation");
        }
    }

    #[test]
    fn admission_parse_roundtrip() {
        for p in [AdmissionPolicy::Open, AdmissionPolicy::Shed, AdmissionPolicy::Queue] {
            assert_eq!(AdmissionPolicy::parse(p.name()).unwrap(), p);
        }
        assert_eq!(AdmissionPolicy::parse("none").unwrap(), AdmissionPolicy::Open);
        assert!(AdmissionPolicy::parse("nope").is_err());
    }

    #[test]
    fn burst_script_parses() {
        let bs = parse_bursts("4@2..3; 2.5@10..12.5").unwrap();
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0], Burst { start_s: 2.0, end_s: 3.0, factor: 4.0 });
        assert_eq!(bs[1], Burst { start_s: 10.0, end_s: 12.5, factor: 2.5 });
        assert!(parse_bursts("").unwrap().is_empty());
        assert!(parse_bursts("x@1..2").is_err());
        assert!(parse_bursts("2@1").is_err());
    }

    #[test]
    fn zipf_cdf_is_monotone_and_normalized() {
        let engine = ServeEngine::new(&ServeSpec::default());
        let cdf = &engine.zipf_cdf;
        assert!(cdf.windows(2).all(|w| w[0] < w[1]));
        assert!((cdf.last().unwrap() - 1.0).abs() < 1e-12);
        // Rank 0 is the most popular single key.
        assert!(cdf[0] > 1.0 / cdf.len() as f64);
    }

    #[test]
    fn sequence_is_pure_in_seed_and_tick() {
        let spec = ServeSpec {
            diurnal_amplitude: 0.4,
            bursts: parse_bursts("3@0.1..0.2").unwrap(),
            ..ServeSpec::default()
        };
        let mut a = ServeEngine::new(&spec);
        let mut b = ServeEngine::new(&spec);
        let sa = step_all(a, 50, 8);
        let sb = step_all(b, 50, 8);
        assert_eq!(sa, sb);
        assert!(sa.offered > 0);

        let mut c = ServeEngine::new(&ServeSpec { seed: 8, ..spec });
        let sc = step_all(c, 50, 8);
        assert_ne!(sa.seq_digest, sc.seq_digest);
    }

    #[test]
    fn burst_raises_offered_load() {
        let quiet = ServeSpec { admission: AdmissionPolicy::Open, ..ServeSpec::default() };
        let bursty = ServeSpec {
            bursts: parse_bursts("5@0..1000").unwrap(),
            ..quiet.clone()
        };
        let so = step_all(ServeEngine::new(&quiet), 40, 4);
        let sb = step_all(ServeEngine::new(&bursty), 40, 4);
        assert!(sb.offered > so.offered * 3);
    }

    #[test]
    fn shed_policy_keeps_read_p99_at_slo() {
        // 10× overload: open admission busts the SLO, shed holds it.
        let open = ServeSpec {
            arrival_rate: 20_000.0,
            admission: AdmissionPolicy::Open,
            ..ServeSpec::default()
        };
        let shed = ServeSpec { admission: AdmissionPolicy::Shed, ..open.clone() };
        let so = step_all(ServeEngine::new(&open), 60, 4);
        let ss = step_all(ServeEngine::new(&shed), 60, 4);
        assert!(so.read_p99_ms > open.read_slo_ms);
        // Quantile reports a log-bucket upper edge; allow one bucket.
        assert!(ss.read_p99_ms <= shed.read_slo_ms * 1.2);
        assert!(ss.shed > 0);
        assert_eq!(so.shed, 0);
    }

    #[test]
    fn updates_batch_and_drain_fifo() {
        let spec = ServeSpec {
            arrival_rate: 3_000.0,
            update_frac: 1.0,
            admission: AdmissionPolicy::Open,
            ..ServeSpec::default()
        };
        let stats = step_all(ServeEngine::new(&spec), 30, 4);
        assert!(stats.update_requests > 0);
        assert_eq!(stats.batched_updates + stats.queue_final, stats.update_requests);
        // ~30 arrivals/window vs batch_size 32: some windows still drain
        // a full batch, and queue growth shows up as update latency.
        assert!(stats.batches > 0);
        assert!(stats.update_p99_ms >= spec.window_ms);
    }

    #[test]
    fn staleness_grows_with_update_backlog() {
        let light = ServeSpec {
            arrival_rate: 400.0,
            admission: AdmissionPolicy::Open,
            ..ServeSpec::default()
        };
        let heavy = ServeSpec { arrival_rate: 40_000.0, ..light.clone() };
        let sl = step_all(ServeEngine::new(&light), 60, 4);
        let sh = step_all(ServeEngine::new(&heavy), 60, 4);
        assert!(sh.staleness_p99 > sl.staleness_p99);
    }

    #[test]
    fn poisson_is_deterministic_and_sane() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 2);
        for lambda in [0.0, 0.5, 5.0, 200.0] {
            assert_eq!(poisson(&mut a, lambda), poisson(&mut b, lambda));
        }
        let mut r = Pcg64::new(3, 4);
        let mean = (0..2000).map(|_| poisson(&mut r, 20.0) as f64).sum::<f64>() / 2000.0;
        assert!((mean - 20.0).abs() < 1.0, "poisson mean {mean}");
        let mut r = Pcg64::new(5, 6);
        let mean = (0..2000).map(|_| poisson(&mut r, 500.0) as f64).sum::<f64>() / 2000.0;
        assert!((mean - 500.0).abs() < 5.0, "normal-approx mean {mean}");
    }

    #[test]
    fn theta_cell_publish_read_roundtrip() {
        let cell = ThetaCell::new(3);
        let (e0, s0) = cell.read();
        assert_eq!(e0, 0);
        assert_eq!(s0.as_slice(), &[0.0, 0.0, 0.0]);
        cell.publish(&[1.0, 2.0, 3.0], 1);
        let (e1, s1) = cell.read();
        assert_eq!(e1, 1);
        assert_eq!(s1.as_slice(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn theta_cell_held_snapshot_survives_two_publishes() {
        let cell = ThetaCell::new(2);
        cell.publish(&[1.0, 1.0], 1);
        let (e, held) = cell.read();
        assert_eq!(e, 1);
        // Two publishes cycle back onto the held slot; the reader's view
        // must stay intact (the writer swaps in a fresh buffer instead).
        cell.publish(&[2.0, 2.0], 2);
        cell.publish(&[3.0, 3.0], 3);
        assert_eq!(held.as_slice(), &[1.0, 1.0]);
        let (e3, s3) = cell.read();
        assert_eq!(e3, 3);
        assert_eq!(s3.as_slice(), &[3.0, 3.0]);
    }

    #[test]
    fn serve_stats_shed_rate() {
        let stats = ServeStats { offered: 200, shed: 50, ..ServeStats::default() };
        assert!((stats.shed_rate() - 0.25).abs() < 1e-12);
        assert_eq!(ServeStats::default().shed_rate(), 0.0);
    }
}
