//! Metrics: per-iteration recording, histograms, CSV/JSON export.

pub mod csv;
pub mod histogram;
pub mod recorder;

pub use histogram::Histogram;
pub use recorder::{IterRow, Recorder};
