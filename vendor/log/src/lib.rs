//! Minimal offline stand-in for the `log` crate facade.
//!
//! The build environment is fully offline, so instead of the crates.io
//! `log` crate this vendored shim provides the subset of its API the
//! workspace actually uses: the five level macros, `Level`/`LevelFilter`,
//! the `Log` trait with `Metadata`/`Record`, and the global
//! `set_logger`/`set_max_level` registry.  Semantics match the real crate
//! for that subset, so swapping the real dependency back in is a one-line
//! `Cargo.toml` change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a single log record.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // Honor width/alignment flags like the real crate does.
        f.pad(s)
    }
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        *self as usize == *other as usize
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata about a log record (level + target module path).
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the formatted message arguments.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A log sink.  Implementations must be thread-safe: records arrive from
/// any thread.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata<'_>) -> bool;
    fn log(&self, record: &Record<'_>);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata<'_>) -> bool {
        false
    }
    fn log(&self, _: &Record<'_>) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger.  Fails if one is already installed.
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger (a no-op sink if none was installed).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

/// Set the global maximum level; records above it are skipped cheaply.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing: dispatch one record to the installed logger.
#[doc(hidden)]
pub fn __private_api_log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if level > max_level() {
        return;
    }
    let record = Record {
        metadata: Metadata { level, target },
        args,
    };
    logger().log(&record);
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_api_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_orderings_cross_compare() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Debug <= LevelFilter::Debug);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(LevelFilter::Off < Level::Error);
    }

    #[test]
    fn macros_are_safe_without_logger() {
        set_max_level(LevelFilter::Trace);
        info!("hello {}", 42);
        debug!("debug {x}", x = 1);
        set_max_level(LevelFilter::Off);
    }
}
