"""L1 correctness: pallas KRR gradient kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute hot-spot (Alg. 3 body).
hypothesis sweeps shard sizes, feature dims, tile sizes and value scales.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import krr_grad as kg
from compile.kernels import ref


def _mk(zeta, l, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    theta = jnp.asarray(rng.normal(0, scale, l), jnp.float32)
    phi = jnp.asarray(rng.normal(0, scale, (zeta, l)), jnp.float32)
    y = jnp.asarray(rng.normal(0, scale, zeta), jnp.float32)
    return theta, phi, y


def _assert_close(a, b, rtol=2e-4, atol=2e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


class TestKrrGradBasic:
    def test_matches_ref_default_shape(self):
        theta, phi, y = _mk(2048, 64, 0)
        _assert_close(kg.krr_grad(theta, phi, y, 0.1), ref.krr_grad(theta, phi, y, 0.1))

    def test_matches_ref_wide_shape(self):
        theta, phi, y = _mk(1024, 256, 1)
        _assert_close(kg.krr_grad(theta, phi, y, 0.01), ref.krr_grad(theta, phi, y, 0.01))

    def test_zero_lambda(self):
        theta, phi, y = _mk(512, 32, 2)
        _assert_close(kg.krr_grad(theta, phi, y, 0.0), ref.krr_grad(theta, phi, y, 0.0))

    def test_zero_theta_gradient_is_data_term(self):
        _, phi, y = _mk(256, 32, 3)
        theta = jnp.zeros(32, jnp.float32)
        g = kg.krr_grad(theta, phi, y, 0.5)
        expect = -(phi.T @ y) / 256
        _assert_close(g, expect)

    def test_perfect_fit_grad_is_reg_only(self):
        rng = np.random.default_rng(4)
        theta = jnp.asarray(rng.normal(0, 1, 16), jnp.float32)
        phi = jnp.asarray(rng.normal(0, 1, (128, 16)), jnp.float32)
        y = phi @ theta  # zero residual
        g = kg.krr_grad(theta, phi, y, 0.3)
        _assert_close(g, 0.3 * theta, rtol=1e-3, atol=1e-4)

    def test_single_block(self):
        # zeta <= block_m: grid has exactly one step, seed path only.
        theta, phi, y = _mk(128, 16, 5)
        _assert_close(
            kg.krr_grad(theta, phi, y, 0.1, block_m=256),
            ref.krr_grad(theta, phi, y, 0.1),
        )

    def test_odd_zeta_block_shrink(self):
        # 300 is not divisible by 256 -> kernel must shrink the tile.
        theta, phi, y = _mk(300, 16, 6)
        _assert_close(kg.krr_grad(theta, phi, y, 0.1), ref.krr_grad(theta, phi, y, 0.1))

    def test_prime_zeta(self):
        theta, phi, y = _mk(509, 8, 7)  # prime -> block shrinks to 1
        _assert_close(kg.krr_grad(theta, phi, y, 0.1), ref.krr_grad(theta, phi, y, 0.1))


class TestKrrGradHypothesis:
    @settings(max_examples=25, deadline=None)
    @given(
        zeta=st.integers(8, 768),
        l=st.sampled_from([4, 8, 16, 32, 64]),
        seed=st.integers(0, 2**31 - 1),
        lam=st.floats(0.0, 2.0),
        block_m=st.sampled_from([32, 64, 128, 256]),
    )
    def test_matches_ref(self, zeta, l, seed, lam, block_m):
        theta, phi, y = _mk(zeta, l, seed)
        g1 = kg.krr_grad(theta, phi, y, lam, block_m=block_m)
        g2 = ref.krr_grad(theta, phi, y, lam)
        _assert_close(g1, g2, rtol=5e-4, atol=5e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        zeta=st.integers(16, 256),
        l=st.sampled_from([8, 32]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.floats(0.01, 10.0),
    )
    def test_value_scales(self, zeta, l, seed, scale):
        theta, phi, y = _mk(zeta, l, seed, scale)
        g1 = kg.krr_grad(theta, phi, y, 0.1)
        g2 = ref.krr_grad(theta, phi, y, 0.1)
        denom = max(1.0, float(np.abs(np.asarray(g2)).max()))
        assert float(np.abs(np.asarray(g1 - g2)).max()) / denom < 1e-3

    @settings(max_examples=15, deadline=None)
    @given(
        zeta=st.integers(8, 512),
        l=st.sampled_from([8, 16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_block_size_invariance(self, zeta, l, seed):
        """Tiling must not change the math: all block sizes agree."""
        theta, phi, y = _mk(zeta, l, seed)
        outs = [
            np.asarray(kg.krr_grad(theta, phi, y, 0.2, block_m=bm))
            for bm in (16, 128, 512)
        ]
        for o in outs[1:]:
            np.testing.assert_allclose(outs[0], o, rtol=3e-4, atol=3e-4)


class TestKrrLossTerms:
    def test_matches_ref(self):
        theta, phi, y = _mk(512, 64, 8)
        s1 = kg.krr_loss_terms(theta, phi, y)
        s2 = ref.krr_sumsq(theta, phi, y)
        assert abs(float(s1) - float(s2)) / max(1.0, abs(float(s2))) < 1e-5

    @settings(max_examples=15, deadline=None)
    @given(
        zeta=st.integers(8, 512),
        l=st.sampled_from([4, 16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, zeta, l, seed):
        theta, phi, y = _mk(zeta, l, seed)
        s1 = float(kg.krr_loss_terms(theta, phi, y))
        s2 = float(ref.krr_sumsq(theta, phi, y))
        assert abs(s1 - s2) / max(1.0, abs(s2)) < 1e-4

    def test_nonnegative(self):
        theta, phi, y = _mk(256, 16, 9)
        assert float(kg.krr_loss_terms(theta, phi, y)) >= 0.0
