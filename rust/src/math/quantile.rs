//! Standard-normal quantile (inverse CDF) — the `u_{α/2}` of Algorithm 1.
//!
//! Acklam's rational approximation (relative error < 1.15e-9 over the whole
//! open interval), refined with one Halley step against an erfc-based CDF,
//! which brings it to ~1e-15 — far beyond what the estimator needs.

/// Inverse CDF of N(0,1): returns `z` with `P(Z <= z) = p`, `p ∈ (0, 1)`.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");

    // Acklam coefficients.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // One Halley refinement step: e = CDF(x) - p, u = e / pdf(x).
    let e = normal_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Standard normal CDF via erfc (Abramowitz & Stegun 7.1.26-style series is
/// not accurate enough; use the W. J. Cody rational erf approximation).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Complementary error function, Cody-style (abs error < 1.2e-7 base, but
/// the continued-fraction branch below is ~1e-15 for the ranges we hit).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x < 0.5 {
        return 1.0 - erf_series(x);
    }
    // Continued fraction (modified Lentz) for erfc, x >= 0.5:
    //   erfc(x) = exp(-x^2)/sqrt(pi) / (x + (1/2)/(x + 1/(x + (3/2)/(x + ...))))
    // b_j = x for all levels, a_j = j/2.
    let x2 = x * x;
    let mut f = x; // f_0 = b_0
    let mut c = x; // C_0 = b_0
    let mut d = 0.0; // D_0
    let mut n = 0.5f64;
    for _ in 0..300 {
        d = x + n * d;
        if d.abs() < 1e-300 {
            d = 1e-300;
        }
        d = 1.0 / d;
        c = x + n / c;
        if c.abs() < 1e-300 {
            c = 1e-300;
        }
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
        n += 0.5;
    }
    ((-x2).exp() / f64::sqrt(std::f64::consts::PI) / f).min(1.0)
}

/// Taylor/series erf for small |x| (converges fast for x < 0.5).
fn erf_series(x: f64) -> f64 {
    let mut term = x;
    let mut sum = x;
    let x2 = x * x;
    for n in 1..60 {
        term *= -x2 / n as f64;
        let add = term / (2 * n + 1) as f64;
        sum += add;
        if add.abs() < 1e-17 {
            break;
        }
    }
    sum * 2.0 / f64::sqrt(std::f64::consts::PI)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference values from scipy.stats.norm.ppf.
    const CASES: &[(f64, f64)] = &[
        (0.5, 0.0),
        (0.975, 1.959963984540054),
        (0.95, 1.6448536269514722),
        (0.995, 2.5758293035489004),
        (0.9995, 3.2905267314919255),
        (0.025, -1.959963984540054),
        (0.1, -1.2815515655446004),
        (0.9, 1.2815515655446004),
        (0.99, 2.3263478740408408),
        (0.0001, -3.719016485455709),
    ];

    #[test]
    fn matches_scipy_ppf() {
        for &(p, want) in CASES {
            let got = normal_quantile(p);
            assert!(
                (got - want).abs() < 1e-8,
                "ppf({p}) = {got}, want {want}"
            );
        }
    }

    #[test]
    fn cdf_quantile_roundtrip() {
        for i in 1..99 {
            let p = i as f64 / 100.0;
            let z = normal_quantile(p);
            let back = normal_cdf(z);
            assert!((back - p).abs() < 1e-10, "p={p} z={z} back={back}");
        }
    }

    #[test]
    fn symmetry() {
        for &p in &[0.01, 0.1, 0.25, 0.4] {
            let a = normal_quantile(p);
            let b = normal_quantile(1.0 - p);
            assert!((a + b).abs() < 1e-10);
        }
    }

    #[test]
    fn cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((normal_cdf(1.96) - 0.9750021048517795).abs() < 1e-9);
        assert!((normal_cdf(-1.0) - 0.15865525393145707).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn rejects_p_zero() {
        normal_quantile(0.0);
    }
}
